#!/usr/bin/env bash
# CI guard (ISSUE 4, ISSUE 5; rebuilt in ISSUE 8): the normative wire
# spec in docs/PROTOCOL.md and the implementation must agree on the
# frame-kind byte values, the reject-reason codes, the membership status
# codes, the frame version, and the configuration-key table.
#
# The grep/diff heuristics that used to live here are now the `spec-sync`
# rule of the in-tree analyzer (tools/analyze): it parses the codec
# enums and the spec tables for real, and also checks the code()/
# from_code() bijections and the config-key tables both ways. This
# wrapper keeps the script name stable for CI and muscle memory.
set -euo pipefail
cd "$(dirname "$0")/.."

exec cargo run --quiet --release -p dudd-analyze -- spec-sync "$@"
