#!/usr/bin/env bash
# CI guard (ISSUE 4, extended by ISSUE 5): the normative wire spec in
# docs/PROTOCOL.md and the implementation must agree on the frame-kind
# byte values, the reject-reason codes, the membership status codes, and
# the frame version. Pure grep/diff — runs without a Rust toolchain.
set -euo pipefail
cd "$(dirname "$0")/.."

codec=rust/src/sketch/codec.rs
membership=rust/src/service/membership.rs
spec=docs/PROTOCOL.md
fail=0

# Frame kinds: `Push = 1,` style enum discriminants in the codec vs the
# `| `Push` | 1 |` table rows in the spec. Longest alternatives first so
# MembershipPush never half-matches as Push.
kind_names='MembershipReply|MembershipPush|JoinRequest|DeltaReply|DeltaPush|Reject|Reply|Push'
code_kinds=$(grep -oE "\b($kind_names) = [0-9]+" "$codec" \
  | sed -E 's/ = /=/' | sort -u)
doc_kinds=$(grep -oE "\| \`($kind_names)\` \| [0-9]+ \|" "$spec" \
  | sed -E 's/^\| `//; s/` \| /=/; s/ \|$//' | sort -u)
if ! diff <(echo "$code_kinds") <(echo "$doc_kinds") >/dev/null; then
  echo "FRAME-KIND MISMATCH between $codec and $spec:"
  diff <(echo "$code_kinds") <(echo "$doc_kinds") || true
  fail=1
fi

# Reject reasons: the `RejectReason::X => n,` arms of code() vs the
# spec's reject table.
reason_names='BaselineMismatch|StaleGeneration|NoMembership|Malformed|Lineage|Busy'
code_reasons=$(grep -oE "RejectReason::($reason_names) => [0-9]+" "$codec" \
  | sed -E 's/RejectReason:://; s/ => /=/' | sort -u)
doc_reasons=$(grep -oE "\| \`($reason_names)\` \| [0-9]+ \|" "$spec" \
  | sed -E 's/^\| `//; s/` \| /=/; s/ \|$//' | sort -u)
if ! diff <(echo "$code_reasons") <(echo "$doc_reasons") >/dev/null; then
  echo "REJECT-REASON MISMATCH between $codec and $spec:"
  diff <(echo "$code_reasons") <(echo "$doc_reasons") || true
  fail=1
fi

# Membership status codes: the `MemberStatus::X => n,` arms of code()
# in the membership module vs the spec's status table (ISSUE 5).
status_names='Suspect|Alive|Dead'
code_status=$(grep -oE "MemberStatus::($status_names) => [0-9]+" "$membership" \
  | sed -E 's/MemberStatus:://; s/ => /=/' | sort -u)
doc_status=$(grep -oE "\| \`($status_names)\` \| [0-9]+ \|" "$spec" \
  | sed -E 's/^\| `//; s/` \| /=/; s/ \|$//' | sort -u)
if ! diff <(echo "$code_status") <(echo "$doc_status") >/dev/null; then
  echo "MEMBER-STATUS MISMATCH between $membership and $spec:"
  diff <(echo "$code_status") <(echo "$doc_status") || true
  fail=1
fi

# Frame version byte.
code_version=$(grep -oE 'const VERSION: u8 = [0-9]+' "$codec" | grep -oE '[0-9]+$')
doc_version=$(grep -ioE 'protocol version: \*\*[0-9]+\*\*' "$spec" | grep -oE '[0-9]+')
if [ "$code_version" != "$doc_version" ]; then
  echo "VERSION MISMATCH: codec has $code_version, spec has $doc_version"
  fail=1
fi

if [ "$fail" -ne 0 ]; then
  echo "docs/PROTOCOL.md is out of sync with the implementation"
  exit 1
fi
echo "protocol spec in sync: kinds [$(echo "$code_kinds" | tr '\n' ' ')], reasons [$(echo "$code_reasons" | tr '\n' ' ')], statuses [$(echo "$code_status" | tr '\n' ' ')], version $code_version"
