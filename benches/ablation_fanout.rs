//! Ablation: fan-out (§4 — "each peer the option to gossip with a
//! user-defined number of neighbours"). Measures rounds-to-convergence
//! and per-round cost for fan-out ∈ {1, 2, 4}.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ExperimentConfig;
use duddsketch::data::DatasetKind;
use duddsketch::experiments::run_with_snapshots;
use duddsketch::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    println!("convergence vs fan-out (adversarial input, P=300):");
    println!("  fan-out | worst ARE @R5 | @R10 | @R15 | wall");
    for fan_out in [1usize, 2, 4] {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = DatasetKind::Adversarial;
        cfg.peers = 300;
        cfg.items_per_peer = 500;
        cfg.fan_out = fan_out;
        let out = run_with_snapshots(&cfg, &[5, 10, 15]).unwrap();
        let worst = |i: usize| -> f64 {
            out.snapshots[i]
                .quantiles
                .iter()
                .map(|q| q.are)
                .fold(0.0f64, f64::max)
        };
        println!(
            "  {:<7} | {:<13.3e} | {:<8.1e} | {:<8.1e} | {:.2}s",
            fan_out,
            worst(0),
            worst(1),
            worst(2),
            out.wall_s
        );
    }
    println!();

    for fan_out in [1usize, 2, 4] {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = DatasetKind::Uniform;
        cfg.peers = 512;
        cfg.items_per_peer = 200;
        cfg.fan_out = fan_out;
        let master = duddsketch::rng::default_rng(cfg.seed);
        let datasets = duddsketch::data::all_peer_datasets(
            cfg.dataset,
            cfg.peers,
            cfg.items_per_peer,
            &master,
        );
        let mut grng = master.derive(0x6EA4);
        let graph = duddsketch::graph::paper_ba(cfg.peers, &mut grng);
        let mut proto =
            duddsketch::gossip::Protocol::new(&cfg, graph, &datasets, &master).unwrap();
        b.case(
            &format!("round cost fan-out={fan_out} P=512"),
            cfg.peers as u64,
            || proto.run(1),
        );
    }
    b.finish("ablation_fanout");
}
