//! Insert-path throughput: the L3 ingestion hot loop.
//!
//! Paper context: the streaming model requires O(1) worst-case per-item
//! processing (§1); this bench verifies the constant is small. Ablation:
//! dense vs sparse store, UDDSketch vs DDSketch baseline, collapse-heavy
//! vs collapse-free inputs.

use duddsketch::rng::{default_rng, Rng};
use duddsketch::sketch::{DdSketch, DenseStore, SparseStore, UddSketch};
use duddsketch::util::bench::{black_box, Bencher};

const N: usize = 1_000_000;

fn narrow_data() -> Vec<f64> {
    // Two decades: no collapses at m=1024.
    let mut r = default_rng(1);
    (0..N).map(|_| 1.0 + 99.0 * r.next_f64()).collect()
}

fn wide_data() -> Vec<f64> {
    // Nine decades: forces collapses at m=1024, alpha=0.001.
    let mut r = default_rng(2);
    (0..N).map(|_| 10f64.powf(r.next_f64() * 9.0 - 3.0)).collect()
}

fn main() {
    let mut b = Bencher::new();
    let narrow = narrow_data();
    let wide = wide_data();

    b.case("udd/dense/narrow 1M inserts", N as u64, || {
        let mut s: UddSketch<DenseStore> = UddSketch::new(0.001, 1024).unwrap();
        s.extend(&narrow);
        black_box(s.count());
    });
    b.case("udd/dense/wide 1M inserts (collapsing)", N as u64, || {
        let mut s: UddSketch<DenseStore> = UddSketch::new(0.001, 1024).unwrap();
        s.extend(&wide);
        black_box(s.count());
    });
    b.case("udd/sparse/narrow 1M inserts", N as u64, || {
        let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, 1024).unwrap();
        s.extend(&narrow);
        black_box(s.count());
    });
    b.case("dd/dense/narrow 1M inserts (baseline)", N as u64, || {
        let mut s: DdSketch<DenseStore> = DdSketch::new(0.001, 1024).unwrap();
        s.extend(&narrow);
        black_box(s.count());
    });
    b.case("udd/dense/narrow insert+delete 1M", 2 * N as u64, || {
        let mut s: UddSketch<DenseStore> = UddSketch::new(0.001, 1024).unwrap();
        s.extend(&narrow);
        for &x in &narrow {
            s.delete(x);
        }
        black_box(s.count());
    });
    b.finish("insert");
}
