//! Sharded service ingest throughput vs the single-threaded baseline.
//!
//! Acceptance: multi-shard ingest scales with shard count over the
//! sequential `benches/insert.rs` hot loop (same narrow workload, same
//! sketch parameters). Each service case covers the full lifecycle —
//! spawn, concurrent writers, epoch fold, shutdown — so the numbers are
//! end-to-end, not just the shard inner loop.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ServiceConfig;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::service::{Node, QuantileService};
use duddsketch::sketch::{DenseStore, UddSketch};
use duddsketch::util::bench::{black_box, Bencher};

const N: usize = 1_000_000;

fn narrow_data() -> Vec<f64> {
    // Two decades: no collapses at m=1024 (mirrors insert.rs).
    let mut r = default_rng(1);
    (0..N).map(|_| 1.0 + 99.0 * r.next_f64()).collect()
}

/// Full service lifecycle over `data`: returns the snapshot count so the
/// optimizer cannot elide the fold.
fn run_service(data: &[f64], shards: usize, window_slots: usize) -> f64 {
    let mut cfg = ServiceConfig::default();
    cfg.shards = shards;
    cfg.batch_size = 4096;
    cfg.window_slots = window_slots;
    let svc = QuantileService::start(cfg).unwrap();
    let chunk = data.len().div_ceil(shards);
    std::thread::scope(|scope| {
        for part in data.chunks(chunk) {
            let mut w = svc.writer();
            scope.spawn(move || {
                w.insert_batch(part);
                w.flush();
            });
        }
    });
    let snap = svc.flush();
    assert_eq!(snap.count(), data.len() as f64);
    let c = snap.count();
    svc.shutdown();
    c
}

/// Same lifecycle through a `Node`, whose service books every batch into
/// the metrics registry (ISSUE 6) — the instrumented twin of
/// `run_service` for measuring hot-path booking overhead.
fn run_instrumented(data: &[f64], shards: usize) -> f64 {
    let mut cfg = ServiceConfig::default();
    cfg.shards = shards;
    cfg.batch_size = 4096;
    let node = Node::builder().config(cfg).build().unwrap();
    let chunk = data.len().div_ceil(shards);
    std::thread::scope(|scope| {
        for part in data.chunks(chunk) {
            let mut w = node.writer();
            scope.spawn(move || {
                w.insert_batch(part);
                w.flush();
            });
        }
    });
    let snap = node.flush();
    assert_eq!(snap.count(), data.len() as f64);
    let c = snap.count();
    node.shutdown();
    c
}

fn main() {
    let mut b = Bencher::new();
    let narrow = narrow_data();

    b.case("seq/dense/narrow 1M inserts (baseline)", N as u64, || {
        let mut s: UddSketch<DenseStore> = UddSketch::new(0.001, 1024).unwrap();
        s.extend(&narrow);
        black_box(s.count());
    });

    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    for shards in [1usize, 2, 4, 8] {
        if shards > cores {
            eprintln!("skipping {shards} shards ({cores} cores available)");
            continue;
        }
        b.case(
            &format!("service/{shards}-shard narrow 1M inserts"),
            N as u64,
            || {
                black_box(run_service(&narrow, shards, 0));
            },
        );
    }

    // Registry booking on the ingest hot path (ISSUE 6 acceptance):
    // three relaxed atomic adds per batch, so at batch_size 4096 the
    // instrumented node must land within 5% of the bare service case
    // with the same shard count above.
    for shards in [1usize, 4] {
        if shards > cores {
            eprintln!("skipping instrumented {shards} shards ({cores} cores available)");
            continue;
        }
        b.case(
            &format!("service/{shards}-shard narrow 1M inserts (instrumented)"),
            N as u64,
            || {
                black_box(run_instrumented(&narrow, shards));
            },
        );
    }

    let shards = 4.min(cores);
    b.case(
        &format!("service/{shards}-shard windowed(8) 1M inserts"),
        N as u64,
        || {
            black_box(run_service(&narrow, shards, 8));
        },
    );

    // Epoch fold + publish cost at steady state (ingest done, drain all
    // shards, merge, publish).
    {
        let mut cfg = ServiceConfig::default();
        cfg.shards = shards;
        cfg.batch_size = 4096;
        let svc = QuantileService::start(cfg).unwrap();
        let mut w = svc.writer();
        w.insert_batch(&narrow);
        w.flush();
        // Ship fresh data each iteration: idle cumulative epochs
        // short-circuit without folding, which is not what we're timing.
        b.case("service/epoch fold+publish (1k new items)", 1_000, || {
            let mut w = svc.writer();
            w.insert_batch(&narrow[..1_000]);
            w.flush();
            drop(w);
            black_box(svc.flush().epoch());
        });
        // Lock-free snapshot reads.
        svc.flush();
        b.case("service/snapshot load + p50 query x1000", 1000, || {
            for _ in 0..1000 {
                let snap = svc.snapshot();
                black_box(snap.quantile(0.5).unwrap());
            }
        });
        svc.shutdown();
    }

    b.finish("service_ingest");
}
