//! Ablation: collapse strategy accuracy — UDDSketch's uniform collapse
//! (§3.2) vs DDSketch's collapse-first-two (§3.1) at equal budgets.
//!
//! This regenerates the paper's core qualitative claim (UDDSketch is
//! α-accurate over the whole (0,1) range, DDSketch only near q=1) as a
//! measured table, plus the wall-clock cost of each strategy.

use duddsketch::metrics::relative_error;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::sketch::{DdSketch, ExactQuantiles, UddSketch};
use duddsketch::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let mut r = default_rng(21);
    // Eight decades -> heavy collapsing at m=128.
    let data: Vec<f64> = (0..500_000)
        .map(|_| 10f64.powf(r.next_f64() * 8.0 - 2.0))
        .collect();
    let exact = ExactQuantiles::new(&data);

    let mut udd: UddSketch = UddSketch::new(0.01, 128).unwrap();
    let mut dd: DdSketch = DdSketch::new(0.01, 128).unwrap();
    udd.extend(&data);
    dd.extend(&data);

    println!("accuracy at equal budget (m=128, alpha=0.01, 8-decade input):");
    println!("  q      udd rel.err    dd rel.err");
    for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
        let tru = exact.quantile(q).unwrap();
        let ue = relative_error(udd.quantile(q).unwrap(), tru);
        let de = relative_error(dd.quantile(q).unwrap(), tru);
        println!("  {q:<5}  {ue:<12.4e}  {de:<12.4e}");
    }
    println!(
        "  (udd final alpha: {:.4}; dd keeps alpha {:.4} but only near q=1)\n",
        udd.alpha(),
        dd.alpha()
    );

    b.case("udd build 500k (uniform collapse)", 500_000, || {
        let mut s: UddSketch = UddSketch::new(0.01, 128).unwrap();
        s.extend(&data);
        black_box(s.count());
    });
    b.case("dd build 500k (first-two collapse)", 500_000, || {
        let mut s: DdSketch = DdSketch::new(0.01, 128).unwrap();
        s.extend(&data);
        black_box(s.count());
    });
    b.finish("ablation_collapse");
}
