//! Churn cost A/B (ISSUE 9): what a join, a death, and an epoch advance
//! cost a fleet in wall-clock and wire bytes, under the restart-free
//! rules (`gossip_restart_free = true`, the default — `docs/PROTOCOL.md`
//! §10) versus the PR 5 restart-everything rules (`= false`).
//!
//! Three churn kinds, each as a matched A/B pair:
//!
//! * **join / death / quiet** — whole deterministic simulator runs (the
//!   production loop + membership plane over `SimTransport`, virtual
//!   clock) with one scheduled churn wave mid-run; `quiet` is the
//!   no-churn floor both arms share. Wall-clock lands in the timed
//!   cases; the wire-byte and generation A/B — which the timer cannot
//!   see — is printed as `churn-bytes …` lines from one reference run
//!   of each arm (same seed, so the lines are reproducible).
//! * **epoch-carry / epoch-reseed** — the live `GossipLoop` stepping
//!   through an epoch advance per iteration: carried in place as an
//!   additive delta (restart-free) versus a full snapshot → `PeerState`
//!   rebuild of the whole fleet (PR 5 rules).
//!
//! `DUDD_BENCH_JSON=BENCH_churn.json cargo bench --bench churn_cost`
//! refreshes the committed baseline.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{GossipLoopConfig, ServiceConfig};
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::rng::default_rng;
use duddsketch::service::{GossipLoop, GossipMember, QuantileService};
use duddsketch::sim::{EventAction, Scenario, ScheduledEvent, SimFleet};
use duddsketch::util::bench::{black_box, Bencher};
use std::sync::Arc;

/// Fleet size for the simulator arms — big enough that a fleet-wide
/// reseed visibly outweighs one member's churn, small enough that a
/// whole run fits a bench iteration.
const MEMBERS: usize = 24;
const ROUNDS: u64 = 20;
const SEED: u64 = 7;

fn churn_scenario(name: &str, restart_free: bool, action: Option<EventAction>) -> Scenario {
    let mut s = Scenario::default();
    s.name = name.into();
    s.members = MEMBERS;
    s.rounds = ROUNDS;
    s.items_per_member = 100;
    s.alpha = 0.01;
    s.max_buckets = 256;
    // Dead-detection fits the run: suspicion outlives one virtual
    // round, death two (as in the integration scenarios).
    s.suspect_after_ms = 1_000;
    s.restart_free = restart_free;
    if let Some(action) = action {
        s.events = vec![ScheduledEvent { round: 8, action }];
    }
    s
}

/// One timed case per A/B arm, plus a reference run whose byte and
/// generation totals are printed (the part a wall-clock sample can't
/// carry). `mk` rebuilds the churn wave per run so the scenario needs
/// no `Clone`.
fn sim_case(b: &mut Bencher, label: &str, mk: impl Fn() -> Option<EventAction>) {
    for restart_free in [true, false] {
        let report = SimFleet::new(churn_scenario(label, restart_free, mk()), SEED)
            .unwrap()
            .run()
            .unwrap();
        let exchange_bytes: usize = report.rounds.iter().map(|r| r.bytes).sum();
        let membership_bytes: usize = report.rounds.iter().map(|r| r.membership_bytes).sum();
        let final_generation = report.rounds.iter().map(|r| r.generation).max().unwrap_or(1);
        println!(
            "churn-bytes {label} restart-free={restart_free}: wire_bytes={} \
             exchange_bytes={exchange_bytes} membership_bytes={membership_bytes} \
             final_generation={final_generation}",
            report.net.bytes
        );
        b.case(
            &format!("churn/{label} restart-free={restart_free}"),
            MEMBERS as u64,
            || {
                black_box(
                    SimFleet::new(churn_scenario(label, restart_free, mk()), SEED)
                        .unwrap()
                        .run()
                        .unwrap(),
                );
            },
        );
    }
}

/// A live-loop fleet (one real service + static peers) for the epoch
/// arm, mirroring the `gossip_loop` bench fixture.
fn epoch_fleet(nodes: usize, restart_free: bool) -> (GossipLoop, Arc<QuantileService>) {
    let master = default_rng(42);
    let mut cfg = ServiceConfig::default();
    cfg.shards = 2;
    let svc = QuantileService::start_shared(cfg).unwrap();
    let mut w = svc.writer();
    w.insert_batch(&peer_dataset(DatasetKind::Exponential, 0, 20_000, &master));
    w.flush();
    svc.flush();
    let mut members = vec![GossipMember::service(svc.clone())];
    for i in 1..nodes {
        let data = peer_dataset(DatasetKind::Exponential, i, 20_000, &master);
        members.push(GossipMember::from_dataset(&data, 0.001, 1024).unwrap());
    }
    let mut gcfg = GossipLoopConfig::default();
    gcfg.restart_free = restart_free;
    let gl = GossipLoop::start(gcfg, members).unwrap();
    (gl, svc)
}

fn main() {
    let mut b = Bencher::new();

    sim_case(&mut b, "join", || Some(EventAction::Join(4)));
    sim_case(&mut b, "death", || Some(EventAction::Crash(4)));
    sim_case(&mut b, "quiet", || None);

    // Epoch advance: each iteration publishes a fresh epoch, then steps.
    // Restart-free folds the additive delta into the averaged slot in
    // place; the PR 5 arm rebuilds every PeerState from snapshots.
    for restart_free in [true, false] {
        let (gl, svc) = epoch_fleet(16, restart_free);
        let mut w = svc.writer();
        let mode = if restart_free { "carry" } else { "reseed" };
        b.case(&format!("churn/epoch-{mode} nodes=16"), 16, || {
            w.insert(1.0);
            w.flush();
            svc.flush();
            black_box(gl.step());
        });
        drop(w);
        drop(gl);
        if let Ok(s) = Arc::try_unwrap(svc) {
            s.shutdown();
        }
    }

    b.finish("churn_cost");
}
