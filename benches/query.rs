//! Quantile-query latency: sequential sketch queries and the distributed
//! Algorithm-6 reconstruction.

use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::gossip::PeerState;
use duddsketch::rng::default_rng;
use duddsketch::sketch::{ExactQuantiles, UddSketch};
use duddsketch::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let master = default_rng(9);
    let data = peer_dataset(DatasetKind::Power, 0, 500_000, &master);

    let mut sketch: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    sketch.extend(&data);
    let qs: Vec<f64> = (1..100).map(|i| i as f64 / 100.0).collect();

    b.case("sequential quantile x99", 99, || {
        for &q in &qs {
            black_box(sketch.quantile(q).unwrap());
        }
    });

    let mut state = PeerState::init(0, &data, 0.001, 1024).unwrap();
    state.q_tilde = 1.0 / 1000.0; // converged 1000-peer network
    b.case("algorithm-6 distributed query x99", 99, || {
        for &q in &qs {
            black_box(state.query(q).unwrap());
        }
    });

    let exact = ExactQuantiles::new(&data);
    b.case("exact oracle quantile x99 (500k sorted)", 99, || {
        for &q in &qs {
            black_box(exact.quantile(q).unwrap());
        }
    });

    b.finish("query");
}
