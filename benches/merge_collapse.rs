//! Merge and collapse costs: the per-exchange work of the gossip protocol
//! (Algorithm 5) — the simulator's O(1)-per-round assumption (§4) holds
//! when this is independent of the stream length, which the bench shows.

use duddsketch::gossip::PeerState;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::sketch::{SparseStore, Store, UddSketch};
use duddsketch::util::bench::{black_box, Bencher};

fn peer(seed: u64, items: usize, decades: f64) -> PeerState {
    let mut r = default_rng(seed);
    let data: Vec<f64> = (0..items)
        .map(|_| 10f64.powf(r.next_f64() * decades))
        .collect();
    PeerState::init(seed as usize, &data, 0.001, 1024).unwrap()
}

fn main() {
    let mut b = Bencher::new();

    // Per-exchange cost is independent of stream length (sketch-size
    // bound): same bucket budget, 100x the items.
    for items in [1_000usize, 100_000] {
        let a = peer(1, items, 3.0);
        let c = peer(2, items, 3.0);
        b.case(
            &format!("gossip exchange (UPDATE) items/peer={items}"),
            1,
            || {
                black_box(PeerState::averaged(&a, &c).unwrap());
            },
        );
    }

    // Merge with collapse-depth alignment (worst case: disjoint ranges).
    let lo = peer(3, 10_000, 2.0);
    let hi = {
        let mut r = default_rng(4);
        let data: Vec<f64> = (0..10_000)
            .map(|_| 1e6 * 10f64.powf(r.next_f64() * 2.0))
            .collect();
        PeerState::init(4, &data, 0.001, 1024).unwrap()
    };
    b.case("merge disjoint ranges (align+collapse)", 1, || {
        let mut s = lo.sketch.clone();
        s.merge_weighted(&hi.sketch, 0.5, 0.5).unwrap();
        black_box(s.bucket_count());
    });

    // Pure uniform collapse on a full sparse store.
    let full = {
        let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, usize::MAX >> 1).unwrap();
        let mut r = default_rng(5);
        for _ in 0..100_000 {
            s.insert(10f64.powf(r.next_f64() * 6.0));
        }
        s
    };
    b.case("uniform collapse (sparse, ~7k buckets)", 1, || {
        let mut s = full.clone();
        s.force_collapse();
        black_box(s.positive_store().nonzero());
    });

    b.finish("merge_collapse");
}
