//! Whole-network round cost: sequential vs matched mode, native vs PJRT
//! executor (the Layer-1/2 artifact on the request path), across network
//! sizes — the simulator's end-to-end hot loop.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{ExecutorKind, ExperimentConfig};
use duddsketch::data::{all_peer_datasets, DatasetKind};
use duddsketch::gossip::{Protocol, RoundMode};
use duddsketch::graph::paper_ba;
use duddsketch::rng::default_rng;
use duddsketch::util::bench::Bencher;

fn proto(peers: usize, executor: ExecutorKind, mode: RoundMode) -> Option<Protocol> {
    let mut cfg = ExperimentConfig::default();
    cfg.peers = peers;
    cfg.items_per_peer = 200;
    cfg.dataset = DatasetKind::Uniform;
    cfg.alpha = 0.01;
    cfg.max_buckets = 128;
    cfg.executor = executor;
    let master = default_rng(42);
    let datasets = all_peer_datasets(cfg.dataset, peers, cfg.items_per_peer, &master);
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(peers, &mut grng);
    match Protocol::new(&cfg, graph, &datasets, &master) {
        Ok(mut p) => {
            p.set_mode(mode);
            Some(p)
        }
        Err(e) => {
            eprintln!("skipping {executor:?} (peers={peers}): {e:#}");
            None
        }
    }
}

fn main() {
    let mut b = Bencher::new();

    for peers in [256usize, 1024, 4096] {
        if let Some(mut p) = proto(peers, ExecutorKind::Native, RoundMode::Sequential) {
            b.case(
                &format!("round/sequential/native P={peers}"),
                peers as u64,
                || p.run(1),
            );
        }
        if let Some(mut p) = proto(peers, ExecutorKind::Native, RoundMode::Matched) {
            b.case(
                &format!("round/matched/native P={peers}"),
                peers as u64,
                || p.run(1),
            );
        }
    }
    // PJRT path: only shapes with artifacts (see python/compile/aot.py).
    for peers in [256usize, 1024] {
        if let Some(mut p) = proto(peers, ExecutorKind::Pjrt, RoundMode::Matched) {
            b.case(
                &format!("round/matched/pjrt P={peers}"),
                peers as u64,
                || p.run(1),
            );
        }
    }
    b.finish("gossip_round");
}
