//! Cost of one atomic push–pull exchange per transport: the in-process
//! fast path (direct merge + byte accounting) vs a full loopback-TCP
//! round trip (connect, framed push, serve, framed reply, adopt) — the
//! per-exchange overhead a remote fleet pays over a co-located one.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ServiceConfig;
use duddsketch::gossip::PeerState;
use duddsketch::prelude::*;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::service::transport::in_process_exchange;
use duddsketch::util::bench::{black_box, Bencher};
use std::time::Duration;

fn peer(id: usize, items: usize, seed: u64) -> PeerState {
    let mut r = default_rng(seed);
    let data: Vec<f64> = (0..items)
        .map(|_| 10f64.powf(r.next_f64() * 4.0 - 1.0))
        .collect();
    PeerState::init(id, &data, 0.001, 1024).unwrap()
}

fn main() {
    let mut b = Bencher::new();

    for items in [10_000usize, 100_000] {
        let a0 = peer(0, items, 1);
        let b0 = peer(1, items, 2);
        b.case(&format!("transport/in-process items={items}"), 1, || {
            let mut a = a0.clone();
            let mut bb = b0.clone();
            black_box(in_process_exchange(&mut a, &mut bb).unwrap());
        });
    }

    // Loopback TCP: a 2-node fleet; each measured op is one full framed
    // push–pull against the serving node's accept loop.
    let mut cfg = ServiceConfig::default();
    cfg.shards = 1;
    cfg.gossip.round_interval_ms = 0;
    let server = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(TcpTransport::bind("127.0.0.1:0", Duration::from_millis(1_000)).unwrap())
        .remote_peer("127.0.0.1:9".parse().unwrap()) // placeholder; server never initiates
        .build()
        .unwrap();
    let addr = server.listen_addr().unwrap();
    {
        let mut w = server.writer();
        w.insert_batch(&(1..=10_000).map(|i| i as f64 * 0.01).collect::<Vec<_>>());
        w.flush();
    }
    server.flush();
    let _ = server.step(); // seed the fresh epoch into the protocol state

    let transport = TcpTransport::connect_only(Duration::from_millis(1_000)).unwrap();
    let gen = server.global_view().unwrap().generation();
    let initiator = peer(1, 10_000, 3);
    b.case("transport/tcp-loopback items=10000", 1, || {
        let mut local = initiator.clone();
        black_box(
            transport
                .exchange_remote(&mut local, gen, addr)
                .expect("loopback exchange"),
        );
    });

    server.shutdown();
    b.finish("transport_exchange");
}
