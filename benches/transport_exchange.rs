//! Cost of one atomic push–pull exchange per transport configuration:
//! the in-process fast path (direct merge + byte accounting), a full
//! loopback-TCP round trip on a **fresh connect** per exchange (the
//! pre-PR 4 hot path), the same on a **pooled** connection (connection
//! reuse), and a pooled **delta** exchange on a near-converged pair
//! (changed buckets only) — the three layers of the ISSUE 4 transport
//! overhaul, A/B-able against each other.
//!
//! Besides latency, the run prints the measured bytes-on-wire of a full
//! vs a near-converged delta exchange. Refresh the checked-in baseline
//! with:
//!
//! ```text
//! DUDD_BENCH_JSON=BENCH_transport.json cargo bench --bench transport_exchange
//! ```

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ServiceConfig;
use duddsketch::gossip::PeerState;
use duddsketch::prelude::*;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::service::transport::in_process_exchange;
use duddsketch::util::bench::{black_box, Bencher};
use std::time::Duration;

fn peer(id: usize, items: usize, seed: u64) -> PeerState {
    let mut r = default_rng(seed);
    let data: Vec<f64> = (0..items)
        .map(|_| 10f64.powf(r.next_f64() * 4.0 - 1.0))
        .collect();
    PeerState::init(id, &data, 0.001, 1024).unwrap()
}

fn opts(pool: usize, delta: bool) -> TcpTransportOptions {
    TcpTransportOptions {
        deadline: Duration::from_millis(2_000),
        pool_connections: pool,
        pool_idle: Duration::from_millis(30_000),
        delta_exchanges: delta,
        ..TcpTransportOptions::default()
    }
}

fn main() {
    let mut b = Bencher::new();

    for items in [10_000usize, 100_000] {
        let a0 = peer(0, items, 1);
        let b0 = peer(1, items, 2);
        b.case(&format!("transport/in-process items={items}"), 1, || {
            let mut a = a0.clone();
            let mut bb = b0.clone();
            black_box(in_process_exchange(&mut a, &mut bb).unwrap());
        });
    }

    // Loopback TCP: one serving node; each measured op is one full
    // framed push–pull against its serve loop. The server's own remote
    // peer entry is a placeholder (it never initiates).
    let mut cfg = ServiceConfig::default();
    cfg.shards = 1;
    cfg.gossip.round_interval_ms = 0;
    let server = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(TcpTransport::bind_with("127.0.0.1:0", opts(2, true)).unwrap())
        .remote_peer("127.0.0.1:9".parse().unwrap())
        .build()
        .unwrap();
    let addr = server.listen_addr().unwrap();
    {
        let mut w = server.writer();
        w.insert_batch(&(1..=10_000).map(|i| i as f64 * 0.01).collect::<Vec<_>>());
        w.flush();
    }
    server.flush();
    let _ = server.step(); // seed the fresh epoch into the protocol state
    let gen = server.global_view().unwrap().generation();
    let initiator = peer(1, 10_000, 3);

    // Fresh connect per exchange (pool disabled, full frames): the
    // pre-PR 4 cost, ~1 RTT of connect on top of every push–pull.
    let fresh = TcpTransport::connect_only_with(opts(0, false)).unwrap();
    b.case("transport/tcp-fresh-connect items=10000", 1, || {
        let mut local = initiator.clone();
        black_box(
            fresh
                .exchange_remote(&mut local, gen, addr)
                .expect("loopback exchange"),
        );
    });

    // Pooled connection, full frames: connect paid once, then reused.
    let pooled = TcpTransport::connect_only_with(opts(2, false)).unwrap();
    {
        let mut warm = initiator.clone();
        pooled.exchange_remote(&mut warm, gen, addr).expect("pool warm-up");
    }
    b.case("transport/tcp-pooled items=10000", 1, || {
        let mut local = initiator.clone();
        black_box(
            pooled
                .exchange_remote(&mut local, gen, addr)
                .expect("loopback exchange"),
        );
    });

    // Pooled + delta on a near-converged pair: warm up once so both
    // sides share a baseline, then keep exchanging the already-averaged
    // state — each push/reply ships only the (empty) bucket diff.
    let delta = TcpTransport::connect_only_with(opts(2, true)).unwrap();
    let mut converged = initiator.clone();
    let full_bytes = delta
        .exchange_remote(&mut converged, gen, addr)
        .expect("baseline warm-up (full frames)");
    let delta_bytes = delta
        .exchange_remote(&mut converged.clone(), gen, addr)
        .expect("near-converged delta exchange");
    println!(
        "bench transport/bytes-on-wire full={full_bytes}B near-converged-delta={delta_bytes}B \
         ({}x smaller)",
        full_bytes / delta_bytes.max(1)
    );
    b.case("transport/tcp-pooled-delta items=10000", 1, || {
        let mut local = converged.clone();
        black_box(
            delta
                .exchange_remote(&mut local, gen, addr)
                .expect("loopback exchange"),
        );
    });

    let stats = pooled.pool_stats();
    println!(
        "bench transport/pool-stats reused={} fresh={} stale={} expired={}",
        stats.reused, stats.fresh_connects, stats.stale_discarded, stats.expired
    );

    server.shutdown();
    b.finish("transport_exchange");
}
