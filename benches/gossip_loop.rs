//! Per-round cost of the continuous service gossip loop: the refresh
//! check + fan-out exchange + per-member view publication, across fleet
//! sizes — the steady-state overhead a serving fleet pays per epoch tick.
//!
//! Also isolates the reseed path (new epoch → rebuild every PeerState
//! from snapshots), which bounds how fast the loop can track a
//! fast-epoching service.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{GossipLoopConfig, ServiceConfig};
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::rng::default_rng;
use duddsketch::service::{GossipLoop, GossipMember, QuantileService};
use duddsketch::util::bench::{black_box, Bencher};
use std::sync::Arc;

const ITEMS: usize = 20_000;

/// A fleet of one live service plus `nodes - 1` static peers, seeded and
/// ready to step.
fn fleet(nodes: usize, restart_free: bool) -> (GossipLoop, Arc<QuantileService>) {
    let master = default_rng(42);
    let mut cfg = ServiceConfig::default();
    cfg.shards = 2;
    let svc = QuantileService::start_shared(cfg).unwrap();
    let mut w = svc.writer();
    w.insert_batch(&peer_dataset(DatasetKind::Exponential, 0, ITEMS, &master));
    w.flush();
    svc.flush();
    let mut members = vec![GossipMember::service(svc.clone())];
    for i in 1..nodes {
        let data = peer_dataset(DatasetKind::Exponential, i, ITEMS, &master);
        members.push(GossipMember::from_dataset(&data, 0.001, 1024).unwrap());
    }
    let mut gcfg = GossipLoopConfig::default();
    gcfg.restart_free = restart_free;
    let gl = GossipLoop::start(gcfg, members).unwrap();
    (gl, svc)
}

fn main() {
    let mut b = Bencher::new();

    for nodes in [4usize, 16, 64] {
        let (gl, svc) = fleet(nodes, true);
        b.case(&format!("loop/steady-round nodes={nodes}"), nodes as u64, || {
            black_box(gl.step());
        });
        drop(gl);
        if let Ok(s) = Arc::try_unwrap(svc) {
            s.shutdown();
        }
    }

    // Reseed path: every case iteration publishes a fresh epoch first, so
    // each step pays the full snapshot → PeerState rebuild for the fleet.
    // Pinned to `restart_free = false` — under the restart-free default an
    // epoch advance is carried in place instead; the carry-vs-reseed A/B
    // lives in the `churn_cost` bench.
    for nodes in [4usize, 16] {
        let (gl, svc) = fleet(nodes, false);
        let mut w = svc.writer();
        b.case(&format!("loop/reseed-round nodes={nodes}"), nodes as u64, || {
            w.insert(1.0);
            w.flush();
            svc.flush();
            black_box(gl.step());
        });
        drop(w);
        drop(gl);
        if let Ok(s) = Arc::try_unwrap(svc) {
            s.shutdown();
        }
    }

    b.finish("gossip_loop");
}
