//! Experiment configuration: the paper's Table 2 parameters plus runtime
//! knobs, with a small `key=value` config-file parser and CLI overrides.

#![forbid(unsafe_code)]

use crate::churn::ChurnKind;
use crate::data::DatasetKind;
use std::net::SocketAddr;
use std::path::Path;

/// Overlay topology models of §7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    /// Barabási–Albert, 5 edges/vertex (the paper's reported plots).
    BarabasiAlbert,
    /// Erdős–Rényi, p = 10/n.
    ErdosRenyi,
    /// Watts–Strogatz small world (k=5, β=0.1) — topology ablation.
    WattsStrogatz,
    /// Ring lattice (k=5) — high-diameter worst case for the ablation.
    Ring,
    /// Complete graph — the natural overlay for small service fleets
    /// (every peer reachable in one hop; quadratic in edges, so only for
    /// small n).
    Complete,
}

impl GraphKind {
    /// CSV/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            GraphKind::BarabasiAlbert => "ba",
            GraphKind::ErdosRenyi => "er",
            GraphKind::WattsStrogatz => "ws",
            GraphKind::Ring => "ring",
            GraphKind::Complete => "complete",
        }
    }
}

impl std::str::FromStr for GraphKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ba" | "barabasi-albert" | "barabasialbert" => Ok(GraphKind::BarabasiAlbert),
            "er" | "erdos-renyi" | "erdosrenyi" => Ok(GraphKind::ErdosRenyi),
            "ws" | "watts-strogatz" | "smallworld" => Ok(GraphKind::WattsStrogatz),
            "ring" | "lattice" => Ok(GraphKind::Ring),
            "complete" | "full" => Ok(GraphKind::Complete),
            other => Err(format!(
                "unknown graph '{other}' (expected ba|er|ws|ring|complete)"
            )),
        }
    }
}

/// Which executor runs the averaging round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Pure-Rust averaging (reference path).
    Native,
    /// AOT-compiled XLA artifact on the PJRT CPU client.
    Pjrt,
}

impl std::str::FromStr for ExecutorKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(ExecutorKind::Native),
            "pjrt" | "xla" => Ok(ExecutorKind::Pjrt),
            other => Err(format!("unknown executor '{other}' (expected native|pjrt)")),
        }
    }
}

/// Full configuration of one distributed run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Input workload.
    pub dataset: DatasetKind,
    /// Network size `p`.
    pub peers: usize,
    /// Gossip rounds `R`.
    pub rounds: usize,
    /// Neighbours contacted per round (paper default 1).
    pub fan_out: usize,
    /// Sketch accuracy α (paper default 0.001).
    pub alpha: f64,
    /// Sketch budget m (paper default 1024).
    pub max_buckets: usize,
    /// Stream length per peer (paper default 100000).
    pub items_per_peer: usize,
    /// Overlay model.
    pub graph: GraphKind,
    /// Churn model (None reproduces §7.1).
    pub churn: ChurnKind,
    /// Master seed for data, topology and protocol randomness.
    pub seed: u64,
    /// Quantiles evaluated (paper Table 2 set).
    pub quantiles: Vec<f64>,
    /// Averaging-round executor.
    pub executor: ExecutorKind,
}

/// The paper's quantile set (Table 2).
pub const PAPER_QUANTILES: [f64; 11] = [
    0.01, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.99,
];

impl Default for ExperimentConfig {
    /// Scaled defaults: Table 2 parameters with a CI-friendly network
    /// (1000 peers) and stream length (2000 items/peer). Convergence
    /// behaviour per round is scale-free (Prop. 4); `paper_scale()`
    /// restores the full-size parameters.
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Adversarial,
            peers: 1000,
            rounds: 25,
            fan_out: 1,
            alpha: 0.001,
            max_buckets: 1024,
            items_per_peer: 2000,
            graph: GraphKind::BarabasiAlbert,
            churn: ChurnKind::None,
            seed: 42,
            quantiles: PAPER_QUANTILES.to_vec(),
            executor: ExecutorKind::Native,
        }
    }
}

impl ExperimentConfig {
    /// Table 2 exactly: 100000 items/peer.
    pub fn paper_scale(mut self) -> Self {
        self.items_per_peer = 100_000;
        self
    }

    /// Apply one `key=value` assignment.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_err = |k: &str, v: &str| format!("bad value '{v}' for key '{k}'");
        match key {
            "dataset" => self.dataset = value.parse()?,
            "peers" => self.peers = value.parse().map_err(|_| parse_err(key, value))?,
            "rounds" => self.rounds = value.parse().map_err(|_| parse_err(key, value))?,
            "fan_out" | "fanout" => {
                self.fan_out = value.parse().map_err(|_| parse_err(key, value))?
            }
            "alpha" => self.alpha = value.parse().map_err(|_| parse_err(key, value))?,
            "max_buckets" | "buckets" | "m" => {
                self.max_buckets = value.parse().map_err(|_| parse_err(key, value))?
            }
            "items_per_peer" | "items" => {
                self.items_per_peer = value.parse().map_err(|_| parse_err(key, value))?
            }
            "graph" => self.graph = value.parse()?,
            "churn" => self.churn = value.parse()?,
            "seed" => self.seed = value.parse().map_err(|_| parse_err(key, value))?,
            "executor" => self.executor = value.parse()?,
            "quantiles" => {
                let qs: Result<Vec<f64>, _> =
                    value.split(',').map(|s| s.trim().parse::<f64>()).collect();
                self.quantiles = qs.map_err(|_| parse_err(key, value))?;
            }
            other => return Err(format!("unknown config key '{other}'")),
        }
        Ok(())
    }

    /// Parse a config file of `key = value` lines (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let mut cfg = Self::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key=value", lineno + 1))?;
            cfg.set(key.trim(), value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<(), String> {
        if self.peers < 2 {
            return Err("peers must be >= 2".into());
        }
        if !(self.alpha > 0.0 && self.alpha < 1.0) {
            return Err(format!("alpha must be in (0,1), got {}", self.alpha));
        }
        if self.max_buckets < 2 {
            return Err("max_buckets must be >= 2".into());
        }
        if self.fan_out < 1 {
            return Err("fan_out must be >= 1".into());
        }
        if self.quantiles.iter().any(|q| !(0.0..=1.0).contains(q)) {
            return Err("quantiles must lie in [0,1]".into());
        }
        Ok(())
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "dataset={} peers={} rounds={} fan_out={} alpha={} m={} items/peer={} graph={:?} churn={:?} seed={} executor={:?}",
            self.dataset.name(),
            self.peers,
            self.rounds,
            self.fan_out,
            self.alpha,
            self.max_buckets,
            self.items_per_peer,
            self.graph,
            self.churn,
            self.seed,
            self.executor,
        )
    }
}

/// Configuration of the sharded ingest/snapshot service
/// ([`crate::service`]).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Sketch accuracy α (every shard shares one α₀ lineage so epoch
    /// folds merge exactly).
    pub alpha: f64,
    /// Bucket budget m per sketch.
    pub max_buckets: usize,
    /// Ingest shards (worker threads); must be ≥ 1. The default resolves
    /// to one per available core at construction, so a zero here is
    /// always an explicit mistake and is rejected by
    /// [`ServiceConfig::validate`] with a named-key error instead of
    /// surfacing as a downstream panic.
    pub shards: usize,
    /// Values per ingest message (writer-side batching).
    pub batch_size: usize,
    /// Bounded queue depth per shard, in batches (backpressure).
    pub queue_depth: usize,
    /// Background epoch interval in milliseconds; 0 disables the ticker
    /// (epochs then run only via `QuantileService::flush`).
    pub epoch_interval_ms: u64,
    /// Sliding-window ring slots, one epoch interval each; 0 serves the
    /// cumulative all-time sketch instead.
    pub window_slots: usize,
    /// Continuous gossip-loop knobs (used when the service fronts a
    /// [`GossipLoop`](crate::service::GossipLoop)).
    pub gossip: GossipLoopConfig,
    /// Address the node's Prometheus `/metrics` endpoint listens on;
    /// `None` (the default) runs no HTTP listener. Port 0 binds an
    /// ephemeral port (query it via
    /// [`Node::metrics_addr`](crate::service::Node::metrics_addr)).
    pub metrics_bind: Option<SocketAddr>,
    /// Path of the structured event log (JSONL, `docs/OBSERVABILITY.md`):
    /// one line per gossip round, exchange span, and membership change.
    /// `None` (the default) disables export. The sink is bounded and
    /// non-blocking — when the writer lags, events are dropped and
    /// counted in `dudd_events_dropped_total`, never stalling a round.
    pub obs_event_log: Option<std::path::PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            alpha: 0.001,
            max_buckets: 1024,
            shards: std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1),
            batch_size: 1024,
            queue_depth: 64,
            epoch_interval_ms: 0,
            window_slots: 0,
            gossip: GossipLoopConfig::default(),
            metrics_bind: None,
            obs_event_log: None,
        }
    }
}

impl ServiceConfig {
    /// Apply one `key=value` assignment (CLI overrides).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_err = |k: &str, v: &str| format!("bad value '{v}' for key '{k}'");
        match key {
            "alpha" => self.alpha = value.parse().map_err(|_| parse_err(key, value))?,
            "max_buckets" | "buckets" | "m" => {
                self.max_buckets = value.parse().map_err(|_| parse_err(key, value))?
            }
            "shards" => self.shards = value.parse().map_err(|_| parse_err(key, value))?,
            "batch_size" | "batch" => {
                self.batch_size = value.parse().map_err(|_| parse_err(key, value))?
            }
            "queue_depth" | "queue" => {
                self.queue_depth = value.parse().map_err(|_| parse_err(key, value))?
            }
            "epoch_interval_ms" | "epoch_ms" => {
                self.epoch_interval_ms =
                    value.parse().map_err(|_| parse_err(key, value))?
            }
            "window_slots" | "window" => {
                self.window_slots = value.parse().map_err(|_| parse_err(key, value))?
            }
            "metrics_bind" | "metrics" => {
                self.metrics_bind = match value {
                    "" | "none" | "off" => None,
                    addr => Some(addr.parse().map_err(|_| parse_err(key, value))?),
                }
            }
            "obs_event_log" | "event_log" => {
                self.obs_event_log = match value {
                    "" | "none" | "off" => None,
                    path => Some(std::path::PathBuf::from(path)),
                }
            }
            _ if key.starts_with("gossip_") => {
                self.gossip.set(&key["gossip_".len()..], value)?
            }
            other => return Err(format!("unknown service config key '{other}'")),
        }
        Ok(())
    }

    /// Validate every knob at construction time, naming the offending key
    /// — a bad value must fail here, not as a panic deep in a shard or
    /// exchange thread.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.alpha > 0.0 && self.alpha < 1.0) {
            // The range check also rejects NaN/±inf: no non-finite alpha
            // satisfies 0 < alpha < 1.
            return Err(format!("alpha must be in (0,1), got {}", self.alpha));
        }
        if self.max_buckets < 2 {
            return Err("max_buckets must be >= 2".into());
        }
        if self.shards < 1 {
            return Err("shards must be >= 1 (one ingest worker per shard)".into());
        }
        if self.batch_size < 1 {
            return Err("batch_size must be >= 1".into());
        }
        if self.queue_depth < 1 {
            return Err("queue_depth must be >= 1".into());
        }
        self.gossip.validate()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "alpha={} m={} shards={} batch={} queue={} epoch_ms={} window={} metrics={} event_log={}",
            self.alpha,
            self.max_buckets,
            self.shards,
            self.batch_size,
            self.queue_depth,
            self.epoch_interval_ms,
            self.window_slots,
            self.metrics_bind
                .map_or_else(|| "off".to_string(), |a| a.to_string()),
            self.obs_event_log
                .as_ref()
                .map_or_else(|| "off".to_string(), |p| p.display().to_string()),
        )
    }
}

/// Configuration of the continuous service-driven gossip loop
/// ([`crate::service::GossipLoop`]): the refresh → exchange → serve cycle
/// that keeps a fleet of ingest services converged on one global view.
#[derive(Debug, Clone)]
pub struct GossipLoopConfig {
    /// Background round interval in milliseconds; 0 disables the loop
    /// thread (rounds then run only via `GossipLoop::step`).
    pub round_interval_ms: u64,
    /// Neighbours each peer contacts per round (paper default 1).
    pub fan_out: usize,
    /// Overlay connecting the loop's members. Service fleets are small,
    /// so the default is [`GraphKind::Complete`]; the simulation
    /// topologies work too.
    pub graph: GraphKind,
    /// Convergence threshold: the loop reports converged once the
    /// largest relative drift of the probe-quantile estimates between
    /// consecutive rounds falls to this value or below.
    pub convergence_rel: f64,
    /// Quantiles probed for the drift metric.
    pub probe_quantiles: Vec<f64>,
    /// Seed for overlay generation and exchange-partner randomness.
    /// Remote fleets must share one seed (and one graph kind) so every
    /// node builds the same overlay.
    pub seed: u64,
    /// Per-exchange transport deadline in milliseconds (connect, read,
    /// and write individually), used by remote transports such as
    /// [`TcpTransport`](crate::service::TcpTransport). An exchange that
    /// misses the deadline is cancelled: both sides keep their pre-round
    /// state (§7.2) and the failure is counted in
    /// [`GossipRoundReport::failed`](crate::service::GossipRoundReport).
    /// Must be ≥ 1 — a zero deadline would fail every exchange.
    pub exchange_deadline_ms: u64,
    /// Idle TCP connections kept per remote peer for reuse; 0 disables
    /// pooling (every exchange pays a fresh connect — roughly one extra
    /// RTT on the hot path).
    pub pool_connections: usize,
    /// Pooled connections idle longer than this many milliseconds are
    /// discarded at checkout (and the serve side evicts its half on the
    /// same clock). Must be ≥ 1.
    pub pool_idle_ms: u64,
    /// Ship delta exchange frames (changed buckets against the
    /// per-(peer, generation) baseline of the pair's last completed
    /// exchange) instead of full ~16 KiB states when possible. Always
    /// falls back to full frames automatically on a baseline mismatch;
    /// see `docs/PROTOCOL.md`.
    pub delta_exchanges: bool,
    /// Seed addresses for the **dynamic membership** plane
    /// (`docs/PROTOCOL.md` §9): non-empty means the node joins a running
    /// fleet by asking each seed in turn for a `dudd-join` handshake
    /// instead of listing a static member order. An empty list with
    /// membership bootstrapped makes this node the fleet's first member
    /// (id 0).
    pub seed_peers: Vec<SocketAddr>,
    /// Membership suspicion interval in milliseconds: a member whose
    /// exchange-failure streak outlives this turns *suspect* (connect
    /// attempts back off exponentially), and after another such interval
    /// *dead* (a protocol restart re-anchors the mass on the
    /// survivors). Must be ≥ 1.
    pub suspect_after_ms: u64,
    /// Tombstone TTL in milliseconds: dead entries are kept (and keep
    /// spreading, so nobody resurrects the member) this long after the
    /// local node observed the death, then garbage-collected. Keep it
    /// well above the fleet's anti-entropy spread time. Must be ≥ 1.
    pub tombstone_ttl_ms: u64,
    /// Restart-free churn and epochs (`docs/PROTOCOL.md` §10): joins
    /// and incarnation advances are admitted into the **current**
    /// restart generation (a joiner enters with `q̃ = 0`, which is
    /// mass-conserving by construction), additive epoch advances are
    /// folded in as a carry delta instead of a reseed, and delta
    /// baselines survive generation bumps (fingerprint-authenticated
    /// baseline carry). Only dead ↔ non-dead flips of the member set
    /// still re-anchor the generation. `false` restores the PR 5
    /// bump-on-every-view-change behaviour (the A/B arm of the churn
    /// bench).
    pub restart_free: bool,
}

impl Default for GossipLoopConfig {
    fn default() -> Self {
        Self {
            round_interval_ms: 0,
            fan_out: 1,
            graph: GraphKind::Complete,
            convergence_rel: 1e-9,
            probe_quantiles: vec![0.5, 0.9, 0.99],
            seed: 42,
            exchange_deadline_ms: 1_000,
            pool_connections: 2,
            pool_idle_ms: 30_000,
            delta_exchanges: true,
            seed_peers: Vec::new(),
            suspect_after_ms: 5_000,
            tombstone_ttl_ms: 60_000,
            restart_free: true,
        }
    }
}

impl GossipLoopConfig {
    /// Apply one `key=value` assignment (keys as in `serve-gossip`
    /// overrides, without the `gossip_` prefix).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_err = |k: &str, v: &str| format!("bad value '{v}' for gossip key '{k}'");
        match key {
            "round_interval_ms" | "ms" => {
                self.round_interval_ms =
                    value.parse().map_err(|_| parse_err(key, value))?
            }
            "fan_out" | "fanout" => {
                self.fan_out = value.parse().map_err(|_| parse_err(key, value))?
            }
            "graph" => self.graph = value.parse()?,
            "convergence_rel" | "drift" => {
                self.convergence_rel =
                    value.parse().map_err(|_| parse_err(key, value))?
            }
            "probes" | "probe_quantiles" => {
                let qs: Result<Vec<f64>, _> =
                    value.split(',').map(|s| s.trim().parse::<f64>()).collect();
                self.probe_quantiles = qs.map_err(|_| parse_err(key, value))?;
            }
            "seed" => self.seed = value.parse().map_err(|_| parse_err(key, value))?,
            "exchange_deadline_ms" | "deadline_ms" | "deadline" => {
                self.exchange_deadline_ms =
                    value.parse().map_err(|_| parse_err(key, value))?
            }
            "pool_connections" | "pool" => {
                self.pool_connections = value.parse().map_err(|_| parse_err(key, value))?
            }
            "pool_idle_ms" | "pool_idle" => {
                self.pool_idle_ms = value.parse().map_err(|_| parse_err(key, value))?
            }
            "delta_exchanges" | "delta" => {
                self.delta_exchanges = parse_bool(value).ok_or_else(|| parse_err(key, value))?
            }
            "seed_peers" | "seeds" => {
                let addrs: Result<Vec<SocketAddr>, _> = value
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::parse)
                    .collect();
                self.seed_peers = addrs.map_err(|_| parse_err(key, value))?;
            }
            "suspect_after_ms" | "suspect_after" => {
                self.suspect_after_ms = value.parse().map_err(|_| parse_err(key, value))?
            }
            "tombstone_ttl_ms" | "tombstone_ttl" => {
                self.tombstone_ttl_ms = value.parse().map_err(|_| parse_err(key, value))?
            }
            "restart_free" => {
                self.restart_free = parse_bool(value).ok_or_else(|| parse_err(key, value))?
            }
            other => return Err(format!("unknown gossip config key '{other}'")),
        }
        Ok(())
    }

    /// Validate every knob at construction time, naming the offending
    /// key (`gossip_`-prefixed, as on the CLI).
    pub fn validate(&self) -> Result<(), String> {
        if self.fan_out < 1 {
            return Err("gossip_fan_out must be >= 1".into());
        }
        if self.convergence_rel.is_nan() || self.convergence_rel < 0.0 {
            return Err(format!(
                "gossip_convergence_rel must be >= 0, got {}",
                self.convergence_rel
            ));
        }
        if self.probe_quantiles.is_empty() {
            return Err("gossip_probe_quantiles must be non-empty".into());
        }
        if self.probe_quantiles.iter().any(|q| !(0.0..=1.0).contains(q)) {
            return Err("gossip_probe_quantiles must lie in [0,1]".into());
        }
        if self.exchange_deadline_ms < 1 {
            return Err(
                "gossip_exchange_deadline_ms must be >= 1 (a zero deadline \
                 cancels every remote exchange)"
                    .into(),
            );
        }
        if self.pool_idle_ms < 1 {
            return Err(
                "gossip_pool_idle_ms must be >= 1 (a zero idle timeout \
                 discards every pooled connection)"
                    .into(),
            );
        }
        if self.suspect_after_ms < 1 {
            return Err(
                "gossip_suspect_after_ms must be >= 1 (a zero suspicion \
                 interval declares every member dead on its first failure)"
                    .into(),
            );
        }
        if self.tombstone_ttl_ms < 1 {
            return Err(
                "gossip_tombstone_ttl_ms must be >= 1 (a zero TTL collects \
                 tombstones before they can spread)"
                    .into(),
            );
        }
        Ok(())
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "round_ms={} fan_out={} graph={} drift<={:e} probes={:?} seed={} deadline_ms={} \
             pool={} pool_idle_ms={} delta={} seeds={} suspect_after_ms={} tombstone_ttl_ms={} \
             restart_free={}",
            self.round_interval_ms,
            self.fan_out,
            self.graph.name(),
            self.convergence_rel,
            self.probe_quantiles,
            self.seed,
            self.exchange_deadline_ms,
            self.pool_connections,
            self.pool_idle_ms,
            self.delta_exchanges,
            self.seed_peers.len(),
            self.suspect_after_ms,
            self.tombstone_ttl_ms,
            self.restart_free,
        )
    }
}

/// Parse a boolean config value (`true/false`, `1/0`, `on/off`,
/// `yes/no`).
fn parse_bool(value: &str) -> Option<bool> {
    match value.to_ascii_lowercase().as_str() {
        "true" | "1" | "on" | "yes" => Some(true),
        "false" | "0" | "off" | "no" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let c = ExperimentConfig::default();
        assert_eq!(c.alpha, 0.001);
        assert_eq!(c.max_buckets, 1024);
        assert_eq!(c.fan_out, 1);
        assert_eq!(c.quantiles.len(), 11);
        c.validate().unwrap();
        assert_eq!(c.paper_scale().items_per_peer, 100_000);
    }

    #[test]
    fn set_and_parse_values() {
        let mut c = ExperimentConfig::default();
        c.set("dataset", "normal").unwrap();
        c.set("peers", "5000").unwrap();
        c.set("graph", "er").unwrap();
        c.set("churn", "failstop").unwrap();
        c.set("executor", "pjrt").unwrap();
        c.set("quantiles", "0.5, 0.9").unwrap();
        assert_eq!(c.dataset, DatasetKind::Normal);
        assert_eq!(c.peers, 5000);
        assert_eq!(c.graph, GraphKind::ErdosRenyi);
        assert_eq!(c.executor, ExecutorKind::Pjrt);
        assert_eq!(c.quantiles, vec![0.5, 0.9]);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("peers", "not-a-number").is_err());
    }

    #[test]
    fn config_file_round_trip() {
        let dir = std::env::temp_dir().join("duddsketch_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.cfg");
        std::fs::write(
            &path,
            "# paper fig-3 style\ndataset = exponential\npeers = 500\nrounds=10 # trailing comment\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(c.dataset, DatasetKind::Exponential);
        assert_eq!(c.peers, 500);
        assert_eq!(c.rounds, 10);
    }

    #[test]
    fn service_config_defaults_validate() {
        let c = ServiceConfig::default();
        c.validate().unwrap();
        assert!(c.shards >= 1, "default shards resolve to the core count");
        assert!(c.summary().contains("shards="));
    }

    #[test]
    fn validation_names_the_offending_key() {
        // Satellite (ISSUE 3): bad knobs fail at construction with the
        // key named, never as a downstream panic.
        let mut c = ServiceConfig::default();
        c.shards = 0;
        assert!(c.validate().unwrap_err().contains("shards"));

        let mut c = ServiceConfig::default();
        c.alpha = f64::NAN;
        assert!(c.validate().unwrap_err().contains("alpha"));
        c.alpha = f64::INFINITY;
        assert!(c.validate().unwrap_err().contains("alpha"));

        let mut c = ServiceConfig::default();
        c.gossip.fan_out = 0;
        assert!(c.validate().unwrap_err().contains("gossip_fan_out"));

        let mut c = ServiceConfig::default();
        c.gossip.exchange_deadline_ms = 0;
        assert!(c
            .validate()
            .unwrap_err()
            .contains("gossip_exchange_deadline_ms"));
    }

    #[test]
    fn service_config_set_and_validate() {
        let mut c = ServiceConfig::default();
        c.set("shards", "4").unwrap();
        c.set("batch", "512").unwrap();
        c.set("window", "8").unwrap();
        c.set("epoch_ms", "250").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.batch_size, 512);
        assert_eq!(c.window_slots, 8);
        assert_eq!(c.epoch_interval_ms, 250);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("shards", "many").is_err());
        c.batch_size = 0;
        assert!(c.validate().is_err());
        c.batch_size = 1;
        c.alpha = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn gossip_config_set_and_validate() {
        let mut c = ServiceConfig::default();
        c.set("gossip_ms", "25").unwrap();
        c.set("gossip_fanout", "2").unwrap();
        c.set("gossip_graph", "complete").unwrap();
        c.set("gossip_drift", "1e-6").unwrap();
        c.set("gossip_probes", "0.5, 0.99").unwrap();
        c.set("gossip_seed", "7").unwrap();
        c.set("gossip_deadline_ms", "250").unwrap();
        assert_eq!(c.gossip.round_interval_ms, 25);
        assert_eq!(c.gossip.fan_out, 2);
        assert_eq!(c.gossip.graph, GraphKind::Complete);
        assert_eq!(c.gossip.convergence_rel, 1e-6);
        assert_eq!(c.gossip.probe_quantiles, vec![0.5, 0.99]);
        assert_eq!(c.gossip.seed, 7);
        assert_eq!(c.gossip.exchange_deadline_ms, 250);
        c.validate().unwrap();
        assert!(c.set("gossip_bogus", "1").is_err());

        let mut g = GossipLoopConfig::default();
        g.fan_out = 0;
        assert!(g.validate().is_err());
        let mut g = GossipLoopConfig::default();
        g.probe_quantiles = vec![1.5];
        assert!(g.validate().is_err());
        let mut g = GossipLoopConfig::default();
        g.probe_quantiles.clear();
        assert!(g.validate().is_err());
        assert!(GossipLoopConfig::default().summary().contains("fan_out=1"));
    }

    #[test]
    fn gossip_transport_keys_set_and_validate() {
        let mut c = ServiceConfig::default();
        c.set("gossip_pool_connections", "4").unwrap();
        c.set("gossip_pool_idle_ms", "500").unwrap();
        c.set("gossip_delta_exchanges", "off").unwrap();
        assert_eq!(c.gossip.pool_connections, 4);
        assert_eq!(c.gossip.pool_idle_ms, 500);
        assert!(!c.gossip.delta_exchanges);
        c.set("gossip_delta", "1").unwrap();
        assert!(c.gossip.delta_exchanges);
        c.set("gossip_pool", "0").unwrap();
        assert_eq!(c.gossip.pool_connections, 0);
        c.validate().unwrap();

        assert!(c.set("gossip_delta", "maybe").is_err());
        let mut g = GossipLoopConfig::default();
        g.pool_idle_ms = 0;
        assert!(g
            .validate()
            .unwrap_err()
            .contains("gossip_pool_idle_ms"));
        let s = GossipLoopConfig::default().summary();
        assert!(s.contains("pool=2"), "{s}");
        assert!(s.contains("delta=true"), "{s}");
    }

    #[test]
    fn gossip_membership_keys_set_and_validate() {
        let mut c = ServiceConfig::default();
        c.set("gossip_seed_peers", "10.0.0.1:7400, 10.0.0.2:7400").unwrap();
        c.set("gossip_suspect_after_ms", "750").unwrap();
        c.set("gossip_tombstone_ttl_ms", "90000").unwrap();
        assert_eq!(c.gossip.seed_peers.len(), 2);
        assert_eq!(c.gossip.seed_peers[0], "10.0.0.1:7400".parse().unwrap());
        assert_eq!(c.gossip.suspect_after_ms, 750);
        assert_eq!(c.gossip.tombstone_ttl_ms, 90000);
        c.validate().unwrap();

        assert!(c.set("gossip_seed_peers", "not-an-addr").is_err());
        let mut g = GossipLoopConfig::default();
        g.suspect_after_ms = 0;
        assert!(g
            .validate()
            .unwrap_err()
            .contains("gossip_suspect_after_ms"));
        let mut g = GossipLoopConfig::default();
        g.tombstone_ttl_ms = 0;
        assert!(g
            .validate()
            .unwrap_err()
            .contains("gossip_tombstone_ttl_ms"));
        let s = GossipLoopConfig::default().summary();
        assert!(s.contains("suspect_after_ms=5000"), "{s}");
        assert!(s.contains("tombstone_ttl_ms=60000"), "{s}");
    }

    #[test]
    fn gossip_restart_free_key_sets_and_defaults_on() {
        let mut c = ServiceConfig::default();
        assert!(c.gossip.restart_free, "restart-free churn is the default");
        c.set("gossip_restart_free", "off").unwrap();
        assert!(!c.gossip.restart_free);
        c.set("gossip_restart_free", "1").unwrap();
        assert!(c.gossip.restart_free);
        assert!(c.set("gossip_restart_free", "maybe").is_err());
        let s = GossipLoopConfig::default().summary();
        assert!(s.contains("restart_free=true"), "{s}");
    }

    #[test]
    fn metrics_bind_key_sets_clears_and_rejects() {
        let mut c = ServiceConfig::default();
        assert!(c.metrics_bind.is_none(), "off by default");
        assert!(c.summary().contains("metrics=off"));

        c.set("metrics_bind", "127.0.0.1:9464").unwrap();
        assert_eq!(c.metrics_bind, Some("127.0.0.1:9464".parse().unwrap()));
        assert!(c.summary().contains("metrics=127.0.0.1:9464"));
        c.validate().unwrap();

        // `none`/`off` (and the `metrics` alias) clear it again.
        c.set("metrics", "off").unwrap();
        assert!(c.metrics_bind.is_none());
        c.set("metrics_bind", "0.0.0.0:0").unwrap();
        c.set("metrics_bind", "none").unwrap();
        assert!(c.metrics_bind.is_none());

        assert!(c.set("metrics_bind", "not-an-addr").is_err());
    }

    #[test]
    fn obs_event_log_key_sets_clears_and_rides_summary() {
        let mut c = ServiceConfig::default();
        assert!(c.obs_event_log.is_none(), "off by default");
        assert!(c.summary().contains("event_log=off"));

        c.set("obs_event_log", "/tmp/dudd-events.jsonl").unwrap();
        assert_eq!(
            c.obs_event_log.as_deref(),
            Some(std::path::Path::new("/tmp/dudd-events.jsonl"))
        );
        assert!(c.summary().contains("event_log=/tmp/dudd-events.jsonl"));
        c.validate().unwrap();

        // `none`/`off` (and the `event_log` alias) clear it again.
        c.set("event_log", "off").unwrap();
        assert!(c.obs_event_log.is_none());
        c.set("obs_event_log", "logs/a.jsonl").unwrap();
        c.set("obs_event_log", "none").unwrap();
        assert!(c.obs_event_log.is_none());
    }

    #[test]
    fn graph_kind_complete_parses() {
        assert_eq!("complete".parse::<GraphKind>().unwrap(), GraphKind::Complete);
        assert_eq!("full".parse::<GraphKind>().unwrap(), GraphKind::Complete);
        assert_eq!(GraphKind::Complete.name(), "complete");
    }

    #[test]
    fn validation_rejects_bad_ranges() {
        let mut c = ExperimentConfig::default();
        c.peers = 1;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.quantiles = vec![1.2];
        assert!(c.validate().is_err());
    }
}
