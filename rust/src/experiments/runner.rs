//! One distributed run with per-round-count measurement snapshots.

use crate::config::ExperimentConfig;
use crate::data::all_peer_datasets;
use crate::gossip::Protocol;
use crate::graph::Graph;
use crate::metrics::{average_relative_error, relative_error, BoxSummary};
use crate::rng::default_rng;
use crate::sketch::UddSketch;
use crate::util::Stopwatch;
use anyhow::{Context, Result};

/// Per-quantile measurement at one snapshot.
#[derive(Debug, Clone)]
pub struct QuantileSnapshot {
    /// The quantile q.
    pub q: f64,
    /// The sequential algorithm's estimate `x̂_q` (the comparison target,
    /// exactly as in §7: distributed vs sequential, not vs exact).
    pub truth: f64,
    /// Average Relative Error across online peers (Eq. 10).
    pub are: f64,
    /// Distribution of per-peer relative errors (the paper's boxes).
    pub box_summary: BoxSummary,
}

/// Measurements after a given number of rounds.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Rounds executed when measured.
    pub rounds: usize,
    /// Peers online at measurement time.
    pub online: usize,
    /// Per-quantile errors.
    pub quantiles: Vec<QuantileSnapshot>,
}

/// A full run: configuration + snapshots + timing.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// The configuration executed.
    pub cfg: ExperimentConfig,
    /// One entry per requested snapshot round count (ascending).
    pub snapshots: Vec<Snapshot>,
    /// Error bound α of the sequential reference after its collapses.
    pub seq_alpha: f64,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Total completed push–pull exchanges across all rounds.
    pub exchanges: usize,
    /// Total wire traffic in bytes (codec-exact, push + pull frames).
    pub bytes: usize,
}

/// Build the overlay prescribed by the config.
pub fn build_graph(cfg: &ExperimentConfig, master: &crate::rng::Xoshiro256pp) -> Graph {
    let mut grng = master.derive(0x6EA4);
    crate::graph::from_kind(cfg.graph, cfg.peers, &mut grng)
}

/// Run the distributed protocol, measuring at each round count in
/// `snapshot_rounds` (ascending; deduplicated). The protocol instance is
/// shared across snapshots — exactly like observing one execution at
/// several times, which is what the paper's per-round plots depict.
pub fn run_with_snapshots(
    cfg: &ExperimentConfig,
    snapshot_rounds: &[usize],
) -> Result<RunOutcome> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    let sw = Stopwatch::start();
    let master = default_rng(cfg.seed);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);

    // Sequential reference over the union of the local streams.
    let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets)
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    for d in &datasets {
        seq.extend(d);
    }

    let graph = build_graph(cfg, &master);
    let mut proto = Protocol::new(cfg, graph, &datasets, &master)
        .context("initializing protocol")?;

    let mut points: Vec<usize> = snapshot_rounds.to_vec();
    points.sort_unstable();
    points.dedup();

    let mut snapshots = Vec::with_capacity(points.len());
    for &target in &points {
        let todo = target.saturating_sub(proto.round());
        proto.run(todo);
        snapshots.push(measure(&proto, &seq, &cfg.quantiles));
    }

    Ok(RunOutcome {
        cfg: cfg.clone(),
        snapshots,
        seq_alpha: seq.alpha(),
        wall_s: sw.secs(),
        exchanges: proto.history().iter().map(|h| h.exchanges).sum(),
        bytes: proto.history().iter().map(|h| h.bytes).sum(),
    })
}

/// Measure the current protocol state against the sequential reference.
fn measure(proto: &Protocol, seq: &UddSketch, quantiles: &[f64]) -> Snapshot {
    let p = proto.states().len();
    let online: Vec<usize> = (0..p).filter(|&l| proto.is_online(l)).collect();
    let quantile_snaps = quantiles
        .iter()
        .map(|&q| {
            let truth = seq.quantile(q).expect("non-empty sequential sketch");
            let errors: Vec<f64> = online
                .iter()
                .map(|&l| {
                    let est = proto.states()[l].query(q).expect("valid query");
                    relative_error(est, truth)
                })
                .collect();
            let estimates: Vec<f64> = online
                .iter()
                .map(|&l| proto.states()[l].query(q).expect("valid query"))
                .collect();
            QuantileSnapshot {
                q,
                truth,
                are: average_relative_error(&estimates, truth),
                box_summary: BoxSummary::from_data(&errors)
                    .unwrap_or(BoxSummary {
                        whisker_lo: 0.0,
                        q1: 0.0,
                        median: 0.0,
                        q3: 0.0,
                        whisker_hi: 0.0,
                        min: 0.0,
                        max: 0.0,
                        outliers: 0,
                    }),
            }
        })
        .collect();
    Snapshot {
        rounds: proto.round(),
        online: online.len(),
        quantiles: quantile_snaps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GraphKind;
    use crate::data::DatasetKind;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.peers = 48;
        cfg.items_per_peer = 200;
        cfg.dataset = DatasetKind::Exponential;
        cfg.quantiles = vec![0.1, 0.5, 0.9];
        cfg
    }

    #[test]
    fn snapshots_are_measured_at_requested_rounds() {
        let cfg = tiny_cfg();
        let out = run_with_snapshots(&cfg, &[2, 5, 10]).unwrap();
        let rounds: Vec<usize> = out.snapshots.iter().map(|s| s.rounds).collect();
        assert_eq!(rounds, vec![2, 5, 10]);
        assert_eq!(out.snapshots[0].quantiles.len(), 3);
        assert!(out.wall_s > 0.0);
    }

    #[test]
    fn errors_decrease_with_rounds() {
        let cfg = tiny_cfg();
        let out = run_with_snapshots(&cfg, &[1, 20]).unwrap();
        let are_early: f64 = out.snapshots[0].quantiles.iter().map(|q| q.are).sum();
        let are_late: f64 = out.snapshots[1].quantiles.iter().map(|q| q.are).sum();
        assert!(
            are_late <= are_early,
            "ARE should not grow: {are_early} -> {are_late}"
        );
        assert!(are_late < 1e-3, "late total ARE {are_late}");
    }

    #[test]
    fn er_graph_variant_runs() {
        let mut cfg = tiny_cfg();
        cfg.graph = GraphKind::ErdosRenyi;
        let out = run_with_snapshots(&cfg, &[5]).unwrap();
        assert_eq!(out.snapshots.len(), 1);
    }

    #[test]
    fn duplicate_snapshot_rounds_deduped() {
        let cfg = tiny_cfg();
        let out = run_with_snapshots(&cfg, &[3, 3, 3]).unwrap();
        assert_eq!(out.snapshots.len(), 1);
    }
}
