//! Evaluation harness: re-runs every experiment behind the paper's tables
//! and figures (§7) and emits the same rows/series (CSV + ASCII box
//! plots).

#![forbid(unsafe_code)]

mod figures;
mod runner;

pub use figures::{figure_ids, run_figure, FigureReport};
pub use runner::{run_with_snapshots, QuantileSnapshot, RunOutcome, Snapshot};
