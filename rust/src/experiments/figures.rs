//! Figure/table registry: one regeneration entry per paper artifact.
//!
//! Every entry produces (a) a CSV with the measured series and (b) an
//! ASCII rendering of the figure's panels. The default profile is scaled
//! down (smaller networks / streams) so the full suite runs in minutes;
//! `paper_scale = true` restores the exact Table 2 / §7 parameters.
//! Convergence behaviour per round is scale-free (Prop. 4), so the scaled
//! profile preserves the figures' *shape* (see EXPERIMENTS.md).

use super::runner::{run_with_snapshots, RunOutcome};
use crate::churn::ChurnKind;
use crate::config::ExperimentConfig;
use crate::data::{peer_dataset, DatasetKind};
use crate::metrics::BoxSummary;
use crate::rng::default_rng;
use crate::util::csv::CsvWriter;
use crate::util::plot::{render_boxes, BoxRow};
use anyhow::{bail, Result};
use std::path::Path;

/// Report from regenerating one figure/table.
#[derive(Debug)]
pub struct FigureReport {
    /// Figure id (e.g. "fig3").
    pub id: String,
    /// Human-readable rendering (panels of box plots / table rows).
    pub text: String,
    /// Path of the CSV written (empty for pure tables printed inline).
    pub csv_path: String,
}

/// All regenerable ids, in paper order.
pub fn figure_ids() -> Vec<&'static str> {
    vec![
        "table1", "table2", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6",
        "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
        // Ablations beyond the paper's panels (DESIGN.md §4):
        "abl_topology", "abl_fanout",
    ]
}

/// Scale a paper network size down for the default profile.
fn scale_peers(paper_peers: usize, paper_scale: bool) -> usize {
    if paper_scale {
        paper_peers
    } else {
        // 1/5 of the paper's sizes (floor 200) keeps ≥2 disjoint
        // adversarial groups (group = 100 peers) and the BA/ER regimes
        // intact while fitting CI budgets.
        (paper_peers / 5).max(200)
    }
}

fn items_per_peer(paper_scale: bool) -> usize {
    if paper_scale {
        100_000
    } else {
        2_000
    }
}

/// One experiment panel: label + config + snapshot rounds.
struct Panel {
    label: String,
    cfg: ExperimentConfig,
    rounds: Vec<usize>,
}

fn base_cfg(dataset: DatasetKind, peers: usize, paper_scale: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = dataset;
    cfg.peers = peers;
    cfg.items_per_peer = items_per_peer(paper_scale);
    cfg
}

fn convergence_panels(
    datasets: &[DatasetKind],
    paper_peers: &[usize],
    rounds: &[usize],
    churn: ChurnKind,
    paper_scale: bool,
) -> Vec<Panel> {
    let mut panels = Vec::new();
    for &d in datasets {
        for &pp in paper_peers {
            let peers = scale_peers(pp, paper_scale);
            let mut cfg = base_cfg(d, peers, paper_scale);
            cfg.churn = churn;
            let churn_tag = match churn {
                ChurnKind::None => String::new(),
                c => format!(" churn={}", c.name()),
            };
            panels.push(Panel {
                label: format!(
                    "{} P={pp}{churn_tag}{}",
                    d.name(),
                    if paper_scale { "" } else { " (scaled)" }
                ),
                cfg,
                rounds: rounds.to_vec(),
            });
        }
    }
    panels
}

fn panels_for(id: &str, paper_scale: bool) -> Result<Vec<Panel>> {
    use ChurnKind::*;
    use DatasetKind::*;
    let p = |v: &[usize]| v.to_vec();
    Ok(match id {
        // Figs 1–2: adversarial input, R ∈ {10,15,20,25}, four sizes.
        "fig1" => convergence_panels(&[Adversarial], &[1000, 5000], &p(&[10, 15, 20, 25]), None, paper_scale),
        "fig2" => convergence_panels(&[Adversarial], &[10000, 15000], &p(&[10, 15, 20, 25]), None, paper_scale),
        // Figs 3–4: smooth inputs converge by 10 rounds.
        "fig3" => convergence_panels(&[Exponential, Normal, Uniform], &[10000], &p(&[5, 10]), None, paper_scale),
        "fig4" => convergence_panels(&[Exponential, Normal, Uniform], &[15000], &p(&[5, 10]), None, paper_scale),
        // Figs 5–6: Fail & Stop churn (p=0.01), P=10000.
        "fig5" => convergence_panels(&[Adversarial, Uniform], &[10000], &p(&[5, 10, 15, 20, 25]), FailStop, paper_scale),
        "fig6" => convergence_panels(&[Exponential, Normal], &[10000], &p(&[5, 10, 15, 20, 25]), FailStop, paper_scale),
        // Figs 7–8: Yao (shifted-Pareto rejoin).
        "fig7" => convergence_panels(&[Adversarial, Uniform], &[10000], &p(&[5, 10, 15, 20, 25]), YaoPareto, paper_scale),
        "fig8" => convergence_panels(&[Exponential, Normal], &[10000], &p(&[5, 10, 15, 20, 25]), YaoPareto, paper_scale),
        // Figs 9–10: Yao exponential rejoin.
        "fig9" => convergence_panels(&[Adversarial, Uniform], &[10000], &p(&[5, 10, 15, 20, 25]), YaoExponential, paper_scale),
        "fig10" => convergence_panels(&[Exponential, Normal], &[10000], &p(&[5, 10, 15, 20, 25]), YaoExponential, paper_scale),
        // Figs 11–12: the power dataset, all four churn settings.
        "fig11" => {
            let mut v = convergence_panels(&[Power], &[10000], &p(&[5, 10, 15, 20, 25]), None, paper_scale);
            v.extend(convergence_panels(&[Power], &[10000], &p(&[5, 10, 15, 20, 25]), FailStop, paper_scale));
            v
        }
        "fig12" => {
            let mut v = convergence_panels(&[Power], &[10000], &p(&[5, 10, 15, 20, 25]), YaoPareto, paper_scale);
            v.extend(convergence_panels(&[Power], &[10000], &p(&[5, 10, 15, 20, 25]), YaoExponential, paper_scale));
            v
        }
        // Ablation: overlay topology (the paper reports "no appreciable
        // difference" between BA and ER; WS and a pure ring probe how much
        // the small-world property matters).
        "abl_topology" => {
            use crate::config::GraphKind::*;
            let mut v = Vec::new();
            for graph in [BarabasiAlbert, ErdosRenyi, WattsStrogatz, Ring] {
                let mut cfg = base_cfg(Adversarial, scale_peers(5000, paper_scale), paper_scale);
                cfg.graph = graph;
                v.push(Panel {
                    label: format!("adversarial graph={}", graph.name()),
                    cfg,
                    rounds: vec![5, 10, 15, 20, 25],
                });
            }
            v
        }
        // Ablation: fan-out (§4 allows fan-out ≥ 1).
        "abl_fanout" => {
            let mut v = Vec::new();
            for fan_out in [1usize, 2, 4] {
                let mut cfg = base_cfg(Adversarial, scale_peers(5000, paper_scale), paper_scale);
                cfg.fan_out = fan_out;
                v.push(Panel {
                    label: format!("adversarial fan-out={fan_out}"),
                    cfg,
                    rounds: vec![5, 10, 15, 20, 25],
                });
            }
            v
        }
        other => bail!("unknown figure id '{other}' (see `duddsketch figure --list`)"),
    })
}

/// CSV columns shared by all figure outputs.
const CSV_HEADER: [&str; 16] = [
    "figure", "panel", "dataset", "churn", "peers", "items_per_peer", "rounds",
    "online", "q", "seq_estimate", "are", "re_q1", "re_median", "re_q3",
    "re_whisker_lo", "re_whisker_hi",
];

fn outcome_to_csv(id: &str, label: &str, out: &RunOutcome, csv: &mut CsvWriter) {
    for snap in &out.snapshots {
        for qs in &snap.quantiles {
            csv.row(&[
                id.to_string(),
                label.to_string(),
                out.cfg.dataset.name().to_string(),
                out.cfg.churn.name().to_string(),
                out.cfg.peers.to_string(),
                out.cfg.items_per_peer.to_string(),
                snap.rounds.to_string(),
                snap.online.to_string(),
                format!("{}", qs.q),
                format!("{:.9e}", qs.truth),
                format!("{:.6e}", qs.are),
                format!("{:.6e}", qs.box_summary.q1),
                format!("{:.6e}", qs.box_summary.median),
                format!("{:.6e}", qs.box_summary.q3),
                format!("{:.6e}", qs.box_summary.whisker_lo),
                format!("{:.6e}", qs.box_summary.whisker_hi),
            ]);
        }
    }
}

fn render_outcome(label: &str, out: &RunOutcome) -> String {
    let mut text = String::new();
    for snap in &out.snapshots {
        let rows: Vec<BoxRow> = snap
            .quantiles
            .iter()
            .map(|qs| BoxRow {
                label: format!("q={:<4}", qs.q),
                summary: qs.box_summary,
            })
            .collect();
        text.push_str(&render_boxes(
            &format!(
                "{label} | rounds={} online={} (relative error vs sequential)",
                snap.rounds, snap.online
            ),
            &rows,
            64,
            1e-12,
        ));
    }
    text
}

fn table1_report() -> FigureReport {
    let master = default_rng(42);
    let mut text = String::from(
        "Table 1 — synthetic datasets (per-peer parameters drawn uniformly at random)\n",
    );
    for kind in DatasetKind::SYNTHETIC {
        let xs = peer_dataset(kind, 0, 5_000, &master);
        let b = BoxSummary::from_data(&xs).unwrap();
        text.push_str(&format!(
            "  {:<12} sample(peer 0): min={:.4e} median={:.4e} max={:.4e}\n",
            kind.name(),
            b.min,
            b.median,
            b.max
        ));
    }
    text.push_str(
        "  definitions: adversarial=Uniform(1,1e2)·100^group | uniform=U([1,1e5],[1e6,1e7])\n\
         \x20 exponential=Exp([0.1,3.5]) | normal=N([1e6,1e7],[1e5,1e6])\n",
    );
    FigureReport {
        id: "table1".into(),
        text,
        csv_path: String::new(),
    }
}

fn table2_report() -> FigureReport {
    let cfg = ExperimentConfig::default();
    let text = format!(
        "Table 2 — default parameters\n\
         \x20 alpha             {}\n\
         \x20 quantiles         {:?}\n\
         \x20 number of buckets m = {}\n\
         \x20 number of peers P {{1000,5000,10000,15000}} (scaled: /10)\n\
         \x20 number of rounds R {{5,10,15,20,25}}\n\
         \x20 fan-out           {}\n\
         \x20 items/peer        100000 (scaled default: {})\n",
        cfg.alpha, cfg.quantiles, cfg.max_buckets, cfg.fan_out, cfg.items_per_peer,
    );
    FigureReport {
        id: "table2".into(),
        text,
        csv_path: String::new(),
    }
}

/// Regenerate one figure/table. CSVs land in `out_dir`.
pub fn run_figure(id: &str, paper_scale: bool, out_dir: &Path) -> Result<FigureReport> {
    match id {
        "table1" => return Ok(table1_report()),
        "table2" => return Ok(table2_report()),
        _ => {}
    }
    let panels = panels_for(id, paper_scale)?;
    let mut csv = CsvWriter::new(&CSV_HEADER);
    let mut text = String::new();
    for panel in &panels {
        let out = run_with_snapshots(&panel.cfg, &panel.rounds)?;
        outcome_to_csv(id, &panel.label, &out, &mut csv);
        text.push_str(&render_outcome(&panel.label, &out));
        text.push('\n');
    }
    let csv_path = out_dir.join(format!("{id}.csv"));
    csv.write_to(&csv_path)?;
    Ok(FigureReport {
        id: id.to_string(),
        text,
        csv_path: csv_path.display().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_cover_every_paper_artifact() {
        let ids = figure_ids();
        assert_eq!(ids.len(), 16); // 2 tables + 12 figures + 2 ablations
        for i in 1..=12 {
            assert!(ids.contains(&format!("fig{i}").as_str()));
        }
        assert!(ids.contains(&"abl_topology"));
        assert!(ids.contains(&"abl_fanout"));
    }

    #[test]
    fn tables_render() {
        let t1 = run_figure("table1", false, Path::new("/tmp")).unwrap();
        assert!(t1.text.contains("adversarial"));
        let t2 = run_figure("table2", false, Path::new("/tmp")).unwrap();
        assert!(t2.text.contains("0.001"));
    }

    #[test]
    fn unknown_id_is_an_error() {
        assert!(run_figure("fig99", false, Path::new("/tmp")).is_err());
    }

    #[test]
    fn every_figure_has_panels() {
        for id in figure_ids() {
            if id.starts_with("fig") || id.starts_with("abl") {
                let panels = panels_for(id, false).unwrap();
                assert!(!panels.is_empty(), "{id}");
                for p in &panels {
                    p.cfg.validate().unwrap();
                    assert!(!p.rounds.is_empty());
                }
            }
        }
    }

    /// Smoke-run a miniature fig3-style panel end to end (tiny sizes so
    /// the unit-test suite stays fast; the real scaled profile runs via
    /// the CLI / `make figures`).
    #[test]
    fn figure_pipeline_smoke() {
        let dir = std::env::temp_dir().join("duddsketch_fig_smoke");
        let mut cfg = base_cfg(DatasetKind::Exponential, 60, false);
        cfg.items_per_peer = 200;
        let out = run_with_snapshots(&cfg, &[5, 10]).unwrap();
        let mut csv = CsvWriter::new(&CSV_HEADER);
        outcome_to_csv("smoke", "exp P=60", &out, &mut csv);
        assert_eq!(csv.len(), 2 * cfg.quantiles.len());
        let text = render_outcome("exp P=60", &out);
        assert!(text.contains("rounds=10"));
        std::fs::create_dir_all(&dir).unwrap();
        csv.write_to(&dir.join("smoke.csv")).unwrap();
    }
}
