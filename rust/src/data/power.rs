//! The *power* dataset of §7.3: global active power readings from the UCI
//! *Individual Household Electric Power Consumption* dataset [29].
//!
//! This environment has no network access, so the real file
//! (`household_power_consumption.txt`) cannot be downloaded. Two paths are
//! provided (DESIGN.md §6 documents the substitution):
//!
//! * [`load_power_file`] parses the real UCI file when the user supplies
//!   it (semicolon-separated, `Global_active_power` in column 3, missing
//!   values as `?`).
//! * [`PowerSurrogate`] samples a mixture model matched to the published
//!   marginal of the real column: ≈1-minute household readings in
//!   (0.076, 11.122) kW, heavy mass in the 0.2–0.6 kW standby band, a bulk
//!   cooking/heating band around 1–2 kW, and a thin right tail to ~11 kW.
//!   The sketches only observe the marginal distribution (UDDSketch is
//!   permutation-invariant), so the surrogate exercises the identical code
//!   path and error behaviour.

use crate::rng::{Normal, Rng, Sample};
use std::io::BufRead;
use std::path::Path;

/// Mixture-of-lognormals surrogate for the UCI global-active-power column.
#[derive(Debug, Clone, Copy)]
pub struct PowerSurrogate {
    /// Component weights (sum to 1): standby, appliance, heavy-load.
    pub weights: [f64; 3],
    /// Lognormal location parameters per component (kW scale).
    pub mu: [f64; 3],
    /// Lognormal shape parameters per component.
    pub sigma: [f64; 3],
    /// Hard clamp matching the real column's observed support.
    pub min_kw: f64,
    /// Upper clamp (real max: 11.122 kW).
    pub max_kw: f64,
}

impl Default for PowerSurrogate {
    fn default() -> Self {
        Self {
            // ~62% standby (~0.3 kW), ~31% appliance band (~1.4 kW),
            // ~7% heavy loads (~4 kW) — matches the published histogram's
            // bimodal shape, overall mean ≈ 1.09 kW.
            weights: [0.62, 0.31, 0.07],
            mu: [-1.20, 0.33, 1.35],
            sigma: [0.38, 0.35, 0.30],
            min_kw: 0.076,
            max_kw: 11.122,
        }
    }
}

impl Sample for PowerSurrogate {
    fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        let u = rng.next_f64();
        let comp = if u < self.weights[0] {
            0
        } else if u < self.weights[0] + self.weights[1] {
            1
        } else {
            2
        };
        let z = Normal::new(self.mu[comp], self.sigma[comp]).sample(rng);
        z.exp().clamp(self.min_kw, self.max_kw)
    }
}

/// Parse the real UCI file: returns the `Global_active_power` column.
///
/// Format: `Date;Time;Global_active_power;...` with a header line and `?`
/// for missing values (skipped, as in the authors' preprocessing).
pub fn load_power_file(path: &Path) -> std::io::Result<Vec<f64>> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.starts_with("Date") {
            continue; // header
        }
        let mut fields = line.split(';');
        let value = fields.nth(2);
        match value {
            Some("?") | Some("") | None => continue,
            Some(v) => {
                if let Ok(x) = v.trim().parse::<f64>() {
                    if x > 0.0 && x.is_finite() {
                        out.push(x);
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Load the real dataset if `POWER_DATASET` points at it (or it sits at
/// `data/household_power_consumption.txt`), else sample `n` surrogate
/// values.
pub fn power_dataset_or_surrogate<R: Rng>(n: usize, rng: &mut R) -> Vec<f64> {
    let candidates = [
        std::env::var("POWER_DATASET").unwrap_or_default(),
        "data/household_power_consumption.txt".to_string(),
    ];
    for c in candidates.iter().filter(|c| !c.is_empty()) {
        let p = Path::new(c);
        if p.exists() {
            if let Ok(xs) = load_power_file(p) {
                if !xs.is_empty() {
                    return xs;
                }
            }
        }
    }
    PowerSurrogate::default().sample_n(rng, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    #[test]
    fn surrogate_support_and_moments() {
        let mut r = default_rng(1);
        let d = PowerSurrogate::default();
        let xs = d.sample_n(&mut r, 200_000);
        assert!(xs.iter().all(|&x| (0.076..=11.122).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // Published column mean ≈ 1.09 kW; surrogate within ~15%.
        assert!((0.9..=1.3).contains(&mean), "mean {mean}");
        // Bimodality proxy: plenty of mass below 0.6 kW and above 1 kW.
        let lo = xs.iter().filter(|&&x| x < 0.6).count() as f64 / xs.len() as f64;
        let hi = xs.iter().filter(|&&x| x > 1.0).count() as f64 / xs.len() as f64;
        assert!(lo > 0.4, "standby mass {lo}");
        assert!(hi > 0.25, "active mass {hi}");
    }

    #[test]
    fn parses_uci_format() {
        let dir = std::env::temp_dir().join("duddsketch_power_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.txt");
        std::fs::write(
            &path,
            "Date;Time;Global_active_power;Global_reactive_power;Voltage\n\
             16/12/2006;17:24:00;4.216;0.418;234.840\n\
             16/12/2006;17:25:00;?;0.436;233.630\n\
             16/12/2006;17:26:00;5.360;0.498;233.290\n",
        )
        .unwrap();
        let xs = load_power_file(&path).unwrap();
        assert_eq!(xs, vec![4.216, 5.360]);
    }

    #[test]
    fn surrogate_heavy_tail_exists() {
        let mut r = default_rng(2);
        let d = PowerSurrogate::default();
        let xs = d.sample_n(&mut r, 200_000);
        let p99 = {
            let mut s = xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[(0.99 * (s.len() - 1) as f64) as usize]
        };
        assert!(p99 > 3.0, "p99 {p99} should reach the heavy-load band");
    }
}
