//! The adversarial workload construction of §7.1.
//!
//! Base values are uniform in (1, 10²). Peers are partitioned into groups
//! of at most [`super::ADVERSARIAL_GROUP`] peers; peers in different groups
//! receive values from **disjoint intervals** chosen so the intervals also
//! occupy disjoint sets of sketch buckets. Following the authors'
//! simulator, group `g`'s interval is the base interval scaled by `100^g`:
//! `(100^g, 100^(g+1))` — consecutive groups share no bucket because the
//! intervals are separated at the value 100^(g+1) itself.
//!
//! This is the distributed-averaging worst case: at round 0 the sketches of
//! different groups have no bucket in common, so every counter must
//! propagate across the whole overlay rather than just equalize.

use super::ADVERSARIAL_GROUP;
use crate::rng::{Rng, Sample, Uniform};

/// Per-peer description of the adversarial input interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdversarialSpec {
    /// The peer's group `g = peer_id / 100`.
    pub group: usize,
    /// Interval lower bound `100^g`.
    pub lo: f64,
    /// Interval upper bound `100^(g+1)`.
    pub hi: f64,
}

impl AdversarialSpec {
    /// The spec for a given peer id.
    ///
    /// f64 overflows past ~154 groups (100^154 ≈ 1e308); the group index
    /// therefore wraps at 150 — irrelevant below 15 000 peers, which is the
    /// paper's maximum network size.
    pub fn for_peer(peer_id: usize) -> Self {
        let group = (peer_id / ADVERSARIAL_GROUP) % 150;
        let lo = 100f64.powi(group as i32);
        let hi = 100f64.powi(group as i32 + 1);
        Self { group, lo, hi }
    }

    /// Draw `n` values: `u · 100^g` with `u` uniform in (1, 100).
    pub fn sample_n<R: Rng>(&self, rng: &mut R, n: usize) -> Vec<f64> {
        let base = Uniform::new(1.0, 100.0);
        (0..n)
            .map(|_| {
                // Exclude the exact lower edge so the interval is open as
                // in the paper ((1,100) scaled).
                let mut u = base.sample(rng);
                while u <= 1.0 {
                    u = base.sample(rng);
                }
                u * self.lo
            })
            .collect()
    }
}

/// The value interval assigned to adversarial group `g` (for tests and
/// documentation).
pub fn adversarial_interval(group: usize) -> (f64, f64) {
    let s = AdversarialSpec::for_peer(group * ADVERSARIAL_GROUP);
    (s.lo, s.hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;
    use crate::sketch::LogMapping;

    #[test]
    fn groups_of_one_hundred() {
        assert_eq!(AdversarialSpec::for_peer(0).group, 0);
        assert_eq!(AdversarialSpec::for_peer(99).group, 0);
        assert_eq!(AdversarialSpec::for_peer(100).group, 1);
        assert_eq!(AdversarialSpec::for_peer(14_999).group, 149);
    }

    #[test]
    fn values_fall_in_group_interval() {
        let mut r = default_rng(1);
        for peer in [0, 150, 742] {
            let spec = AdversarialSpec::for_peer(peer);
            let xs = spec.sample_n(&mut r, 1000);
            assert!(xs.iter().all(|&x| x > spec.lo && x < spec.hi));
        }
    }

    #[test]
    fn different_groups_hit_disjoint_buckets() {
        // The defining property: with the paper's alpha=0.001, sketch
        // bucket sets of different groups must not intersect.
        let mut r = default_rng(2);
        let map = LogMapping::new(0.001).unwrap();
        let idx = |peer: usize, r: &mut crate::rng::Xoshiro256pp| {
            let xs = AdversarialSpec::for_peer(peer).sample_n(r, 2000);
            let mut is: Vec<i64> = xs.iter().map(|&x| map.index(x)).collect();
            is.sort_unstable();
            is.dedup();
            is
        };
        let g0 = idx(0, &mut r);
        let g1 = idx(100, &mut r);
        let g2 = idx(200, &mut r);
        assert!(g0.last().unwrap() < g1.first().unwrap());
        assert!(g1.last().unwrap() < g2.first().unwrap());
    }

    #[test]
    fn interval_helper_matches_spec() {
        assert_eq!(adversarial_interval(0), (1.0, 100.0));
        assert_eq!(adversarial_interval(2), (1e4, 1e6));
    }
}
