//! Input workloads: the four synthetic datasets of Table 1 (§7.1) and the
//! real *power* dataset of §7.3 (UCI Individual Household Electric Power
//! Consumption — loader for the real file plus a documented surrogate, see
//! DESIGN.md §6).

#![forbid(unsafe_code)]

mod power;
mod synthetic;

pub use power::{load_power_file, PowerSurrogate};
pub use synthetic::{adversarial_interval, AdversarialSpec};

use crate::rng::{Exponential, Normal, Sample, Uniform, Xoshiro256pp};

/// Number of peers per adversarial group (§7.1: "groups of at most one
/// hundred peers").
pub const ADVERSARIAL_GROUP: usize = 100;

/// The workloads of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// §7.1 worst case: per-group disjoint value intervals so local
    /// sketches share no buckets.
    Adversarial,
    /// `Uniform(lo, hi)` with per-peer `lo ∈ [1, 1e5]`, `hi ∈ [1e6, 1e7]`.
    Uniform,
    /// `Exp(λ)` with per-peer `λ ∈ [0.1, 3.5]`.
    Exponential,
    /// `N(μ, σ)` with per-peer `μ ∈ [1e6, 1e7]`, `σ ∈ [1e5, 1e6]`,
    /// truncated to ℝ>0 (the sketches' domain, Theorem 2).
    Normal,
    /// §7.3 real dataset (global active power), surrogate-backed when the
    /// UCI file is absent.
    Power,
}

impl DatasetKind {
    /// All synthetic kinds, in the paper's presentation order.
    pub const SYNTHETIC: [DatasetKind; 4] = [
        DatasetKind::Adversarial,
        DatasetKind::Uniform,
        DatasetKind::Exponential,
        DatasetKind::Normal,
    ];

    /// Lower-case name used by the CLI and CSV output.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Adversarial => "adversarial",
            DatasetKind::Uniform => "uniform",
            DatasetKind::Exponential => "exponential",
            DatasetKind::Normal => "normal",
            DatasetKind::Power => "power",
        }
    }
}

impl std::str::FromStr for DatasetKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "adversarial" => Ok(DatasetKind::Adversarial),
            "uniform" => Ok(DatasetKind::Uniform),
            "exponential" | "exp" => Ok(DatasetKind::Exponential),
            "normal" | "gaussian" => Ok(DatasetKind::Normal),
            "power" => Ok(DatasetKind::Power),
            other => Err(format!(
                "unknown dataset '{other}' (expected adversarial|uniform|exponential|normal|power)"
            )),
        }
    }
}

/// Generate peer `peer_id`'s local dataset of `items` values.
///
/// Per §7.1, the per-peer distribution parameters are drawn "independently
/// and uniformly at random by each peer": each peer derives an independent
/// RNG stream from the master generator, so datasets are reproducible given
/// the experiment seed and independent across peers.
pub fn peer_dataset(
    kind: DatasetKind,
    peer_id: usize,
    items: usize,
    master: &Xoshiro256pp,
) -> Vec<f64> {
    let mut rng = master.derive(0x5EED_0000 + peer_id as u64);
    match kind {
        DatasetKind::Adversarial => {
            let spec = AdversarialSpec::for_peer(peer_id);
            spec.sample_n(&mut rng, items)
        }
        DatasetKind::Uniform => {
            let lo = Uniform::new(1.0, 1e5).sample(&mut rng);
            let hi = Uniform::new(1e6, 1e7).sample(&mut rng);
            Uniform::new(lo, hi).sample_n(&mut rng, items)
        }
        DatasetKind::Exponential => {
            let lambda = Uniform::new(0.1, 3.5).sample(&mut rng);
            Exponential::new(lambda).sample_n(&mut rng, items)
        }
        DatasetKind::Normal => {
            let mean = Uniform::new(1e6, 1e7).sample(&mut rng);
            let sd = Uniform::new(1e5, 1e6).sample(&mut rng);
            let d = Normal::new(mean, sd);
            // Truncate to the sketches' ℝ>0 domain by rejection; with
            // μ ≥ 10σ this virtually never loops.
            (0..items)
                .map(|_| loop {
                    let x = d.sample(&mut rng);
                    if x > 0.0 {
                        break x;
                    }
                })
                .collect()
        }
        DatasetKind::Power => {
            // Real UCI file when supplied (POWER_DATASET env or
            // data/household_power_consumption.txt): deterministic
            // per-peer slice with wrap-around; surrogate otherwise.
            let pool = power::power_dataset_or_surrogate(0, &mut rng);
            if pool.is_empty() {
                PowerSurrogate::default().sample_n(&mut rng, items)
            } else {
                (0..items)
                    .map(|k| pool[(peer_id * items + k) % pool.len()])
                    .collect()
            }
        }
    }
}

/// Generate all peers' datasets (convenience used by the experiment
/// harness); row `l` is peer `l`'s local stream.
pub fn all_peer_datasets(
    kind: DatasetKind,
    peers: usize,
    items: usize,
    master: &Xoshiro256pp,
) -> Vec<Vec<f64>> {
    (0..peers)
        .map(|l| peer_dataset(kind, l, items, master))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    #[test]
    fn kinds_parse_round_trip() {
        for k in [
            DatasetKind::Adversarial,
            DatasetKind::Uniform,
            DatasetKind::Exponential,
            DatasetKind::Normal,
            DatasetKind::Power,
        ] {
            let parsed: DatasetKind = k.name().parse().unwrap();
            assert_eq!(parsed, k);
        }
        assert!("nope".parse::<DatasetKind>().is_err());
    }

    #[test]
    fn datasets_are_deterministic_per_seed_and_peer() {
        let m = default_rng(42);
        let a = peer_dataset(DatasetKind::Uniform, 3, 100, &m);
        let b = peer_dataset(DatasetKind::Uniform, 3, 100, &m);
        let c = peer_dataset(DatasetKind::Uniform, 4, 100, &m);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn all_kinds_positive_and_sized() {
        let m = default_rng(7);
        for kind in [
            DatasetKind::Adversarial,
            DatasetKind::Uniform,
            DatasetKind::Exponential,
            DatasetKind::Normal,
            DatasetKind::Power,
        ] {
            let xs = peer_dataset(kind, 0, 500, &m);
            assert_eq!(xs.len(), 500);
            assert!(
                xs.iter().all(|&x| x > 0.0 && x.is_finite()),
                "{kind:?} produced non-positive values"
            );
        }
    }

    #[test]
    fn uniform_peers_have_distinct_params() {
        let m = default_rng(8);
        let a = peer_dataset(DatasetKind::Uniform, 0, 2000, &m);
        let b = peer_dataset(DatasetKind::Uniform, 1, 2000, &m);
        let max_a = a.iter().cloned().fold(f64::MIN, f64::max);
        let max_b = b.iter().cloned().fold(f64::MIN, f64::max);
        // Per-peer hi parameters differ with overwhelming probability.
        assert!((max_a - max_b).abs() > 1.0);
    }
}
