//! Per-peer protocol state (Algorithm 3) and the distributed quantile
//! query (Algorithm 6).

use crate::sketch::{DenseStore, SketchError, Store, UddSketch, VecStore};

/// Sketch type carried by gossip peers: sorted-vector backed, so memory is
/// proportional to live buckets (≤ m) rather than to the index span —
/// essential on the adversarial workload, where a cross-group merge spans
/// hundreds of thousands of indices before collapses catch up — and the
/// per-exchange merge is a linear two-pointer pass (§Perf in
/// EXPERIMENTS.md: ~14× over the BTreeMap store it replaced).
pub type GossipSketch = UddSketch<VecStore>;

/// The state `(S_l, Ñ_l, q̃_l)` a peer carries through the protocol.
#[derive(Debug, Clone)]
pub struct PeerState {
    /// Peer identifier `l` (1-based in the paper; 0-based here).
    pub id: usize,
    /// The local UDDSketch summary (bucket counters become fractional as
    /// averaging proceeds).
    pub sketch: GossipSketch,
    /// Estimate of the average local stream length `N̄ = (1/p) Σ N_l`;
    /// initialized to the local `N_l`.
    pub n_tilde: f64,
    /// Estimate of `1/p`; peer 0 starts at 1, everyone else at 0
    /// (Algorithm 3 lines 3–6 — no leader election needed since ids are
    /// distinct).
    pub q_tilde: f64,
}

impl PeerState {
    /// Algorithm 3: process the local dataset with sequential UDDSketch
    /// and initialize the averaging scalars.
    pub fn init(
        id: usize,
        dataset: &[f64],
        alpha: f64,
        max_buckets: usize,
    ) -> Result<Self, SketchError> {
        // Bulk ingestion runs on the dense store (fast hot path), the
        // result converts to the sparse gossip representation once.
        // Scalar initialization is shared with `from_sketch`
        // (`count()` == dataset.len() exactly for unit-weight inserts).
        let mut dense: UddSketch<DenseStore> = UddSketch::new(alpha, max_buckets)?;
        dense.extend(dataset);
        Ok(Self::from_sketch(id, &dense))
    }

    /// Front an already-built local summary as a gossip peer: Algorithm
    /// 3's scalar initialization with the sketch supplied instead of
    /// re-processed from the raw stream. This is how a
    /// [`service`](crate::service) snapshot becomes a live peer — the
    /// serving path maintains the local UDDSketch, gossip averages it.
    ///
    /// ```
    /// use duddsketch::gossip::PeerState;
    /// use duddsketch::sketch::UddSketch;
    ///
    /// let mut local: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    /// local.extend(&[1.0, 2.0, 3.0]);
    /// // Peer 0 plays Algorithm 3's distinguished role: q̃ = 1.
    /// let peer = PeerState::from_sketch(0, &local);
    /// assert_eq!(peer.n_tilde, 3.0);
    /// assert_eq!(peer.q_tilde, 1.0);
    /// assert_eq!(PeerState::from_sketch(3, &local).q_tilde, 0.0);
    /// ```
    pub fn from_sketch<S: Store>(id: usize, sketch: &UddSketch<S>) -> Self {
        Self {
            id,
            sketch: sketch.convert_store(),
            n_tilde: sketch.count(),
            q_tilde: if id == 0 { 1.0 } else { 0.0 },
        }
    }

    /// Algorithm 4's UPDATE: the averaged state both exchange partners
    /// adopt. Sketches merge with weight ½ each (Algorithm 5; collapse
    /// alignment happens inside the merge), scalars average.
    pub fn averaged(a: &PeerState, b: &PeerState) -> Result<PeerState, SketchError> {
        let mut sketch = a.sketch.clone();
        sketch.merge_weighted(&b.sketch, 0.5, 0.5)?;
        Ok(PeerState {
            id: a.id,
            sketch,
            n_tilde: 0.5 * (a.n_tilde + b.n_tilde),
            q_tilde: 0.5 * (a.q_tilde + b.q_tilde),
        })
    }

    /// In-place UPDATE for the engine's hot loop: averages `a` and `b`
    /// directly into both slots with a single merge and a single clone
    /// (the two peers must end up with equal but independent states).
    pub fn exchange(a: &mut PeerState, b: &mut PeerState) -> Result<(), SketchError> {
        a.sketch.merge_weighted(&b.sketch, 0.5, 0.5)?;
        b.sketch = a.sketch.clone();
        let n = 0.5 * (a.n_tilde + b.n_tilde);
        let q = 0.5 * (a.q_tilde + b.q_tilde);
        a.n_tilde = n;
        b.n_tilde = n;
        a.q_tilde = q;
        b.q_tilde = q;
        Ok(())
    }

    /// Epoch-carry correction (`docs/PROTOCOL.md` §10): fold an
    /// insert-only extension of the *local* summary into this
    /// already-averaged slot, so an epoch advance needs no protocol
    /// restart. `delta` is the bucketwise difference between the new
    /// local snapshot and the summary this slot was last seeded from
    /// ([`UddSketch::additive_delta`]).
    ///
    /// Correction algebra: the averaged quantities are conserved as
    /// *fleet sums* (`Σ n_tilde = Σ N_l`, `Σ B̃_i = Σ B_i`). Growing the
    /// local stream by the delta grows each sum by exactly the delta's
    /// contribution, so adding the full delta to this one slot — sketch
    /// merged at weight (1, 1), `n_tilde += delta.count()` — keeps every
    /// sum exact; subsequent exchanges re-spread the new mass at the
    /// usual variance-contraction rate. `q̃` carries mass about the
    /// *membership*, not the stream, and is untouched: the generation's
    /// `q̃` total stays exactly 1.
    pub fn carry_epoch_delta<S: Store>(
        &mut self,
        delta: &UddSketch<S>,
    ) -> Result<(), SketchError> {
        self.sketch.merge_weighted(&delta.convert_store(), 1.0, 1.0)?;
        self.n_tilde += delta.count();
        Ok(())
    }

    /// Estimated network size `p̃ = round(1/q̃)` (∞ while `q̃` is still 0,
    /// i.e. before any information from peer 0 reached this peer).
    ///
    /// Algorithm 6 writes `⌈1/q̃⌉`, but `q̃` converges to `1/p`
    /// *oscillating from both sides*: whenever it sits a hair below, the
    /// ceiling reports `p + 1` and the query's target rank inflates by a
    /// factor `(p+1)/p` that the per-bucket integer rounding of small
    /// counters does not follow — a persistent one-bucket bias. Rounding
    /// agrees with the ceiling at the fixed point (`1/q̃ = p` exactly) and
    /// converges from both sides.
    pub fn estimated_peers(&self) -> f64 {
        if self.q_tilde <= 0.0 {
            f64::INFINITY
        } else {
            (1.0 / self.q_tilde).round().max(1.0)
        }
    }

    /// Estimated global stream length `Ñ = round(p̃ · Ñ_l)`.
    pub fn estimated_total(&self) -> f64 {
        let p = self.estimated_peers();
        if p.is_finite() {
            (p * self.n_tilde).round()
        } else {
            f64::INFINITY
        }
    }

    /// Algorithm 6: estimate the q-quantile of the *global* dataset from
    /// this peer's averaged state.
    ///
    /// Counters scale back to global counts by rounding `B̃_i · p̃` to the
    /// nearest integer, and the walk uses the same `cumulative ≥
    /// target-rank` convention as the sequential query, so that a fully
    /// converged peer returns *exactly* the sequential estimate. Two
    /// deliberate deviations from Algorithm 6's pseudocode, both of which
    /// only tighten convergence: (i) the paper writes `⌈B̃_i · p̃⌉`, but a
    /// ceiling turns any positive floating-point residual left by
    /// finitely many averaging rounds into a +1 per bucket, which biases
    /// low quantiles when the stream/bucket ratio is small — rounding
    /// recovers the exact integer global counts at the fixed point;
    /// (ii) the paper advances while `count ≤ target`, which skips to the
    /// next bucket when the target rank lands exactly on a bucket
    /// boundary — we keep Definition 2's inferior-quantile convention, as
    /// the sequential algorithm does.
    pub fn query(&self, q: f64) -> Result<f64, SketchError> {
        if !(0.0..=1.0).contains(&q) || q.is_nan() {
            return Err(SketchError::InvalidQuantile(q));
        }
        let p_hat = self.estimated_peers();
        if !p_hat.is_finite() {
            // No global information yet: answer from the local sketch
            // (p̃ = 1) — this is what a peer can honestly report and what
            // makes early-round relative errors large but finite, as in
            // the paper's round-5 plots.
            return self.sketch.quantile(q);
        }
        let n_hat = (p_hat * self.n_tilde).round();
        if n_hat <= 0.0 {
            return Err(SketchError::Empty);
        }
        let target = (1.0 + q * (n_hat - 1.0)).floor().max(1.0);
        let mapping = self.sketch.mapping();
        let mut acc = 0.0;
        let mut result: Option<f64> = None;

        // Negative store (most negative value first), then zeros, then the
        // positive store — mirrors the sequential walk with scaled counts.
        let mut neg = self.sketch.negative_store().entries();
        neg.reverse();
        for (i, c) in neg {
            acc += (c * p_hat).round();
            if acc >= target && result.is_none() {
                result = Some(-mapping.value(i));
            }
        }
        if result.is_none() && self.sketch.zero_weight() > 0.0 {
            acc += (self.sketch.zero_weight() * p_hat).round();
            if acc >= target {
                result = Some(0.0);
            }
        }
        if result.is_none() {
            self.sketch.positive_store().for_each(|i, c| {
                acc += (c * p_hat).round();
                if acc >= target && result.is_none() {
                    result = Some(mapping.value(i));
                }
            });
        }
        result
            .or_else(|| {
                self.sketch
                    .positive_store()
                    .max_index()
                    .map(|i| mapping.value(i))
            })
            .ok_or(SketchError::Empty)
    }

    /// Algorithm 6's count scaling applied to the rank walk: estimated
    /// CDF of the **union** stream at `x`, counting every bucket whose
    /// representative is ≤ x with its counter scaled back to a global
    /// count by `round(B̃_i · p̃)` — the same convention as
    /// [`PeerState::query`], so a fully converged peer returns exactly
    /// the sequential estimate. Falls back to the local sketch while no
    /// global information has arrived (`q̃ = 0`).
    pub fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        if x.is_nan() {
            return Err(SketchError::UnsupportedValue(x));
        }
        let p_hat = self.estimated_peers();
        if !p_hat.is_finite() {
            return self.sketch.cdf(x);
        }
        let n_hat = (p_hat * self.n_tilde).round();
        if n_hat <= 0.0 {
            return Err(SketchError::Empty);
        }
        let mapping = self.sketch.mapping();
        let mut acc = 0.0;
        self.sketch.negative_store().for_each(|i, c| {
            if -mapping.value(i) <= x {
                acc += (c * p_hat).round();
            }
        });
        if x >= 0.0 {
            acc += (self.sketch.zero_weight() * p_hat).round();
        }
        self.sketch.positive_store().for_each(|i, c| {
            if mapping.value(i) <= x {
                acc += (c * p_hat).round();
            }
        });
        Ok((acc / n_hat).clamp(0.0, 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::UddSketch;

    #[test]
    fn init_sets_scalars_per_algorithm3() {
        let d = vec![1.0, 2.0, 3.0];
        let s0 = PeerState::init(0, &d, 0.01, 64).unwrap();
        let s1 = PeerState::init(1, &d, 0.01, 64).unwrap();
        assert_eq!(s0.q_tilde, 1.0);
        assert_eq!(s1.q_tilde, 0.0);
        assert_eq!(s0.n_tilde, 3.0);
        assert_eq!(s0.sketch.count(), 3.0);
    }

    #[test]
    fn averaged_preserves_sum() {
        let a = PeerState::init(0, &[1.0, 2.0, 3.0, 4.0], 0.01, 64).unwrap();
        let b = PeerState::init(1, &[10.0, 20.0], 0.01, 64).unwrap();
        let m = PeerState::averaged(&a, &b).unwrap();
        assert_eq!(m.n_tilde, 3.0);
        assert_eq!(m.q_tilde, 0.5);
        assert!((m.sketch.count() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn estimated_peers_recovers_p() {
        let mut s = PeerState::init(0, &[1.0], 0.01, 64).unwrap();
        s.q_tilde = 1.0 / 8.0;
        assert_eq!(s.estimated_peers(), 8.0);
        s.q_tilde = 0.126; // round(1/0.126) = round(7.94) = 8
        assert_eq!(s.estimated_peers(), 8.0);
        s.q_tilde = 0.0;
        assert!(s.estimated_peers().is_infinite());
    }

    #[test]
    fn converged_state_queries_match_sequential() {
        // Build the exact average state of p=4 peers and check the
        // reconstruction equals the sequential sketch's answers.
        let mut r = default_rng(1);
        let datasets: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..1000).map(|_| 1.0 + 99.0 * r.next_f64()).collect())
            .collect();
        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        for d in &datasets {
            seq.extend(d);
        }
        // Perfectly averaged state (what r -> ∞ gossip yields).
        let states: Vec<PeerState> = datasets
            .iter()
            .enumerate()
            .map(|(i, d)| PeerState::init(i, d, 0.001, 1024).unwrap())
            .collect();
        let mut avg = states[0].clone();
        for s in &states[1..] {
            avg.sketch.merge(&s.sketch).unwrap();
            avg.n_tilde += s.n_tilde;
            avg.q_tilde += s.q_tilde;
        }
        let p = states.len() as f64;
        avg.sketch = {
            let mut sk = UddSketch::new(0.001, 1024).unwrap();
            sk.merge_weighted(&avg.sketch, 0.0, 1.0 / p).unwrap();
            sk
        };
        avg.n_tilde /= p;
        avg.q_tilde /= p;

        for q in [0.0, 0.01, 0.25, 0.5, 0.75, 0.99, 1.0] {
            let est = avg.query(q).unwrap();
            let tru = seq.quantile(q).unwrap();
            assert_eq!(est, tru, "q={q}");
        }
        for x in [0.5, 1.0, 10.0, 50.0, 99.0, 200.0] {
            assert_eq!(avg.cdf(x).unwrap(), seq.cdf(x).unwrap(), "cdf x={x}");
        }
    }

    #[test]
    fn carry_epoch_delta_conserves_fleet_sums() {
        // Peer 1's local stream grows by an epoch mid-gossip; the carry
        // keeps every fleet sum equal to the new global totals without
        // touching the generation's q̃ mass.
        let mut local: UddSketch = UddSketch::new(0.01, 64).unwrap();
        local.extend(&[10.0, 20.0]);
        let mut a = PeerState::init(0, &[1.0, 2.0], 0.01, 64).unwrap();
        let mut b = PeerState::from_sketch(1, &local);
        PeerState::exchange(&mut a, &mut b).unwrap();

        let seed = local.clone();
        local.extend(&[30.0, 40.0, 50.0]);
        let delta = local.additive_delta(&seed).unwrap();
        b.carry_epoch_delta(&delta).unwrap();

        assert_eq!(a.n_tilde + b.n_tilde, 7.0, "Σ n_tilde == Σ N_l");
        assert_eq!(a.q_tilde + b.q_tilde, 1.0, "q̃ mass untouched");
        assert!(
            (a.sketch.count() + b.sketch.count() - 7.0).abs() < 1e-12,
            "Σ averaged counters == global count"
        );
        // Another exchange keeps re-spreading the carried mass.
        PeerState::exchange(&mut a, &mut b).unwrap();
        assert_eq!(a.n_tilde + b.n_tilde, 7.0);
        assert_eq!(a.q_tilde + b.q_tilde, 1.0);
    }

    #[test]
    fn cdf_without_global_info_falls_back_to_local() {
        let s = PeerState::init(3, &[5.0, 6.0, 7.0], 0.01, 64).unwrap();
        assert_eq!(s.cdf(6.5).unwrap(), s.sketch.cdf(6.5).unwrap());
        assert!(s.cdf(f64::NAN).is_err());
    }

    #[test]
    fn query_without_global_info_falls_back_to_local() {
        let s = PeerState::init(3, &[5.0, 6.0, 7.0], 0.01, 64).unwrap();
        assert_eq!(s.q_tilde, 0.0);
        let est = s.query(0.5).unwrap();
        assert!((est - 6.0).abs() <= 0.01 * 6.0 + 1e-9);
    }

    #[test]
    fn query_rejects_bad_q() {
        let s = PeerState::init(0, &[1.0], 0.01, 64).unwrap();
        assert!(s.query(-0.1).is_err());
        assert!(s.query(1.1).is_err());
    }
}
