//! The synchronous gossip round engine (Algorithm 4).
//!
//! Two round modes are provided:
//!
//! * [`RoundMode::Sequential`] — Jelasity et al.'s simulation method, the
//!   one the paper's analysis assumes (§4.1): a random permutation of the
//!   peers is drawn and each peer in turn initiates an atomic push–pull
//!   with `fan-out` random online neighbours. A peer may be *contacted*
//!   several times per round; every exchange is atomic (the sequential
//!   simulation interleaves nothing), giving the convergence factor
//!   `E[2^{-ψ}] = 1/(2√e)` of Theorem 3.
//! * [`RoundMode::Matched`] — the simultaneous variant of Definition 9:
//!   a random matching of noninteracting pairs is drawn and all pairs
//!   exchange at once. This is the dense, batchable formulation the PJRT
//!   executor accelerates; it converges with factor ≈ matching-coverage/2
//!   per round (slower per round, identical fixed point).
//!
//! Churn semantics (§7.2): peers offline this round neither initiate nor
//! respond; an exchange with a peer that fails mid-exchange is cancelled
//! with both endpoints keeping (restoring) their pre-exchange state —
//! modelled by [`Protocol::set_exchange_drop`] failure injection.

use super::executor::{DenseRound, NativeExecutor, RoundExecutor};
use super::state::PeerState;
use crate::churn::ChurnModel;
use crate::config::{ExecutorKind, ExperimentConfig};
use crate::graph::Graph;
use crate::rng::{Rng, Xoshiro256pp};
use anyhow::{bail, Context};

/// Exchange scheduling discipline for a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Permutation-ordered atomic push–pull (paper/Jelasity model).
    Sequential,
    /// Simultaneous noninteracting pairs (dense/batched model).
    Matched,
}

/// Telemetry for one executed round.
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    /// Round number (0-based).
    pub round: usize,
    /// Completed push–pull exchanges.
    pub exchanges: usize,
    /// Exchanges cancelled by failure injection.
    pub dropped: usize,
    /// Peers online during the round.
    pub online: usize,
    /// Wire traffic this round (push + pull frames, codec byte-exact).
    pub bytes: usize,
}

/// The distributed protocol over one overlay.
pub struct Protocol {
    graph: Graph,
    states: Vec<PeerState>,
    churn: ChurnModel,
    rng: Xoshiro256pp,
    fan_out: usize,
    mode: RoundMode,
    executor: Box<dyn RoundExecutor>,
    round: usize,
    exchange_drop: f64,
    history: Vec<RoundStats>,
}

impl Protocol {
    /// Initialize all peers (Algorithm 3) over `graph` with one local
    /// dataset per peer.
    pub fn new(
        cfg: &ExperimentConfig,
        graph: Graph,
        datasets: &[Vec<f64>],
        master: &Xoshiro256pp,
    ) -> anyhow::Result<Self> {
        if graph.len() != datasets.len() {
            bail!(
                "graph has {} vertices but {} datasets supplied",
                graph.len(),
                datasets.len()
            );
        }
        cfg.validate().map_err(anyhow::Error::msg)?;
        let states = init_states(datasets, cfg.alpha, cfg.max_buckets)?;
        let churn = ChurnModel::new(cfg.churn, graph.len(), master);
        let (executor, mode): (Box<dyn RoundExecutor>, RoundMode) = match cfg.executor {
            ExecutorKind::Native => (Box::new(NativeExecutor), RoundMode::Sequential),
            ExecutorKind::Pjrt => (
                Box::new(
                    super::executor::PjrtExecutor::discover(cfg.peers)
                        .context("PJRT executor init (run `make artifacts`?)")?,
                ),
                RoundMode::Matched,
            ),
        };
        Ok(Self {
            graph,
            states,
            churn,
            rng: master.derive(0x905C),
            fan_out: cfg.fan_out,
            mode,
            executor,
            round: 0,
            exchange_drop: 0.0,
            history: Vec::new(),
        })
    }

    /// Override the round mode (e.g. `Matched` with the native executor,
    /// used by the Native≡PJRT integration tests).
    pub fn set_mode(&mut self, mode: RoundMode) {
        self.mode = mode;
    }

    /// Failure injection: probability that any single exchange is
    /// cancelled mid-flight (both endpoints restore their state, §7.2).
    pub fn set_exchange_drop(&mut self, p: f64) {
        assert!((0.0..=1.0).contains(&p));
        self.exchange_drop = p;
    }

    /// Peer states (peer `l` at index `l`).
    pub fn states(&self) -> &[PeerState] {
        &self.states
    }

    /// The overlay.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Rounds executed so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Per-round telemetry.
    pub fn history(&self) -> &[RoundStats] {
        &self.history
    }

    /// Online status of peer `l` (after the last `churn` step).
    pub fn is_online(&self, l: usize) -> bool {
        self.churn.is_online(l)
    }

    /// Execute `rounds` more gossip rounds.
    pub fn run(&mut self, rounds: usize) {
        for _ in 0..rounds {
            self.run_round();
        }
    }

    /// Execute a single round (Algorithm 4's outer loop body).
    pub fn run_round(&mut self) {
        self.churn.step();
        let p = self.states.len();
        let online = self.churn.online_mask(p);
        let stats = match self.mode {
            RoundMode::Sequential => self.round_sequential(&online),
            RoundMode::Matched => self.round_matched(&online),
        };
        self.history.push(stats);
        self.round += 1;
    }

    fn round_sequential(&mut self, online: &[bool]) -> RoundStats {
        let (exchanges, dropped, bytes) = fan_out_round(
            &mut self.states,
            &self.graph,
            online,
            self.fan_out,
            self.exchange_drop,
            &mut self.rng,
        );
        RoundStats {
            round: self.round,
            exchanges,
            dropped,
            online: online.iter().filter(|&&b| b).count(),
            bytes,
        }
    }

    fn round_matched(&mut self, online: &[bool]) -> RoundStats {
        let p = self.states.len();
        let mut partner: Vec<usize> = (0..p).collect();
        let order = self.rng.permutation(p);
        let mut exchanges = 0;
        let mut dropped = 0;
        for &l in &order {
            if !online[l] || partner[l] != l {
                continue;
            }
            let candidates: Vec<usize> = self
                .graph
                .neighbours(l)
                .iter()
                .copied()
                .filter(|&j| online[j] && partner[j] == j && j != l)
                .collect();
            if candidates.is_empty() {
                continue;
            }
            let j = candidates[self.rng.index(candidates.len())];
            if self.exchange_drop > 0.0 && self.rng.chance(self.exchange_drop) {
                dropped += 1;
                continue;
            }
            partner[l] = j;
            partner[j] = l;
            exchanges += 1;
        }
        // Dense batched averaging over the noninteracting pairs.
        let width = self.executor.preferred_width();
        let max_peers = self.executor.max_peers();
        if let Some(cap) = max_peers {
            assert!(
                p <= cap,
                "executor supports at most {cap} peers, got {p}"
            );
        }
        let mut dense = DenseRound::build(&mut self.states, &partner, width)
            .expect("dense build (positive-domain data)");
        self.executor
            .average(&mut dense)
            .expect("executor round failure");
        dense.write_back(&mut self.states);
        let bytes: usize = (0..p)
            .filter(|&l| partner[l] > l)
            .map(|l| {
                crate::sketch::codec::peer_state_wire_size(&self.states[l])
                    + crate::sketch::codec::peer_state_wire_size(&self.states[partner[l]])
            })
            .sum();
        RoundStats {
            round: self.round,
            exchanges,
            dropped,
            online: online.iter().filter(|&&b| b).count(),
            bytes,
        }
    }

    /// Query every peer for quantile `q` (the experiments' measurement).
    pub fn query_all(&self, q: f64) -> Vec<f64> {
        self.states
            .iter()
            .map(|s| s.query(q).expect("valid q, non-empty sketches"))
            .collect()
    }
}

/// One permutation-ordered atomic push–pull round over `states`
/// (Algorithm 4's inner loop) — the exchange discipline shared by the
/// simulation [`Protocol`] and the service layer's continuous
/// [`GossipLoop`](crate::service::GossipLoop).
///
/// Every peer with `online[l]` initiates exchanges with up to `fan_out`
/// distinct online neighbours in `graph`; each exchange is atomic
/// ([`PeerState::exchange`]) and may be cancelled with probability
/// `exchange_drop` (§7.2 failure injection, both endpoints keep their
/// state). Returns `(exchanges, dropped, bytes)` where `bytes` is the
/// codec-exact wire traffic of the push + pull frames.
pub fn fan_out_round<R: Rng>(
    states: &mut [PeerState],
    graph: &Graph,
    online: &[bool],
    fan_out: usize,
    exchange_drop: f64,
    rng: &mut R,
) -> (usize, usize, usize) {
    let p = states.len();
    assert_eq!(graph.len(), p, "graph/state size mismatch");
    assert_eq!(online.len(), p, "online mask size mismatch");
    let mut exchanges = 0;
    let mut dropped = 0;
    let mut bytes = 0usize;
    let order = rng.permutation(p);
    let mut scratch: Vec<usize> = Vec::new();
    for &l in &order {
        if !online[l] {
            continue;
        }
        let k = select_exchange_partners(graph, online, l, fan_out, &mut scratch, rng);
        for &j in scratch.iter().take(k) {
            if exchange_drop > 0.0 && rng.chance(exchange_drop) {
                dropped += 1;
                continue; // §7.2: cancelled exchange, both states kept
            }
            // Push carries the sender's pre-exchange state; the pull
            // reply carries the merged one (sizes computed around the
            // in-place exchange).
            bytes += crate::sketch::codec::peer_state_wire_size(&states[l]);
            {
                let (lo, hi) = states.split_at_mut(l.max(j));
                let (a, b) = if l < j {
                    (&mut lo[l], &mut hi[0])
                } else {
                    (&mut hi[0], &mut lo[j])
                };
                PeerState::exchange(a, b)
                    .expect("same alpha0 lineage by construction");
            }
            bytes += crate::sketch::codec::peer_state_wire_size(&states[j]);
            exchanges += 1;
        }
    }
    (exchanges, dropped, bytes)
}

/// Select up to `fan_out` distinct online neighbours of `l` into the
/// front of `scratch` (a caller-owned buffer, reused across initiators)
/// and return how many were selected.
///
/// This is Algorithm 4's partner draw — a partial Fisher–Yates over the
/// online neighbourhood — factored out so the simulation round above and
/// the service layer's transport-driven round
/// ([`GossipLoop`](crate::service::GossipLoop)) consume rng draws
/// **identically**: the refactored in-process loop reproduces the PR 2
/// exchange schedule bit for bit.
pub fn select_exchange_partners<R: Rng>(
    graph: &Graph,
    online: &[bool],
    l: usize,
    fan_out: usize,
    scratch: &mut Vec<usize>,
    rng: &mut R,
) -> usize {
    scratch.clear();
    scratch.extend(
        graph
            .neighbours(l)
            .iter()
            .copied()
            .filter(|&j| online[j]),
    );
    partial_fisher_yates(scratch, fan_out, rng)
}

/// Draw up to `fan_out` of `count` candidate *positions* into the front
/// of `scratch` (reset to `0..count` first) and return how many were
/// drawn — the same partial Fisher–Yates as
/// [`select_exchange_partners`], for callers whose candidate set is not
/// a graph neighbourhood (the membership plane's live member view).
/// Consumes one rng draw per selected partner, like the graph path.
pub fn draw_fan_out<R: Rng>(
    count: usize,
    fan_out: usize,
    scratch: &mut Vec<usize>,
    rng: &mut R,
) -> usize {
    scratch.clear();
    scratch.extend(0..count);
    partial_fisher_yates(scratch, fan_out, rng)
}

/// Partial Fisher–Yates: the first `min(fan_out, len)` entries of
/// `pool` become a uniform draw without replacement; returns that count.
fn partial_fisher_yates<R: Rng>(pool: &mut [usize], fan_out: usize, rng: &mut R) -> usize {
    if pool.is_empty() {
        return 0;
    }
    let k = fan_out.min(pool.len());
    for i in 0..k {
        let j = i + rng.index(pool.len() - i);
        pool.swap(i, j);
    }
    k
}

/// Build all peers' initial states, in parallel across available cores
/// (local stream processing is embarrassingly parallel).
fn init_states(
    datasets: &[Vec<f64>],
    alpha: f64,
    max_buckets: usize,
) -> anyhow::Result<Vec<PeerState>> {
    let n = datasets.len();
    let threads = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(n.max(1));
    if threads <= 1 || n < 64 {
        return (0..n)
            .map(|l| {
                PeerState::init(l, &datasets[l], alpha, max_buckets)
                    .map_err(anyhow::Error::from)
            })
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<PeerState>> = vec![None; n];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, slots) in out.chunks_mut(chunk).enumerate() {
            let lo = t * chunk;
            let data = &datasets[lo..(lo + slots.len())];
            handles.push(scope.spawn(move || {
                for (k, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(
                        PeerState::init(lo + k, &data[k], alpha, max_buckets)
                            .expect("valid sketch params"),
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("init worker panicked");
        }
    });
    Ok(out.into_iter().map(|s| s.expect("filled")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::data::{all_peer_datasets, DatasetKind};
    use crate::graph::paper_ba;
    use crate::metrics::{mean, variance_around};
    use crate::rng::default_rng;

    fn small_proto(peers: usize, seed: u64) -> Protocol {
        let mut cfg = ExperimentConfig::default();
        cfg.peers = peers;
        cfg.items_per_peer = 100;
        cfg.seed = seed;
        cfg.dataset = DatasetKind::Exponential;
        let master = default_rng(seed);
        let datasets =
            all_peer_datasets(cfg.dataset, peers, cfg.items_per_peer, &master);
        let mut grng = master.derive(0x6EA4);
        let graph = paper_ba(peers, &mut grng);
        Protocol::new(&cfg, graph, &datasets, &master).unwrap()
    }

    #[test]
    fn mass_conservation_without_churn() {
        // Invariant 5 (DESIGN.md): the sum (equivalently mean) of every
        // averaged quantity is invariant under exchanges.
        let mut p = small_proto(50, 1);
        let sum_n: f64 = p.states().iter().map(|s| s.n_tilde).sum();
        let sum_q: f64 = p.states().iter().map(|s| s.q_tilde).sum();
        let sum_c: f64 = p.states().iter().map(|s| s.sketch.count()).sum();
        p.run(10);
        let sum_n2: f64 = p.states().iter().map(|s| s.n_tilde).sum();
        let sum_q2: f64 = p.states().iter().map(|s| s.q_tilde).sum();
        let sum_c2: f64 = p.states().iter().map(|s| s.sketch.count()).sum();
        assert!((sum_n - sum_n2).abs() < 1e-6 * sum_n.abs());
        assert!((sum_q - sum_q2).abs() < 1e-9, "q mass {sum_q} -> {sum_q2}");
        assert!((sum_c - sum_c2).abs() < 1e-6 * sum_c.abs());
    }

    #[test]
    fn variance_contracts_near_jelasity_factor() {
        // Theorem 3 / §4.1: per-round variance reduction ≈ 1/(2√e) ≈ 0.303
        // for the permutation-based pair selection. Measured on q̃ (the
        // only scalar with non-zero initial variance: 1 at peer 0, else 0).
        // Loose band: the neighbour restriction on a BA overlay slows
        // mixing slightly.
        let mut p = small_proto(400, 2);
        let true_mean = 1.0 / 400.0;
        let mut factors = Vec::new();
        let mut prev = {
            let v: Vec<f64> = p.states().iter().map(|s| s.q_tilde).collect();
            variance_around(&v, true_mean)
        };
        for _ in 0..8 {
            p.run(1);
            let v: Vec<f64> = p.states().iter().map(|s| s.q_tilde).collect();
            let var = variance_around(&v, true_mean);
            if prev > 1e-30 {
                factors.push(var / prev);
            }
            prev = var;
        }
        let avg_factor = mean(&factors);
        assert!(
            (0.15..0.55).contains(&avg_factor),
            "mean contraction {avg_factor}, factors {factors:?}"
        );
    }

    #[test]
    fn matched_mode_also_converges() {
        let mut p = small_proto(80, 3);
        p.set_mode(RoundMode::Matched);
        let true_mean = mean(
            &p.states()
                .iter()
                .map(|s| s.n_tilde)
                .collect::<Vec<_>>(),
        );
        p.run(40);
        for s in p.states() {
            assert!(
                (s.n_tilde - true_mean).abs() < 1e-6 * true_mean.max(1.0),
                "peer {} n_tilde {} vs {}",
                s.id,
                s.n_tilde,
                true_mean
            );
        }
    }

    #[test]
    fn exchange_drop_slows_but_preserves_mass() {
        let mut p = small_proto(60, 4);
        p.set_exchange_drop(0.5);
        let sum_q: f64 = p.states().iter().map(|s| s.q_tilde).sum();
        p.run(10);
        let sum_q2: f64 = p.states().iter().map(|s| s.q_tilde).sum();
        assert!((sum_q - sum_q2).abs() < 1e-9);
        let dropped: usize = p.history().iter().map(|h| h.dropped).sum();
        assert!(dropped > 0, "injection should cancel some exchanges");
    }

    #[test]
    fn history_records_rounds() {
        let mut p = small_proto(30, 5);
        p.run(7);
        assert_eq!(p.history().len(), 7);
        assert_eq!(p.round(), 7);
        assert!(p.history().iter().all(|h| h.online == 30));
        assert!(p.history().iter().all(|h| h.exchanges > 0));
    }

    #[test]
    fn offline_peers_do_not_exchange() {
        let mut cfg = ExperimentConfig::default();
        cfg.peers = 40;
        cfg.items_per_peer = 50;
        cfg.churn = crate::churn::ChurnKind::FailStop;
        let master = default_rng(6);
        let datasets =
            all_peer_datasets(DatasetKind::Uniform, 40, 50, &master);
        let mut grng = master.derive(0x6EA4);
        let graph = paper_ba(40, &mut grng);
        let mut p = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
        p.run(30);
        let h = p.history();
        // With fail&stop, online count is non-increasing.
        for w in h.windows(2) {
            assert!(w[1].online <= w[0].online);
        }
    }
}
