//! Batched averaging-round executors.
//!
//! [`RoundMode::Matched`](super::RoundMode) rounds operate on a **dense
//! network state matrix**: row `l` is peer `l`'s state `[bucket counters
//! (window W) | Ñ | q̃]`, all rows sharing one γ lineage and one index
//! window. The matrix plus a partner vector feed a [`RoundExecutor`]:
//!
//! * [`NativeExecutor`] — pure-Rust pairwise averaging (reference).
//! * [`PjrtExecutor`] — the AOT-compiled JAX/Pallas `avg_pairs` artifact
//!   executed on the PJRT CPU client; numerics are f32, everything else is
//!   identical (asserted by `rust/tests/integration_runtime.rs`).

use super::state::PeerState;
use crate::sketch::Store;
use anyhow::{bail, Result};

/// Dense formulation of one matched gossip round.
#[derive(Debug)]
pub struct DenseRound {
    /// Live peers (rows 0..peers; executors may pad beyond).
    pub peers: usize,
    /// Bucket window width W (columns 0..W are counters).
    pub width: usize,
    /// Logarithmic index of column 0.
    pub offset: i64,
    /// Row-major `[peers × (width + 2)]`: counters, then Ñ, then q̃.
    pub matrix: Vec<f64>,
    /// `partner[l]` = exchange partner of `l` (== `l` when idle). The
    /// vector is an involution with no fixed-point violations: pairs are
    /// noninteracting (Definition 9).
    pub partner: Vec<usize>,
}

impl DenseRound {
    /// Columns per row.
    pub fn cols(&self) -> usize {
        self.width + 2
    }

    /// Build the dense matrix from peer states:
    ///
    /// 1. align every sketch to the deepest collapse lineage present;
    /// 2. compute the global index window; if `max_width` is given,
    ///    collapse **all** peers until the window fits (this may collapse
    ///    earlier than the sequential path would — the fixed point is
    ///    unchanged, resolution is what a global merge would settle to);
    /// 3. write counters + scalars row-major.
    ///
    /// Fails if any sketch holds zero/negative-domain weight: the dense
    /// path (like Algorithm 6 and the paper's experiments) covers ℝ>0.
    pub fn build(
        states: &mut [PeerState],
        partner: &[usize],
        max_width: Option<usize>,
    ) -> Result<Self> {
        assert_eq!(states.len(), partner.len());
        for (l, s) in states.iter().enumerate() {
            if s.sketch.zero_weight() != 0.0 || !s.sketch.negative_store().is_empty() {
                bail!("dense round: peer {l} holds non-positive-domain weight");
            }
            if partner[l] != l {
                assert_eq!(partner[partner[l]], l, "partner vector not an involution");
            }
        }
        let deepest = states
            .iter()
            .map(|s| s.sketch.collapses())
            .max()
            .unwrap_or(0);
        for s in states.iter_mut() {
            s.sketch.align_to_collapses(deepest);
        }
        let window = |states: &[PeerState]| -> Option<(i64, i64)> {
            let mut lo = i64::MAX;
            let mut hi = i64::MIN;
            for s in states {
                if let (Some(a), Some(b)) = (
                    s.sketch.positive_store().min_index(),
                    s.sketch.positive_store().max_index(),
                ) {
                    lo = lo.min(a);
                    hi = hi.max(b);
                }
            }
            (lo <= hi).then_some((lo, hi))
        };
        let (mut lo, mut hi) = window(states)
            .ok_or_else(|| anyhow::anyhow!("dense round: all sketches empty"))?;
        if let Some(w) = max_width {
            while (hi - lo + 1) as usize > w {
                for s in states.iter_mut() {
                    s.sketch.force_collapse();
                }
                let (l2, h2) = window(states).expect("non-empty");
                lo = l2;
                hi = h2;
            }
        }
        let width = max_width.unwrap_or((hi - lo + 1) as usize);
        let peers = states.len();
        let cols = width + 2;
        let mut matrix = vec![0.0; peers * cols];
        for (l, s) in states.iter().enumerate() {
            let row = &mut matrix[l * cols..(l + 1) * cols];
            s.sketch.positive_store().for_each(|i, c| {
                let k = (i - lo) as usize;
                debug_assert!(k < width);
                row[k] = c;
            });
            row[width] = s.n_tilde;
            row[width + 1] = s.q_tilde;
        }
        Ok(Self {
            peers,
            width,
            offset: lo,
            matrix,
            partner: partner.to_vec(),
        })
    }

    /// Write the (averaged) matrix back into the peer states.
    pub fn write_back(&self, states: &mut [PeerState]) {
        let cols = self.cols();
        for (l, s) in states.iter_mut().enumerate() {
            let row = &self.matrix[l * cols..(l + 1) * cols];
            s.sketch.set_positive_dense(self.offset, &row[..self.width]);
            s.n_tilde = row[self.width];
            s.q_tilde = row[self.width + 1];
        }
    }
}

/// Strategy executing the dense averaging of one matched round.
pub trait RoundExecutor {
    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;

    /// Fixed bucket-window width this executor needs (None = any width).
    fn preferred_width(&self) -> Option<usize>;

    /// Maximum number of peers supported (None = unbounded).
    fn max_peers(&self) -> Option<usize>;

    /// Average all paired rows in place: for every pair `(l, j)`,
    /// rows l and j both become `(row_l + row_j) / 2`.
    fn average(&mut self, round: &mut DenseRound) -> Result<()>;
}

/// Pure-Rust reference executor.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeExecutor;

impl RoundExecutor for NativeExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn preferred_width(&self) -> Option<usize> {
        None
    }

    fn max_peers(&self) -> Option<usize> {
        None
    }

    fn average(&mut self, round: &mut DenseRound) -> Result<()> {
        let cols = round.cols();
        for l in 0..round.peers {
            let j = round.partner[l];
            if j <= l {
                continue; // idle (j == l) or already handled (j < l)
            }
            let (a, b) = round.matrix.split_at_mut(j * cols);
            let row_l = &mut a[l * cols..(l + 1) * cols];
            let row_j = &mut b[..cols];
            for (x, y) in row_l.iter_mut().zip(row_j.iter_mut()) {
                let avg = 0.5 * (*x + *y);
                *x = avg;
                *y = avg;
            }
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_executor::PjrtExecutor;

/// PJRT executor stub: the `pjrt` feature is off, so discovery always
/// fails with a clear message and callers degrade to the native path.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct PjrtExecutor {
    _never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtExecutor {
    /// Always fails: PJRT support is not compiled into this build.
    pub fn discover(_peers: usize) -> Result<Self> {
        bail!(
            "PJRT executor unavailable: support not compiled in (rebuild \
             with `--features pjrt` and an `xla` path dependency)"
        )
    }

    /// Always fails: PJRT support is not compiled into this build.
    pub fn from_artifact(_name: &str, _p_cap: usize, _w_cap: usize) -> Result<Self> {
        Self::discover(0)
    }
}

#[cfg(not(feature = "pjrt"))]
impl RoundExecutor for PjrtExecutor {
    fn name(&self) -> &'static str {
        match self._never {}
    }

    fn preferred_width(&self) -> Option<usize> {
        match self._never {}
    }

    fn max_peers(&self) -> Option<usize> {
        match self._never {}
    }

    fn average(&mut self, _round: &mut DenseRound) -> Result<()> {
        match self._never {}
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_executor {
    use super::{DenseRound, RoundExecutor};
    use crate::runtime::{list_shaped_artifacts, Executable, Runtime};
    use anyhow::{bail, Context, Result};

    /// PJRT executor: runs the `avg_pairs_p<P>_w<W>` artifact.
    pub struct PjrtExecutor {
        runtime: Runtime,
        exe: std::rc::Rc<Executable>,
        /// Artifact's static peer capacity.
        p_cap: usize,
        /// Artifact's static bucket window.
        w_cap: usize,
    }

    impl std::fmt::Debug for PjrtExecutor {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "PjrtExecutor(p={}, w={})", self.p_cap, self.w_cap)
        }
    }

    impl PjrtExecutor {
        /// Pick the smallest `avg_pairs` artifact that fits `peers`, compile
        /// it, and return the executor.
        pub fn discover(peers: usize) -> Result<Self> {
            let shapes = list_shaped_artifacts("avg_pairs");
            let (p_cap, w_cap, path) = shapes
                .into_iter()
                .find(|(p, _, _)| *p >= peers)
                .with_context(|| {
                    format!(
                        "no avg_pairs artifact with P >= {peers} in {} (run `make artifacts`)",
                        crate::runtime::artifacts_dir().display()
                    )
                })?;
            let mut runtime = Runtime::cpu()?;
            let exe = runtime.load_path(&path)?;
            Ok(Self {
                runtime,
                exe,
                p_cap,
                w_cap,
            })
        }

        /// Build directly from a known artifact (tests).
        pub fn from_artifact(name: &str, p_cap: usize, w_cap: usize) -> Result<Self> {
            let mut runtime = Runtime::cpu()?;
            let exe = runtime.load(name)?;
            Ok(Self {
                runtime,
                exe,
                p_cap,
                w_cap,
            })
        }

        /// The underlying runtime (for diagnostics).
        pub fn runtime(&self) -> &Runtime {
            &self.runtime
        }
    }

    impl RoundExecutor for PjrtExecutor {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn preferred_width(&self) -> Option<usize> {
            Some(self.w_cap)
        }

        fn max_peers(&self) -> Option<usize> {
            Some(self.p_cap)
        }

        fn average(&mut self, round: &mut DenseRound) -> Result<()> {
            if round.width != self.w_cap {
                bail!(
                    "dense width {} != artifact window {}",
                    round.width,
                    self.w_cap
                );
            }
            if round.peers > self.p_cap {
                bail!("{} peers > artifact capacity {}", round.peers, self.p_cap);
            }
            let cols = round.cols();
            // Pad rows to the artifact's static P; padded rows self-pair.
            let mut states_f32 = vec![0f32; self.p_cap * cols];
            for (dst, src) in states_f32
                .chunks_mut(cols)
                .zip(round.matrix.chunks(cols))
            {
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d = *s as f32;
                }
            }
            let mut partner_i32: Vec<i32> = (0..self.p_cap as i32).collect();
            for (l, &j) in round.partner.iter().enumerate() {
                partner_i32[l] = j as i32;
            }
            let states_lit = xla::Literal::vec1(&states_f32)
                .reshape(&[self.p_cap as i64, cols as i64])?;
            let partner_lit = xla::Literal::vec1(&partner_i32);
            let out = self.exe.run1(&[states_lit, partner_lit])?;
            let flat: Vec<f32> = out.to_vec()?;
            if flat.len() != self.p_cap * cols {
                bail!(
                    "artifact returned {} elements, expected {}",
                    flat.len(),
                    self.p_cap * cols
                );
            }
            for (dst, src) in round
                .matrix
                .chunks_mut(cols)
                .zip(flat.chunks(cols))
            {
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d = *s as f64;
                }
            }
            Ok(())
        }
    }
} // mod pjrt_executor

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::PeerState;

    fn mk_states() -> Vec<PeerState> {
        vec![
            PeerState::init(0, &[1.0, 2.0, 4.0], 0.01, 64).unwrap(),
            PeerState::init(1, &[8.0, 16.0], 0.01, 64).unwrap(),
            PeerState::init(2, &[32.0], 0.01, 64).unwrap(),
            PeerState::init(3, &[64.0, 128.0], 0.01, 64).unwrap(),
        ]
    }

    #[test]
    fn dense_round_trip_is_lossless() {
        let mut states = mk_states();
        let before: Vec<_> = states
            .iter()
            .map(|s| (s.sketch.positive_store().entries(), s.n_tilde, s.q_tilde))
            .collect();
        let partner = vec![0, 1, 2, 3];
        let dense = DenseRound::build(&mut states, &partner, None).unwrap();
        dense.write_back(&mut states);
        for (s, (e, n, q)) in states.iter().zip(&before) {
            assert_eq!(&s.sketch.positive_store().entries(), e);
            assert_eq!(s.n_tilde, *n);
            assert_eq!(s.q_tilde, *q);
        }
    }

    #[test]
    fn native_average_pairs_rows() {
        let mut states = mk_states();
        let n_before: Vec<f64> = states.iter().map(|s| s.n_tilde).collect();
        let partner = vec![1, 0, 3, 2];
        let mut dense = DenseRound::build(&mut states, &partner, None).unwrap();
        NativeExecutor.average(&mut dense).unwrap();
        dense.write_back(&mut states);
        assert_eq!(states[0].n_tilde, 0.5 * (n_before[0] + n_before[1]));
        assert_eq!(states[0].n_tilde, states[1].n_tilde);
        assert_eq!(states[2].n_tilde, 0.5 * (n_before[2] + n_before[3]));
        // q mass conserved.
        let q_sum: f64 = states.iter().map(|s| s.q_tilde).sum();
        assert!((q_sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dense_respects_max_width_by_collapsing() {
        let mut states = mk_states();
        // Natural window for values 1..128 at alpha=0.01 spans ~350
        // indices; cap at 64 must trigger collapses.
        let partner = vec![0, 1, 2, 3];
        let dense = DenseRound::build(&mut states, &partner, Some(64)).unwrap();
        assert_eq!(dense.width, 64);
        assert!(states.iter().all(|s| s.sketch.collapses() > 0));
        // Total count preserved through collapse + round trip.
        dense.write_back(&mut states);
        let total: f64 = states.iter().map(|s| s.sketch.count()).sum();
        assert!((total - 8.0).abs() < 1e-9);
    }

    #[test]
    fn dense_rejects_non_positive_domain() {
        let mut states = mk_states();
        states[1].sketch.insert(-5.0);
        let partner = vec![0, 1, 2, 3];
        assert!(DenseRound::build(&mut states, &partner, None).is_err());
    }

    #[test]
    #[should_panic(expected = "involution")]
    fn dense_rejects_non_involution_partner() {
        let mut states = mk_states();
        let partner = vec![1, 2, 0, 3];
        let _ = DenseRound::build(&mut states, &partner, None);
    }
}
