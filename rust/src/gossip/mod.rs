//! Distributed UDDSketch — the paper's gossip protocol (§5).
//!
//! Every peer holds a [`PeerState`] `(S_l, Ñ_l, q̃_l)` (Algorithm 3). Each
//! synchronous round, peers engage in atomic push–pull exchanges with
//! random neighbours (Algorithm 4); an exchange replaces both states with
//! their average: sketches merge bucket-wise with weight ½ (Algorithm 5),
//! `Ñ` and `q̃` average arithmetically. Distributed averaging drives every
//! peer to the average of the round-0 states (Prop. 4), from which
//! Algorithm 6 reconstructs the *global* sketch via the network-size
//! estimate `p̃ = ⌈1/q̃⌉` and answers quantile queries.

#![forbid(unsafe_code)]

mod engine;
mod executor;
mod state;

pub use engine::{draw_fan_out, fan_out_round, select_exchange_partners, Protocol, RoundMode, RoundStats};
pub use executor::{DenseRound, NativeExecutor, PjrtExecutor, RoundExecutor};
pub use state::{GossipSketch, PeerState};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::data::{all_peer_datasets, DatasetKind};
    use crate::graph::paper_ba;
    use crate::metrics::relative_error;
    use crate::rng::default_rng;
    use crate::sketch::UddSketch;

    /// Full-protocol convergence: after enough rounds every peer answers
    /// quantile queries with (near-)zero relative error vs the sequential
    /// sketch over the union of the local streams — the paper's headline
    /// claim (§6, §7).
    #[test]
    fn protocol_converges_to_sequential() {
        let mut cfg = ExperimentConfig::default();
        cfg.peers = 64;
        cfg.items_per_peer = 500;
        cfg.rounds = 30;
        cfg.dataset = DatasetKind::Uniform;
        cfg.alpha = 0.001;
        let master = default_rng(cfg.seed);
        let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);

        let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
        for d in &datasets {
            seq.extend(d);
        }

        let mut graph_rng = master.derive(0x6EA4);
        let graph = paper_ba(cfg.peers, &mut graph_rng);
        let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
        proto.run(cfg.rounds);

        for &q in &[0.01, 0.5, 0.99] {
            let truth = seq.quantile(q).unwrap();
            for l in 0..cfg.peers {
                let est = proto.states()[l].query(q).unwrap();
                let re = relative_error(est, truth);
                assert!(
                    re < 1e-6,
                    "peer {l} q={q}: est {est} vs seq {truth} (re={re})"
                );
            }
        }
    }

    /// The adversarial construction needs more rounds but still converges
    /// (paper Figs. 1–2).
    #[test]
    fn adversarial_converges_slower_but_converges() {
        let mut cfg = ExperimentConfig::default();
        cfg.peers = 300; // 3 disjoint-bucket groups
        cfg.items_per_peer = 200;
        cfg.dataset = DatasetKind::Adversarial;
        let master = default_rng(7);
        let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
        let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
        for d in &datasets {
            seq.extend(d);
        }
        let mut graph_rng = master.derive(0x6EA4);
        let graph = paper_ba(cfg.peers, &mut graph_rng);
        let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();

        proto.run(5);
        let truth = seq.quantile(0.5).unwrap();
        let early: f64 = (0..cfg.peers)
            .map(|l| relative_error(proto.states()[l].query(0.5).unwrap(), truth))
            .sum::<f64>()
            / cfg.peers as f64;

        proto.run(30);
        let late: f64 = (0..cfg.peers)
            .map(|l| relative_error(proto.states()[l].query(0.5).unwrap(), truth))
            .sum::<f64>()
            / cfg.peers as f64;

        assert!(
            late < early / 10.0 || late < 1e-9,
            "ARE should collapse: early {early} late {late}"
        );
        assert!(late < 1e-3, "late ARE {late}");
    }
}
