//! In-tree utility substrates (the offline registry carries none of the
//! usual helper crates — DESIGN.md §6).

#![forbid(unsafe_code)]

pub mod bench;
pub mod csv;
pub mod plot;
pub mod testkit;

use std::time::Instant;

/// Wall-clock stopwatch with split support.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing.
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Format a float compactly for tables (`1.234e-5` / `0.01234` style).
pub fn fmt_g(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e-3 && x.abs() < 1e6 {
        let s = format!("{x:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(sw.millis() >= 9.0);
        let lap = sw.lap();
        assert!(lap >= 0.009);
        assert!(sw.millis() < 10.0);
    }

    #[test]
    fn fmt_g_shapes() {
        assert_eq!(fmt_g(0.0), "0");
        assert_eq!(fmt_g(0.5), "0.5");
        assert_eq!(fmt_g(1.0), "1");
        assert!(fmt_g(1.23e-9).contains('e'));
    }
}
