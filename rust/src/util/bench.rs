//! Micro-benchmark harness (criterion is unavailable offline — DESIGN.md
//! §6). Used by every target in `benches/` with `harness = false`.
//!
//! Methodology: warmup runs, then `samples` timed batches; reports median,
//! mean, and p10/p90 spread plus derived throughput. Deterministic target
//! selection via `--bench-filter <substr>` on the command line.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// 10th percentile seconds.
    pub p10_s: f64,
    /// 90th percentile seconds.
    pub p90_s: f64,
    /// Items processed per iteration (for throughput lines; 0 = skip).
    pub items_per_iter: u64,
}

impl BenchResult {
    /// Items/second at the median.
    pub fn throughput(&self) -> Option<f64> {
        if self.items_per_iter > 0 && self.median_s > 0.0 {
            Some(self.items_per_iter as f64 / self.median_s)
        } else {
            None
        }
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2} G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} K/s", r / 1e3)
    } else {
        format!("{r:.1} /s")
    }
}

/// Bench runner: collects cases, honours `--bench-filter`, prints a table.
pub struct Bencher {
    filter: Option<String>,
    warmup: usize,
    samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Construct from env/args. Honours `DUDD_BENCH_SAMPLES` and
    /// `--bench-filter <substr>` (cargo bench passes unknown args through).
    pub fn new() -> Self {
        let args: Vec<String> = std::env::args().collect();
        let filter = args
            .iter()
            .position(|a| a == "--bench-filter")
            .and_then(|i| args.get(i + 1).cloned())
            .or_else(|| {
                // `cargo bench -- substring` convention: first free arg.
                args.iter()
                    .skip(1)
                    .find(|a| !a.starts_with('-') && *a != "--bench")
                    .cloned()
            });
        let samples = std::env::var("DUDD_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(15);
        Self {
            filter,
            warmup: 3,
            samples,
            results: Vec::new(),
        }
    }

    /// Time `f` (whole-batch closure); `items` is the per-iteration work
    /// amount for throughput reporting.
    pub fn case(&mut self, name: &str, items: u64, mut f: impl FnMut()) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| times[((p * (times.len() - 1) as f64).round()) as usize];
        let result = BenchResult {
            name: name.to_string(),
            median_s: pct(0.5),
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            p10_s: pct(0.1),
            p90_s: pct(0.9),
            items_per_iter: items,
        };
        let tp = result
            .throughput()
            .map(|r| format!("  ({})", fmt_rate(r)))
            .unwrap_or_default();
        println!(
            "bench {:<44} median {:>10}  p10 {:>10}  p90 {:>10}{}",
            result.name,
            fmt_time(result.median_s),
            fmt_time(result.p10_s),
            fmt_time(result.p90_s),
            tp
        );
        self.results.push(result);
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize the collected results as a small hand-rolled JSON
    /// document (serde is unavailable offline) — the format of the
    /// `BENCH_*.json` baselines checked into the repository.
    pub fn to_json(&self, suite: &str) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"suite\": \"{suite}\",\n"));
        out.push_str(&format!("  \"samples\": {},\n", self.samples));
        out.push_str("  \"cases\": [\n");
        for (k, r) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_s\": {:e}, \"mean_s\": {:e}, \
                 \"p10_s\": {:e}, \"p90_s\": {:e}, \"items_per_iter\": {}}}{}\n",
                r.name,
                r.median_s,
                r.mean_s,
                r.p10_s,
                r.p90_s,
                r.items_per_iter,
                if k + 1 < self.results.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Print the closing summary line expected in bench logs; when the
    /// `DUDD_BENCH_JSON` environment variable names a path, also record
    /// the results there as JSON (how `BENCH_transport.json` & co. are
    /// refreshed).
    pub fn finish(&self, suite: &str) {
        println!(
            "suite {suite}: {} case(s), samples={} (set DUDD_BENCH_SAMPLES to change)",
            self.results.len(),
            self.samples
        );
        if let Ok(path) = std::env::var("DUDD_BENCH_JSON") {
            match std::fs::write(&path, self.to_json(suite)) {
                Ok(()) => println!("suite {suite}: json baseline written to {path}"),
                Err(e) => eprintln!("suite {suite}: json baseline write failed: {e}"),
            }
        }
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box is stable since 1.66 — thin wrapper for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_case_records_result() {
        let mut b = Bencher {
            filter: None,
            warmup: 1,
            samples: 5,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.case("smoke", 100, || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert_eq!(b.results().len(), 1);
        let r = &b.results()[0];
        assert!(r.median_s >= 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.p10_s <= r.p90_s);
    }

    #[test]
    fn filter_skips_nonmatching() {
        let mut b = Bencher {
            filter: Some("match-me".into()),
            warmup: 0,
            samples: 1,
            results: Vec::new(),
        };
        b.case("other", 0, || {});
        assert!(b.results().is_empty());
        b.case("does-match-me", 0, || {});
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_serialization_is_well_formed() {
        let mut b = Bencher {
            filter: None,
            warmup: 0,
            samples: 2,
            results: Vec::new(),
        };
        b.case("alpha", 10, || {});
        b.case("beta", 0, || {});
        let json = b.to_json("suite-x");
        assert!(json.contains("\"suite\": \"suite-x\""), "{json}");
        assert!(json.contains("\"name\": \"alpha\""), "{json}");
        assert!(json.contains("\"items_per_iter\": 10"), "{json}");
        // Exactly one separating comma between the two case objects.
        assert_eq!(json.matches("},\n").count(), 1, "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
    }

    #[test]
    fn formatting() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-5).ends_with("µs"));
        assert!(fmt_time(5e-2).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
        assert!(fmt_rate(2e9).ends_with("G/s"));
        assert!(fmt_rate(2e6).ends_with("M/s"));
    }
}
