//! Mini property-testing kit (proptest is unavailable offline — DESIGN.md
//! §6). Deterministic: every case derives from a fixed master seed, so
//! failures are reproducible; on failure the kit reports the failing case
//! seed and a rerun hint, and performs a simple input-halving shrink for
//! vector generators.

use crate::rng::{default_rng, Xoshiro256pp};

/// Number of cases per property (override with `DUDD_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("DUDD_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` generated inputs. `gen` builds an input from a
/// per-case RNG; `prop` returns `Err(msg)` to signal failure.
///
/// Panics with the case seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    master_seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Xoshiro256pp) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let master = default_rng(master_seed);
    for case in 0..cases {
        let mut rng = master.derive(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (master_seed={master_seed}): {msg}\n\
                 input: {input:?}\n\
                 rerun: seed the generator with derive({case})"
            );
        }
    }
}

/// Like [`forall`] for `Vec<f64>` inputs, with halving shrink: on failure
/// the kit tries successively smaller prefixes/suffixes and reports the
/// smallest failing input found.
pub fn forall_vec(
    name: &str,
    master_seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Xoshiro256pp) -> Vec<f64>,
    mut prop: impl FnMut(&[f64]) -> Result<(), String>,
) {
    let master = default_rng(master_seed);
    for case in 0..cases {
        let mut rng = master.derive(case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink: binary chop from both ends while still failing.
            let mut best = input.clone();
            let mut msg = first_msg;
            loop {
                let mut shrunk = false;
                for candidate in [
                    best[..best.len() / 2].to_vec(),
                    best[best.len() / 2..].to_vec(),
                    best[..best.len().saturating_sub(1)].to_vec(),
                ] {
                    if candidate.len() < best.len() && !candidate.is_empty() {
                        if let Err(m) = prop(&candidate) {
                            best = candidate;
                            msg = m;
                            shrunk = true;
                            break;
                        }
                    }
                }
                if !shrunk {
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case {case} (master_seed={master_seed}): {msg}\n\
                 shrunk input ({} items): {:?}",
                best.len(),
                &best[..best.len().min(32)]
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::{Rng, Xoshiro256pp};

    /// Vector of positive log-uniform values across `decades` decades
    /// ending at 10^`hi_exp`.
    pub fn log_uniform_vec(
        rng: &mut Xoshiro256pp,
        max_len: usize,
        decades: f64,
        hi_exp: f64,
    ) -> Vec<f64> {
        let len = 1 + rng.index(max_len.max(1));
        (0..len)
            .map(|_| 10f64.powf(hi_exp - decades * rng.next_f64()))
            .collect()
    }

    /// Vector of uniform values in [lo, hi).
    pub fn uniform_vec(
        rng: &mut Xoshiro256pp,
        max_len: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let len = 1 + rng.index(max_len.max(1));
        (0..len).map(|_| lo + (hi - lo) * rng.next_f64()).collect()
    }

    /// A quantile parameter in [0, 1].
    pub fn quantile(rng: &mut Xoshiro256pp) -> f64 {
        rng.next_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn passing_property_completes() {
        forall(
            "sum-commutes",
            1,
            32,
            |r| (r.next_f64(), r.next_f64()),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("non-commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_context() {
        forall(
            "always-fails",
            2,
            8,
            |r| r.next_f64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input (1 items)")]
    fn vec_property_shrinks() {
        // Fails whenever the input contains a value > 0.5; shrinker should
        // get down to a single offending element.
        forall_vec(
            "has-large-element",
            3,
            16,
            |r| super::gen::uniform_vec(r, 64, 0.0, 1.0),
            |xs| {
                if xs.iter().any(|&x| x > 0.5) {
                    Err("large".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn generators_in_bounds() {
        let mut r = default_rng(4);
        let v = gen::log_uniform_vec(&mut r, 50, 3.0, 2.0);
        assert!(!v.is_empty() && v.len() <= 50);
        assert!(v.iter().all(|&x| x > 0.099 && x <= 100.0 * 1.001));
        let u = gen::uniform_vec(&mut r, 10, 5.0, 6.0);
        assert!(u.iter().all(|&x| (5.0..6.0).contains(&x)));
    }
}
