//! Minimal CSV writer for the experiment harness output.

use std::io::Write;
use std::path::Path;

/// Accumulates rows and writes RFC-4180-ish CSV (quotes fields containing
/// separators/quotes/newlines).
#[derive(Debug, Default)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// New writer with a header row.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the width differs from the header.
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "CSV row width {} != header width {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields.to_vec());
    }

    /// Convenience: append a row of display-able values.
    pub fn row_display<T: std::fmt::Display>(&mut self, fields: &[T]) {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn escape(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Render to a CSV string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let line = |fields: &[String]| -> String {
            fields
                .iter()
                .map(|f| Self::escape(f))
                .collect::<Vec<_>>()
                .join(",")
        };
        out.push_str(&line(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "2".into()]);
        w.row_display(&[3.5, 4.5]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.to_string(), "a,b\n1,2\n3.5,4.5\n");
    }

    #[test]
    fn escapes_specials() {
        let mut w = CsvWriter::new(&["x"]);
        w.row(&["hello, \"world\"".into()]);
        assert_eq!(w.to_string(), "x\n\"hello, \"\"world\"\"\"\n");
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".into()]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("duddsketch_csv_test");
        let path = dir.join("out.csv");
        let mut w = CsvWriter::new(&["n"]);
        w.row_display(&[1]);
        w.write_to(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "n\n1\n");
    }
}
