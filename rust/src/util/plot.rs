//! ASCII rendering of the paper's box-and-whisker figures.
//!
//! The harness prints each figure panel as rows of box plots over a
//! log-scaled error axis, which makes "errors collapse to zero as rounds
//! grow" visible directly in the terminal / EXPERIMENTS.md.

use crate::metrics::BoxSummary;

/// One labelled box in a panel.
#[derive(Debug, Clone)]
pub struct BoxRow {
    /// Row label (e.g. the quantile "q=0.50").
    pub label: String,
    /// The summary to draw.
    pub summary: BoxSummary,
}

/// Render rows of box plots on a shared log10 axis.
///
/// `floor` clamps zero/subnormal errors for the log axis (the paper's
/// figures bottom out similarly); a value entirely at the floor renders as
/// a single `|` at the left edge.
pub fn render_boxes(title: &str, rows: &[BoxRow], width: usize, floor: f64) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if rows.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let lx = |v: f64| v.max(floor).log10();
    let lo = rows
        .iter()
        .map(|r| lx(r.summary.min))
        .fold(f64::MAX, f64::min);
    let hi = rows
        .iter()
        .map(|r| lx(r.summary.max))
        .fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-9);
    let col = |v: f64| -> usize {
        (((lx(v) - lo) / span) * (width.saturating_sub(1)) as f64).round() as usize
    };
    let label_w = rows.iter().map(|r| r.label.len()).max().unwrap_or(0);
    for r in rows {
        let s = &r.summary;
        let mut line = vec![b' '; width];
        let (wl, q1, md, q3, wh) =
            (col(s.whisker_lo), col(s.q1), col(s.median), col(s.q3), col(s.whisker_hi));
        for c in line.iter_mut().take(q1).skip(wl) {
            *c = b'-';
        }
        for c in line.iter_mut().take(wh + 1).skip(q3) {
            *c = b'-';
        }
        for c in line.iter_mut().take(q3 + 1).skip(q1) {
            *c = b'=';
        }
        line[wl] = b'|';
        line[wh.min(width - 1)] = b'|';
        line[md.min(width - 1)] = b'#';
        out.push_str(&format!(
            "  {:label_w$} [{}] med={:.2e}\n",
            r.label,
            String::from_utf8(line).expect("ascii"),
            s.median,
        ));
    }
    out.push_str(&format!(
        "  {:label_w$} axis: log10 err in [{:.1}, {:.1}]\n",
        "", lo, hi
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(vals: &[f64]) -> BoxSummary {
        BoxSummary::from_data(vals).unwrap()
    }

    #[test]
    fn renders_rows_with_markers() {
        let rows = vec![
            BoxRow {
                label: "q=0.5".into(),
                summary: summary(&[1e-6, 1e-5, 1e-4, 1e-3]),
            },
            BoxRow {
                label: "q=0.99".into(),
                summary: summary(&[1e-4, 1e-3, 1e-2]),
            },
        ];
        let s = render_boxes("demo", &rows, 60, 1e-12);
        assert!(s.contains("demo"));
        assert!(s.contains('#'));
        assert!(s.contains("q=0.99"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn empty_rows_safe() {
        let s = render_boxes("none", &[], 40, 1e-12);
        assert!(s.contains("(no data)"));
    }

    #[test]
    fn degenerate_all_zero_errors() {
        let rows = vec![BoxRow {
            label: "q".into(),
            summary: summary(&[0.0, 0.0, 0.0]),
        }];
        let s = render_boxes("zeros", &rows, 40, 1e-12);
        assert!(s.contains('#'));
    }
}
