//! # DUDDSketch — distributed P2P quantile tracking with relative value error
//!
//! Production-oriented reproduction of *"Distributed P2P quantile tracking
//! with relative value error"* (Pulimeno, Epicoco, Cafaro — CS.DC 2025).
//!
//! The crate provides:
//!
//! * [`sketch`] — the sequential [`sketch::UddSketch`] (uniform collapse,
//!   turnstile model) and its predecessor baseline [`sketch::DdSketch`]
//!   (collapse-first-two), both α-relative-value-error quantile summaries,
//!   plus an exact oracle for validation.
//! * [`gossip`] — the paper's contribution: a synchronous, fully
//!   decentralized gossip protocol (atomic push–pull distributed averaging,
//!   Algorithms 3–6) that drives every peer's local sketch to the global
//!   sketch over an unstructured P2P overlay.
//! * [`graph`] — Barabási–Albert and Erdős–Rényi overlay generators.
//! * [`churn`] — Fail&Stop and Yao (shifted-Pareto / exponential rejoin)
//!   churn models of §7.2.
//! * [`data`] — the four synthetic workloads of Table 1 and the *power*
//!   dataset (UCI household power surrogate/loader).
//! * [`service`] — the production ingest path: a multi-threaded
//!   quantile-tracking service with N sharded ingest workers (bounded
//!   mpsc batching, a private `UddSketch` per shard), exact epoch folds
//!   via sketch mergeability, lock-free epoch-stamped snapshot
//!   publication for `quantile`/`quantiles`/`cdf` queries that never
//!   block ingest, an optional sliding-window mode (ring of per-interval
//!   sub-sketches merged on demand), adapters fronting a gossip peer
//!   with the live snapshot, the continuous gossip loop
//!   ([`service::GossipLoop`]) that keeps a fleet converged on a
//!   network-wide [`service::GlobalView`] while ingest continues, and
//!   the transport layer ([`service::transport`]) that lets real nodes
//!   join that fleet over TCP — construction via the fluent
//!   [`service::Node::builder`].
//! * [`sim`] — deterministic discrete-event simulation: whole fleets
//!   (1000+ members) in one process on a virtual clock, the production
//!   gossip loop and membership plane running unmodified over simulated
//!   links with injectable faults (drops, delays, partitions, churn
//!   schedules); same seed ⇒ byte-identical event trace.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts; the
//!   dense averaging round can run through XLA (`gossip::PjrtExecutor`),
//!   gated behind the `pjrt` cargo feature.
//! * [`experiments`] — regeneration harness for every table and figure in
//!   the paper's evaluation (§7).
//! * [`rng`], [`metrics`], [`util`] — in-tree substrates (PRNG +
//!   distributions, error metrics, CSV/JSON/bench/property-test kits).
//!
//! ## Quickstart
//!
//! ```
//! use duddsketch::sketch::UddSketch;
//!
//! let mut s: UddSketch = UddSketch::new(0.001, 1024).unwrap();
//! for i in 1..=10_000 { s.insert(i as f64); }
//! let p99 = s.quantile(0.99).unwrap();
//! assert!((p99 - 9_900.0).abs() / 9_900.0 < 0.01);
//! ```
//!
//! For the serving surface, import the [`prelude`] and build a
//! [`Node`](service::Node):
//!
//! ```
//! use duddsketch::prelude::*;
//!
//! let node = Node::builder().alpha(0.001).shards(2).build().unwrap();
//! let mut w = node.writer();
//! w.insert_batch(&[1.0, 2.0, 3.0]);
//! w.flush();
//! assert_eq!(node.flush().count(), 3.0);
//! node.shutdown();
//! ```
//!
//! See `examples/` for the distributed protocol end-to-end, `README.md`
//! for the architecture diagram and crate-layout table.

// Every public item carries rustdoc; the CI docs lane builds with
// `RUSTDOCFLAGS="-D warnings"`, so a missing doc fails the build.
#![warn(missing_docs)]
// Config structs are plain data mutated after `Default::default()`
// throughout tests, benches and examples; the lint's struct-literal
// update suggestion would obscure which knobs a given site turns.
#![allow(clippy::field_reassign_with_default)]

pub mod churn;
pub mod cli;
pub mod config;
pub mod data;
pub mod experiments;
pub mod gossip;
pub mod graph;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod sketch;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// The serving surface in one import: node construction
/// ([`Node::builder`](service::Node::builder)), the unified query trait
/// ([`QuantileReader`](sketch::QuantileReader)), the gossip loop, and
/// the exchange transports.
///
/// ```
/// use duddsketch::prelude::*;
///
/// let node = Node::builder().shards(1).build().unwrap();
/// node.shutdown();
/// ```
pub mod prelude {
    pub use crate::config::{GossipLoopConfig, ServiceConfig};
    pub use crate::gossip::PeerState;
    pub use crate::obs::{MetricsRegistry, NodeMetrics};
    pub use crate::service::{
        GlobalView, GossipLoop, GossipMember, GossipRoundReport, InProcessTransport,
        MemberStatus, MemberTable, Membership, Node, NodeBuilder, QuantileService,
        RestartCause, ServiceWriter, Snapshot, TcpTransport, TcpTransportOptions, Transport,
        TransportError,
    };
    pub use crate::sketch::{QuantileReader, SketchError, UddSketch};
}
