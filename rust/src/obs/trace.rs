//! Structured round tracing: per-phase wall-clock spans for every
//! gossip round, kept in a bounded ring buffer.
//!
//! The gossip loop times each phase of a round — refresh → exchange
//! (with the membership anti-entropy share broken out) → probe/publish
//! — and pushes one [`RoundTrace`] per round. The ring is bounded
//! ([`TraceRing::capacity`]): a long-running node keeps the most recent
//! traces only, so memory stays flat no matter how many rounds run.
//! [`GossipRoundReport`](crate::service::GossipRoundReport) carries the
//! same durations for the round just executed; the ring is the
//! look-back window behind it.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Duration;

/// Default number of round traces a [`TraceRing`] retains.
pub const DEFAULT_TRACE_CAPACITY: usize = 256;

/// One phase of a gossip round, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundPhase {
    /// Reseed check + (possibly) protocol restart.
    Refresh,
    /// Outbound push–pull exchanges (includes the membership share).
    Exchange,
    /// Membership anti-entropy piggybacked on the exchanges — a
    /// sub-span of [`RoundPhase::Exchange`], broken out separately.
    Membership,
    /// Probe quantiles, drift fold, and view publication.
    Publish,
}

impl RoundPhase {
    /// Every phase, in execution order.
    pub const ALL: [RoundPhase; 4] = [
        RoundPhase::Refresh,
        RoundPhase::Exchange,
        RoundPhase::Membership,
        RoundPhase::Publish,
    ];

    /// The phase's label value in the `dudd_round_phase_seconds`
    /// metric family.
    pub fn name(self) -> &'static str {
        match self {
            RoundPhase::Refresh => "refresh",
            RoundPhase::Exchange => "exchange",
            RoundPhase::Membership => "membership",
            RoundPhase::Publish => "publish",
        }
    }

    fn index(self) -> usize {
        match self {
            RoundPhase::Refresh => 0,
            RoundPhase::Exchange => 1,
            RoundPhase::Membership => 2,
            RoundPhase::Publish => 3,
        }
    }
}

/// The span record of one exchange attempt — the per-exchange child
/// span of a [`RoundTrace`]. Both ends of a traced exchange record one:
/// the initiator's span lands in its round trace (and event log), the
/// server's span goes to its event log with the *same*
/// [`trace_id`](ExchangeSpan::trace_id) echoed off the wire
/// (`docs/PROTOCOL.md` §2), so the two sides join into one causal
/// record without any clock agreement.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExchangeSpan {
    /// The 64-bit wire correlator; 0 on untraced (version-1) exchanges.
    pub trace_id: u64,
    /// True on the node that initiated the push–pull.
    pub initiator: bool,
    /// The remote partner (`addr:port`, or a member id for local
    /// in-process exchanges).
    pub peer: String,
    /// Restart generation the exchange ran under.
    pub generation: u64,
    /// Push frame kind actually sent/served: `"full"`, `"delta"`,
    /// `"local"` for in-process pair averaging, or `"unknown"` on
    /// failure spans synthesized outside the transport (the attempted
    /// frame kind never became visible).
    pub kind: &'static str,
    /// Wire bytes moved by this exchange (push + reply, both ends).
    pub bytes: usize,
    /// `"ok"`, `"reject:<reason>"`, or an error class
    /// (`"error:<kind>"`) for cancelled exchanges.
    pub outcome: &'static str,
    /// Time acquiring a channel (pool checkout or fresh connect);
    /// zero on the serving side.
    pub connect: Duration,
    /// Time writing (initiator) or reading + averaging (server) the
    /// push.
    pub push: Duration,
    /// Time waiting for / writing the reply.
    pub reply: Duration,
    /// Time adopting (initiator) or committing (server) the averaged
    /// state.
    pub commit: Duration,
}

/// The span record of one executed gossip round.
#[derive(Debug, Clone, Default)]
pub struct RoundTrace {
    /// Round counter when the trace was taken.
    pub round: u64,
    /// Restart generation during the round.
    pub generation: u64,
    /// Whether the round reseeded the local members.
    pub reseeded: bool,
    /// Why the round restarted ([`RestartCause`](crate::service::RestartCause)
    /// name), when it did.
    pub restart_cause: Option<&'static str>,
    /// Completed exchanges.
    pub exchanges: usize,
    /// Cancelled exchanges.
    pub failed: usize,
    /// Data-plane wire bytes moved.
    pub bytes: usize,
    /// Whole-round wall clock.
    pub total: Duration,
    /// Per-exchange child spans, in initiation order.
    pub exchange_spans: Vec<ExchangeSpan>,
    phases: [Duration; 4],
}

impl RoundTrace {
    /// Record a phase duration (builder-style, used by the loop).
    pub fn with_phase(mut self, phase: RoundPhase, d: Duration) -> Self {
        self.phases[phase.index()] = d;
        self
    }

    /// Wall clock spent in `phase`. [`RoundPhase::Membership`] is a
    /// sub-span of [`RoundPhase::Exchange`], so the four phases don't
    /// sum to [`RoundTrace::total`].
    pub fn phase(&self, phase: RoundPhase) -> Duration {
        self.phases[phase.index()]
    }

    /// A copy of this trace with every wall-clock span zeroed:
    /// identity, counters, and exchange spans survive, while
    /// [`RoundTrace::total`], the phase spans, and the per-exchange
    /// timings go to zero. The simulator's event export runs the trace
    /// through this before encoding — virtual time is deterministic
    /// but the `Instant`-measured spans are not, and same-seed sim
    /// runs must stay byte-identical (`docs/SIMULATION.md`).
    pub fn without_timings(&self) -> RoundTrace {
        let mut out = self.clone();
        out.total = Duration::ZERO;
        out.phases = [Duration::ZERO; 4];
        for span in &mut out.exchange_spans {
            span.connect = Duration::ZERO;
            span.push = Duration::ZERO;
            span.reply = Duration::ZERO;
            span.commit = Duration::ZERO;
        }
        out
    }
}

/// A bounded, thread-safe ring of the most recent [`RoundTrace`]s.
#[derive(Debug)]
pub struct TraceRing {
    inner: Mutex<VecDeque<RoundTrace>>,
    capacity: usize,
}

impl Default for TraceRing {
    fn default() -> Self {
        Self::new(DEFAULT_TRACE_CAPACITY)
    }
}

impl TraceRing {
    /// A ring retaining at most `capacity` traces (min 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        TraceRing {
            inner: Mutex::new(VecDeque::with_capacity(capacity)),
            capacity,
        }
    }

    fn lock_ring(&self) -> std::sync::MutexGuard<'_, std::collections::VecDeque<RoundTrace>> {
        self.inner.lock().expect("trace ring poisoned")
    }

    /// Append a trace, evicting the oldest when full.
    pub fn push(&self, trace: RoundTrace) {
        let mut ring = self.lock_ring();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(trace);
    }

    /// The most recent `n` traces, oldest first.
    pub fn recent(&self, n: usize) -> Vec<RoundTrace> {
        let ring = self.lock_ring();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).cloned().collect()
    }

    /// Traces currently retained.
    pub fn len(&self) -> usize {
        self.lock_ring().len()
    }

    /// True while no trace has been pushed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The retention bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_keeps_newest() {
        let ring = TraceRing::new(4);
        assert!(ring.is_empty());
        for round in 1..=10u64 {
            ring.push(RoundTrace {
                round,
                ..RoundTrace::default()
            });
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.capacity(), 4);
        let recent = ring.recent(100);
        let rounds: Vec<u64> = recent.iter().map(|t| t.round).collect();
        assert_eq!(rounds, vec![7, 8, 9, 10], "oldest evicted first");
        let last_two: Vec<u64> = ring.recent(2).iter().map(|t| t.round).collect();
        assert_eq!(last_two, vec![9, 10]);
    }

    #[test]
    fn exchange_spans_and_restart_cause_ride_the_trace() {
        let mut t = RoundTrace::default();
        t.exchange_spans.push(ExchangeSpan {
            trace_id: 7,
            initiator: true,
            peer: "127.0.0.1:9".into(),
            kind: "delta",
            outcome: "ok",
            ..ExchangeSpan::default()
        });
        t.restart_cause = Some("view_change");
        let ring = TraceRing::new(2);
        ring.push(t);
        let got = ring.recent(1);
        assert_eq!(got[0].exchange_spans.len(), 1);
        assert_eq!(got[0].exchange_spans[0].trace_id, 7);
        assert!(got[0].exchange_spans[0].initiator);
        assert_eq!(got[0].restart_cause, Some("view_change"));
    }

    #[test]
    fn without_timings_zeroes_spans_but_keeps_identity() {
        let mut t = RoundTrace::default()
            .with_phase(RoundPhase::Exchange, Duration::from_millis(9));
        t.round = 4;
        t.generation = 2;
        t.bytes = 512;
        t.total = Duration::from_millis(11);
        t.exchange_spans.push(ExchangeSpan {
            trace_id: 99,
            peer: "10.0.0.1:7".into(),
            kind: "full",
            outcome: "ok",
            connect: Duration::from_micros(33),
            reply: Duration::from_micros(44),
            ..ExchangeSpan::default()
        });
        let clean = t.without_timings();
        assert_eq!(clean.round, 4);
        assert_eq!(clean.generation, 2);
        assert_eq!(clean.bytes, 512);
        assert_eq!(clean.total, Duration::ZERO);
        assert_eq!(clean.phase(RoundPhase::Exchange), Duration::ZERO);
        assert_eq!(clean.exchange_spans[0].trace_id, 99);
        assert_eq!(clean.exchange_spans[0].peer, "10.0.0.1:7");
        assert_eq!(clean.exchange_spans[0].connect, Duration::ZERO);
        assert_eq!(clean.exchange_spans[0].reply, Duration::ZERO);
    }

    #[test]
    fn phase_durations_round_trip() {
        let t = RoundTrace::default()
            .with_phase(RoundPhase::Refresh, Duration::from_millis(1))
            .with_phase(RoundPhase::Exchange, Duration::from_millis(20))
            .with_phase(RoundPhase::Membership, Duration::from_millis(5))
            .with_phase(RoundPhase::Publish, Duration::from_millis(2));
        assert_eq!(t.phase(RoundPhase::Refresh), Duration::from_millis(1));
        assert_eq!(t.phase(RoundPhase::Exchange), Duration::from_millis(20));
        assert_eq!(t.phase(RoundPhase::Membership), Duration::from_millis(5));
        assert_eq!(t.phase(RoundPhase::Publish), Duration::from_millis(2));
        for p in RoundPhase::ALL {
            assert!(!p.name().is_empty());
        }
    }
}
