//! The convergence observatory behind the `dudd-observe` CLI: scrape a
//! fleet's `/metrics` endpoints, merge the per-node summaries into one
//! fleet report with a convergence **verdict**, and join the nodes'
//! JSONL event logs into causal cross-node exchange records by
//! `trace_id`.
//!
//! Three consumers share this module:
//!
//! * the `dudd-observe` subcommand (`--scrape`, `--json`, `--watch`,
//!   `--self-test`) renders [`FleetReport`]s for humans and machines,
//! * the remote-TCP CI lane smoke-tests `dudd-observe --json` against a
//!   live loopback fleet,
//! * `rust/tests/integration_obs.rs` reassembles both ends of traced
//!   exchanges from event logs via [`join_event_logs`].
//!
//! Everything is `std`-only: the HTTP client is the same hand-rolled
//! one-request/one-response shape as the serving side
//! ([`MetricsServer`](super::MetricsServer)), the Prometheus text
//! parser handles exactly the exposition `render()` emits, and event
//! logs are read through [`parse_flat_json`].
//!
//! ## The verdict
//!
//! A fleet is reported **converged** when every reachable node says so
//! (`dudd_converged = 1`), all nodes sit in the same restart
//! generation, and — when the live Theorem 2 bound is available — the
//! largest per-node probe drift is at or under
//! `dudd_union_rel_err_bound`. An unreachable target or a generation
//! split downgrades the verdict to `degraded`; otherwise a
//! not-yet-converged fleet reports `converging`. A `NaN` (or missing)
//! bound means "bound unavailable" (empty sketches, non-positive
//! values) and only disables the drift-vs-bound check — it never fails
//! the verdict by itself.

use super::export::{parse_flat_json, push_json_str};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::time::Duration;

/// One HTTP GET against `target` (a `host:port` string), returning the
/// response body on a `200`. Connect, read, and write each run under
/// `timeout` — a dead or slow node costs at most a few timeouts, never
/// a hang.
pub fn http_get(target: &str, path: &str, timeout: Duration) -> Result<String, String> {
    let addr: SocketAddr = target
        .to_socket_addrs()
        .map_err(|e| format!("{target}: cannot resolve: {e}"))?
        .next()
        .ok_or_else(|| format!("{target}: resolves to no address"))?;
    let mut stream = TcpStream::connect_timeout(&addr, timeout)
        .map_err(|e| format!("{target}: connect: {e}"))?;
    stream
        .set_read_timeout(Some(timeout))
        .and_then(|()| stream.set_write_timeout(Some(timeout)))
        .map_err(|e| format!("{target}: socket timeouts: {e}"))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {target}\r\nConnection: close\r\n\r\n")
        .map_err(|e| format!("{target}: request: {e}"))?;
    let mut response = String::new();
    stream
        .read_to_string(&mut response)
        .map_err(|e| format!("{target}: response: {e}"))?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .ok_or_else(|| format!("{target}: malformed HTTP response"))?;
    let status = head.lines().next().unwrap_or("");
    if status.split_whitespace().nth(1) != Some("200") {
        return Err(format!("{target}{path}: {status}"));
    }
    Ok(body.to_string())
}

/// Parse Prometheus text exposition into a sample map: the full sample
/// key as rendered (name plus any `{label="value"}` block) → value.
/// Comment (`# HELP`/`# TYPE`) and blank lines are skipped; a line
/// whose value doesn't parse as a Prometheus float (`NaN`/`+Inf`
/// included) is ignored rather than failing the whole scrape.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((key, value)) = line.rsplit_once(' ') else {
            continue;
        };
        if let Ok(v) = value.parse::<f64>() {
            out.insert(key.to_string(), v);
        }
    }
    out
}

/// One scraped node's convergence summary — the `dudd_*` families a
/// fleet operator actually triages by, lifted out of the exposition.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeObservation {
    /// The scrape target (`host:port` of the `/metrics` listener).
    pub target: String,
    /// `dudd_rounds_total`.
    pub rounds: u64,
    /// `dudd_generation` — the restart generation.
    pub generation: u64,
    /// `dudd_drift` — largest relative probe drift of the last round.
    pub drift: f64,
    /// `dudd_converged = 1`.
    pub converged: bool,
    /// `dudd_union_rel_err_bound` — the live Theorem 2 bound (`NaN` =
    /// unavailable).
    pub union_bound: f64,
    /// `dudd_exchanges_total`.
    pub exchanges: u64,
    /// `dudd_exchanges_failed_total`.
    pub failed: u64,
    /// `dudd_exchange_rtt_seconds{quantile="0.5"}` (`NaN` before any
    /// remote exchange).
    pub rtt_p50: f64,
    /// `dudd_exchange_rtt_seconds{quantile="0.99"}`.
    pub rtt_p99: f64,
    /// Nonzero `dudd_restarts_total{cause=...}` samples as
    /// `(cause, count)`, in label order.
    pub restarts: Vec<(String, u64)>,
    /// `dudd_members_alive` (0 on static fleets without a membership
    /// plane).
    pub members_alive: u64,
    /// `dudd_events_dropped_total` — event-log lines lost to a lagging
    /// writer.
    pub events_dropped: u64,
}

impl NodeObservation {
    /// Lift the summary out of one `/metrics` exposition body.
    pub fn from_exposition(target: &str, text: &str) -> NodeObservation {
        let m = parse_exposition(text);
        let num = |key: &str| m.get(key).copied().unwrap_or(f64::NAN);
        let count = |key: &str| {
            let v = num(key);
            if v.is_finite() {
                v as u64
            } else {
                0
            }
        };
        let mut restarts = Vec::new();
        for (key, &v) in m.range("dudd_restarts_total{".to_string()..) {
            let Some(rest) = key.strip_prefix("dudd_restarts_total{cause=\"") else {
                break; // BTreeMap range: past the family once the prefix stops matching
            };
            if let Some(cause) = rest.strip_suffix("\"}") {
                if v > 0.0 {
                    restarts.push((cause.to_string(), v as u64));
                }
            }
        }
        NodeObservation {
            target: target.to_string(),
            rounds: count("dudd_rounds_total"),
            generation: count("dudd_generation"),
            drift: num("dudd_drift"),
            converged: num("dudd_converged") == 1.0,
            union_bound: num("dudd_union_rel_err_bound"),
            exchanges: count("dudd_exchanges_total"),
            failed: count("dudd_exchanges_failed_total"),
            rtt_p50: num("dudd_exchange_rtt_seconds{quantile=\"0.5\"}"),
            rtt_p99: num("dudd_exchange_rtt_seconds{quantile=\"0.99\"}"),
            restarts,
            members_alive: count("dudd_members_alive"),
            events_dropped: count("dudd_events_dropped_total"),
        }
    }
}

/// One row of a node's gossiped member table, as served by
/// `GET /members` (JSON lines).
#[derive(Debug, Clone, PartialEq)]
pub struct MemberRecord {
    /// Stable member id.
    pub id: u64,
    /// Exchange listen address.
    pub addr: String,
    /// Incarnation counter.
    pub incarnation: u64,
    /// `alive` / `suspect` / `dead`.
    pub status: String,
}

/// Parse a `GET /members` NDJSON body. Malformed lines are skipped —
/// one bad row must not blind the observatory to the rest of the
/// table.
pub fn parse_members(body: &str) -> Vec<MemberRecord> {
    body.lines()
        .filter_map(|line| {
            let obj = parse_flat_json(line.trim())?;
            Some(MemberRecord {
                id: obj.get("id")?.as_u64()?,
                addr: obj.get("addr")?.as_str()?.to_string(),
                incarnation: obj.get("incarnation")?.as_u64()?,
                status: obj.get("status")?.as_str()?.to_string(),
            })
        })
        .collect()
}

/// The merged fleet view: every reachable node's
/// [`NodeObservation`], the gossiped member table (from the first node
/// serving `/members`), and the convergence verdict.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Reachable nodes, in scrape-target order.
    pub nodes: Vec<NodeObservation>,
    /// Targets that failed to scrape, with the error.
    pub unreachable: Vec<(String, String)>,
    /// The gossiped member table (empty on static fleets).
    pub members: Vec<MemberRecord>,
    /// Largest per-node probe drift across the fleet.
    pub max_drift: f64,
    /// The fleet's Theorem 2 bound: the largest finite positive
    /// per-node `dudd_union_rel_err_bound` (conservative), or `NaN`
    /// when no node has one.
    pub bound: f64,
    /// All reachable nodes sit in the same restart generation.
    pub generations_agree: bool,
    /// All reachable nodes report `dudd_converged = 1`.
    pub all_converged: bool,
    /// `converged` / `converging` / `degraded` / `no-data` — see the
    /// [module docs](self).
    pub verdict: &'static str,
}

impl FleetReport {
    /// Merge per-node observations into the fleet view and compute the
    /// verdict. (Public so the self-test and unit tests can exercise
    /// the verdict logic without sockets.)
    pub fn assemble(
        nodes: Vec<NodeObservation>,
        unreachable: Vec<(String, String)>,
        members: Vec<MemberRecord>,
    ) -> FleetReport {
        let max_drift = nodes
            .iter()
            .map(|n| n.drift)
            .filter(|d| d.is_finite())
            .fold(f64::NAN, f64::max);
        let bound = nodes
            .iter()
            .map(|n| n.union_bound)
            .filter(|b| b.is_finite() && *b > 0.0)
            .fold(f64::NAN, f64::max);
        let generations_agree = nodes
            .windows(2)
            .all(|w| w[0].generation == w[1].generation);
        let all_converged = !nodes.is_empty() && nodes.iter().all(|n| n.converged);
        let verdict = if nodes.is_empty() {
            "no-data"
        } else if !unreachable.is_empty() || !generations_agree {
            "degraded"
        } else if all_converged && (bound.is_nan() || max_drift <= bound) {
            "converged"
        } else {
            "converging"
        };
        FleetReport {
            nodes,
            unreachable,
            members,
            max_drift,
            bound,
            generations_agree,
            all_converged,
            verdict,
        }
    }

    /// Render the fleet as a human-readable table (the default
    /// `dudd-observe` output).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "fleet: {} node(s), {} unreachable — verdict: {}",
            self.nodes.len(),
            self.unreachable.len(),
            self.verdict
        ));
        if self.bound.is_finite() {
            out.push_str(&format!(
                " (max drift {:.3e} vs Theorem 2 bound {:.3e})",
                self.max_drift, self.bound
            ));
        } else {
            out.push_str(" (Theorem 2 bound unavailable)");
        }
        out.push('\n');
        out.push_str(&format!(
            "{:<22} {:>7} {:>4} {:>10} {:>5} {:>10} {:>9} {:>9} {:>8} {:>7}  {}\n",
            "TARGET",
            "ROUNDS",
            "GEN",
            "DRIFT",
            "CONV",
            "BOUND",
            "RTTp50ms",
            "RTTp99ms",
            "XCHG/ER",
            "DROPPED",
            "RESTARTS"
        ));
        for n in &self.nodes {
            let restarts = if n.restarts.is_empty() {
                "-".to_string()
            } else {
                n.restarts
                    .iter()
                    .map(|(cause, count)| format!("{cause}:{count}"))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            out.push_str(&format!(
                "{:<22} {:>7} {:>4} {:>10.3e} {:>5} {:>10.3e} {:>9.2} {:>9.2} {:>8} {:>7}  {}\n",
                n.target,
                n.rounds,
                n.generation,
                n.drift,
                if n.converged { "yes" } else { "no" },
                n.union_bound,
                n.rtt_p50 * 1e3,
                n.rtt_p99 * 1e3,
                format!("{}/{}", n.exchanges, n.failed),
                n.events_dropped,
                restarts
            ));
        }
        for (target, error) in &self.unreachable {
            out.push_str(&format!("{target:<22} UNREACHABLE: {error}\n"));
        }
        if !self.members.is_empty() {
            out.push_str("members:");
            for m in &self.members {
                out.push_str(&format!(
                    " {}@{}(inc {}, {})",
                    m.id, m.addr, m.incarnation, m.status
                ));
            }
            out.push('\n');
        }
        out
    }

    /// Render the fleet as one JSON object (the `--json` output).
    /// Non-finite numbers become `null` — the output is strict JSON.
    pub fn render_json(&self) -> String {
        let mut out = String::with_capacity(1024);
        out.push_str("{\"verdict\":");
        push_json_str(&mut out, self.verdict);
        out.push_str(&format!(
            ",\"all_converged\":{},\"generations_agree\":{},\"max_drift\":{},\"bound\":{}",
            self.all_converged,
            self.generations_agree,
            json_num(self.max_drift),
            json_num(self.bound)
        ));
        out.push_str(",\"nodes\":[");
        for (i, n) in self.nodes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"target\":");
            push_json_str(&mut out, &n.target);
            out.push_str(&format!(
                ",\"rounds\":{},\"generation\":{},\"drift\":{},\"converged\":{},\
                 \"union_bound\":{},\"exchanges\":{},\"failed\":{},\"rtt_p50\":{},\
                 \"rtt_p99\":{},\"members_alive\":{},\"events_dropped\":{}",
                n.rounds,
                n.generation,
                json_num(n.drift),
                n.converged,
                json_num(n.union_bound),
                n.exchanges,
                n.failed,
                json_num(n.rtt_p50),
                json_num(n.rtt_p99),
                n.members_alive,
                n.events_dropped
            ));
            out.push_str(",\"restarts\":{");
            for (j, (cause, count)) in n.restarts.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                push_json_str(&mut out, cause);
                out.push_str(&format!(":{count}"));
            }
            out.push_str("}}");
        }
        out.push_str("],\"unreachable\":[");
        for (i, (target, error)) in self.unreachable.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"target\":");
            push_json_str(&mut out, target);
            out.push_str(",\"error\":");
            push_json_str(&mut out, error);
            out.push('}');
        }
        out.push_str("],\"members\":[");
        for (i, m) in self.members.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{{\"id\":{},\"addr\":", m.id));
            push_json_str(&mut out, &m.addr);
            out.push_str(&format!(",\"incarnation\":{},\"status\":", m.incarnation));
            push_json_str(&mut out, &m.status);
            out.push('}');
        }
        out.push_str("]}");
        out
    }
}

/// A JSON number literal for `v`: its decimal form when finite, `null`
/// otherwise (JSON has no NaN/Inf).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Scrape every target's `/metrics` (and the first answering
/// `/members`) and assemble the [`FleetReport`].
pub fn observe_fleet(targets: &[String], timeout: Duration) -> FleetReport {
    let mut nodes = Vec::new();
    let mut unreachable = Vec::new();
    let mut members = Vec::new();
    for target in targets {
        match http_get(target, "/metrics", timeout) {
            Ok(body) => nodes.push(NodeObservation::from_exposition(target, &body)),
            Err(e) => {
                unreachable.push((target.clone(), e));
                continue;
            }
        }
        if members.is_empty() {
            // The member table is gossiped state — any one node's copy
            // is the fleet's; a 404 here just means a static fleet.
            if let Ok(body) = http_get(target, "/members", timeout) {
                members = parse_members(&body);
            }
        }
    }
    FleetReport::assemble(nodes, unreachable, members)
}

/// One side of a traced exchange, lifted from an `exchange` event-log
/// line.
#[derive(Debug, Clone, PartialEq)]
pub struct ExchangeSide {
    /// The emitting node's label.
    pub node: String,
    /// That node's round counter at emission.
    pub round: u64,
    /// The partner as that side saw it.
    pub peer: String,
    /// Restart generation the exchange ran under.
    pub generation: u64,
    /// Frame kind (`full`/`delta`/`local`/`unknown`).
    pub kind: String,
    /// Wire bytes moved.
    pub bytes: u64,
    /// `ok`, `reject:<reason>`, or `error:<kind>`.
    pub outcome: String,
}

/// Both ends of one traced exchange, joined by `trace_id` across the
/// fleet's event logs. Either side may be missing (the partner's log
/// wasn't collected, the exchange failed before the server saw it, or
/// it was a local in-process exchange with no serving node).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CausalExchange {
    /// The wire correlator, as the decimal string the logs carry.
    pub trace_id: String,
    /// The initiating side's record.
    pub initiator: Option<ExchangeSide>,
    /// The serving side's record.
    pub server: Option<ExchangeSide>,
}

impl CausalExchange {
    /// Both sides were collected and agree on what happened: same frame
    /// kind and same restart generation. (Byte counts are exposed for
    /// the caller to compare — both ends count push + reply frame
    /// bytes.)
    pub fn consistent(&self) -> bool {
        match (&self.initiator, &self.server) {
            (Some(i), Some(s)) => i.kind == s.kind && i.generation == s.generation,
            _ => false,
        }
    }
}

fn exchange_side(obj: &BTreeMap<String, super::JsonValue>) -> Option<ExchangeSide> {
    Some(ExchangeSide {
        node: obj.get("node")?.as_str()?.to_string(),
        round: obj.get("round")?.as_u64()?,
        peer: obj.get("peer")?.as_str()?.to_string(),
        generation: obj.get("generation")?.as_u64()?,
        kind: obj.get("kind")?.as_str()?.to_string(),
        bytes: obj.get("bytes")?.as_u64()?,
        outcome: obj.get("outcome")?.as_str()?.to_string(),
    })
}

/// Join `exchange` events across event-log *contents* (one string per
/// node's JSONL file) into causal records keyed by `trace_id`.
/// Untraced exchanges (`trace_id` 0) and non-exchange events are
/// skipped; within one record the first line per role wins.
pub fn join_event_lines<'a>(logs: impl IntoIterator<Item = &'a str>) -> Vec<CausalExchange> {
    let mut by_id: BTreeMap<String, CausalExchange> = BTreeMap::new();
    for log in logs {
        for line in log.lines() {
            let Some(obj) = parse_flat_json(line.trim()) else {
                continue;
            };
            if obj.get("event").and_then(|v| v.as_str()) != Some("exchange") {
                continue;
            }
            let Some(trace_id) = obj.get("trace_id").and_then(|v| v.as_str()) else {
                continue;
            };
            if trace_id == "0" {
                continue;
            }
            let Some(side) = exchange_side(&obj) else {
                continue;
            };
            let entry = by_id.entry(trace_id.to_string()).or_insert_with(|| {
                CausalExchange {
                    trace_id: trace_id.to_string(),
                    ..CausalExchange::default()
                }
            });
            let slot = match obj.get("role").and_then(|v| v.as_str()) {
                Some("initiator") => &mut entry.initiator,
                Some("server") => &mut entry.server,
                _ => continue,
            };
            if slot.is_none() {
                *slot = Some(side);
            }
        }
    }
    by_id.into_values().collect()
}

/// [`join_event_lines`] over event-log files on disk.
pub fn join_event_logs(paths: &[&Path]) -> std::io::Result<Vec<CausalExchange>> {
    let mut contents = Vec::with_capacity(paths.len());
    for path in paths {
        contents.push(std::fs::read_to_string(path)?);
    }
    Ok(join_event_lines(contents.iter().map(String::as_str)))
}

/// The `--self-test` battery: exercise the exposition parser, the
/// verdict logic, and the trace-id join on synthetic inputs, with no
/// sockets or files. Returns the first failure as an error string.
pub fn self_test() -> Result<(), String> {
    let check = |ok: bool, what: &str| {
        if ok {
            Ok(())
        } else {
            Err(format!("self-test failed: {what}"))
        }
    };

    let exposition = "# HELP dudd_drift x\n# TYPE dudd_drift gauge\n\
         dudd_drift 1e-10\ndudd_converged 1\ndudd_generation 3\n\
         dudd_rounds_total 32\ndudd_union_rel_err_bound 0.004\n\
         dudd_exchanges_total 9\ndudd_exchanges_failed_total 1\n\
         dudd_exchange_rtt_seconds{quantile=\"0.5\"} 0.001\n\
         dudd_exchange_rtt_seconds{quantile=\"0.99\"} 0.004\n\
         dudd_restarts_total{cause=\"view_change\"} 2\n\
         dudd_restarts_total{cause=\"epoch_advance\"} 0\n\
         dudd_events_dropped_total 0\ndudd_members_alive 4\n";
    let n = NodeObservation::from_exposition("127.0.0.1:1", exposition);
    check(n.rounds == 32 && n.generation == 3 && n.converged, "exposition lift")?;
    check(n.union_bound == 0.004 && n.drift == 1e-10, "gauge lift")?;
    check(
        n.restarts == vec![("view_change".to_string(), 2)],
        "restart causes (nonzero only)",
    )?;
    check(n.rtt_p99 == 0.004 && n.members_alive == 4, "labeled samples")?;

    let twin = |gen: u64, conv: bool| NodeObservation {
        generation: gen,
        converged: conv,
        ..n.clone()
    };
    let report = FleetReport::assemble(vec![twin(3, true), twin(3, true)], vec![], vec![]);
    check(report.verdict == "converged", "two agreeing nodes converge")?;
    let report = FleetReport::assemble(vec![twin(3, true), twin(4, true)], vec![], vec![]);
    check(report.verdict == "degraded", "generation split degrades")?;
    let report = FleetReport::assemble(vec![twin(3, true), twin(3, false)], vec![], vec![]);
    check(report.verdict == "converging", "one unconverged node")?;
    let report = FleetReport::assemble(
        vec![twin(3, true)],
        vec![("x:1".into(), "connect refused".into())],
        vec![],
    );
    check(report.verdict == "degraded", "unreachable target degrades")?;
    check(
        FleetReport::assemble(vec![], vec![], vec![]).verdict == "no-data",
        "empty fleet",
    )?;
    let json = FleetReport::assemble(vec![twin(3, true)], vec![], vec![]).render_json();
    check(json.contains("\"verdict\":\"converged\""), "json verdict field")?;
    check(parse_flat_json("{\"verdict\":\"x\"}").is_some(), "json parser sanity")?;

    let a = "{\"event\":\"exchange\",\"node\":\"n0\",\"t_ms\":1,\"round\":2,\
             \"trace_id\":\"77\",\"role\":\"initiator\",\"peer\":\"b:1\",\
             \"generation\":5,\"kind\":\"delta\",\"bytes\":96,\"outcome\":\"ok\",\
             \"connect_us\":1,\"push_us\":2,\"reply_us\":3,\"commit_us\":4}";
    let b = "{\"event\":\"exchange\",\"node\":\"n1\",\"t_ms\":9,\"round\":2,\
             \"trace_id\":\"77\",\"role\":\"server\",\"peer\":\"a:1\",\
             \"generation\":5,\"kind\":\"delta\",\"bytes\":96,\"outcome\":\"ok\",\
             \"connect_us\":0,\"push_us\":2,\"reply_us\":3,\"commit_us\":4}";
    let joined = join_event_lines([a, b]);
    check(joined.len() == 1, "one causal record per trace id")?;
    check(joined[0].consistent(), "both sides joined consistently")?;
    check(
        joined[0].initiator.as_ref().map(|s| s.node.as_str()) == Some("n0")
            && joined[0].server.as_ref().map(|s| s.node.as_str()) == Some("n1"),
        "roles land on the right side",
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{encode_exchange_event, ExchangeSpan};

    #[test]
    fn self_test_passes() {
        self_test().expect("observatory self-test");
    }

    #[test]
    fn exposition_parser_handles_labels_nan_and_comments() {
        let m = parse_exposition(
            "# HELP a b\n# TYPE a gauge\na 1.5\n\
             b{x=\"y z\"} NaN\nc{q=\"0.5\"} +Inf\n\nnot a sample line\n",
        );
        assert_eq!(m["a"], 1.5);
        assert!(m["b{x=\"y z\"}"].is_nan());
        assert_eq!(m["c{q=\"0.5\"}"], f64::INFINITY);
        assert!(!m.contains_key("not a sample"));
        // The label-value key includes the rendered quotes verbatim —
        // exactly what `registry::render` emits.
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn join_groups_real_encoder_output_by_trace_id() {
        let initiator = ExchangeSpan {
            trace_id: 42,
            initiator: true,
            peer: "127.0.0.1:7401".into(),
            generation: 2,
            kind: "full",
            bytes: 16000,
            outcome: "ok",
            ..ExchangeSpan::default()
        };
        let server = ExchangeSpan {
            initiator: false,
            peer: "127.0.0.1:7400".into(),
            ..initiator.clone()
        };
        let untraced = ExchangeSpan {
            trace_id: 0,
            ..initiator.clone()
        };
        let log_a = format!("{}\n", encode_exchange_event("n0", 5, 3, &initiator));
        let log_b = format!(
            "{}\n{}\nnot json\n",
            encode_exchange_event("n1", 6, 3, &server),
            encode_exchange_event("n1", 7, 3, &untraced)
        );
        let joined = join_event_lines([log_a.as_str(), log_b.as_str()]);
        assert_eq!(joined.len(), 1, "trace 0 skipped, garbage skipped");
        let rec = &joined[0];
        assert_eq!(rec.trace_id, "42");
        assert!(rec.consistent());
        let (i, s) = (rec.initiator.as_ref().unwrap(), rec.server.as_ref().unwrap());
        assert_eq!(i.node, "n0");
        assert_eq!(s.node, "n1");
        assert_eq!(i.bytes, s.bytes);
        assert_eq!(i.kind, "full");
    }

    #[test]
    fn members_parser_skips_bad_rows() {
        let body = "{\"id\":0,\"addr\":\"10.0.0.1:7400\",\"incarnation\":1,\"status\":\"alive\"}\n\
                    garbage\n\
                    {\"id\":2,\"addr\":\"10.0.0.3:7400\",\"incarnation\":4,\"status\":\"dead\"}\n";
        let members = parse_members(body);
        assert_eq!(members.len(), 2);
        assert_eq!(members[0].id, 0);
        assert_eq!(members[1].status, "dead");
    }

    #[test]
    fn report_json_is_machine_readable_with_nan_as_null() {
        let node = NodeObservation {
            target: "h:1".into(),
            rounds: 1,
            generation: 1,
            drift: f64::NAN,
            converged: false,
            union_bound: f64::NAN,
            exchanges: 0,
            failed: 0,
            rtt_p50: f64::NAN,
            rtt_p99: f64::NAN,
            restarts: vec![],
            members_alive: 0,
            events_dropped: 0,
        };
        let json = FleetReport::assemble(vec![node], vec![], vec![]).render_json();
        assert!(json.contains("\"verdict\":\"converging\""), "{json}");
        assert!(json.contains("\"drift\":null"), "{json}");
        assert!(!json.contains("NaN"), "strict JSON only: {json}");
        // The top-level object parses as far as a flat reader can tell:
        // at minimum the verdict is extractable.
        assert!(json.starts_with("{\"verdict\":"));
    }

    #[test]
    fn table_lists_every_node_and_unreachable_target() {
        let node = NodeObservation {
            target: "10.0.0.1:9464".into(),
            rounds: 32,
            generation: 2,
            drift: 1e-10,
            converged: true,
            union_bound: 0.004,
            exchanges: 96,
            failed: 1,
            rtt_p50: 0.0008,
            rtt_p99: 0.0021,
            restarts: vec![("view_change".into(), 1)],
            members_alive: 4,
            events_dropped: 0,
        };
        let report = FleetReport::assemble(
            vec![node],
            vec![("10.0.0.2:9464".into(), "connect: refused".into())],
            vec![MemberRecord {
                id: 0,
                addr: "10.0.0.1:7400".into(),
                incarnation: 1,
                status: "alive".into(),
            }],
        );
        let table = report.render_table();
        assert!(table.contains("verdict: degraded"), "{table}");
        assert!(table.contains("10.0.0.1:9464"), "{table}");
        assert!(table.contains("view_change:1"), "{table}");
        assert!(table.contains("UNREACHABLE"), "{table}");
        assert!(table.contains("0@10.0.0.1:7400"), "{table}");
    }

    #[test]
    fn unreachable_only_fleet_reports_no_data() {
        let report = FleetReport::assemble(
            vec![],
            vec![("h:1".into(), "x".into())],
            vec![],
        );
        assert_eq!(report.verdict, "no-data");
        assert!(report.render_json().contains("\"verdict\":\"no-data\""));
    }
}
