//! Structured event-log export: a bounded, non-blocking JSONL sink.
//!
//! The gossip loop emits one JSON object per line — round spans,
//! per-exchange child spans ([`ExchangeSpan`]), and membership deltas —
//! into an [`EventSink`]. The sink is a bounded channel in front of a
//! dedicated writer thread: the hot path does one `try_send` and **never
//! blocks**; when the writer lags behind, events are dropped and counted
//! (`dudd_events_dropped_total`) instead of stalling a gossip round.
//!
//! The encoder is hand-rolled (the crate carries no serialization
//! dependency, same as `sim/`'s report writer), and [`parse_flat_json`]
//! is the matching hand-rolled reader — `dudd-observe` and the property
//! tests both consume logs through it. The simulator emits the *same*
//! schema from its virtual clock (`sim/fleet.rs`), so production logs
//! and deterministic sim traces are diffable with one toolchain.
//!
//! ## Event schema
//!
//! Every line is one flat JSON object with an `"event"` discriminator:
//!
//! * `round` — `node`, `t_ms`, `round`, `generation`, `reseeded`,
//!   `restart_cause` (string or `null`), `exchanges`, `failed`,
//!   `bytes`, `total_us`, and the four phase spans
//!   `refresh_us`/`exchange_us`/`membership_us`/`publish_us`.
//! * `exchange` — `node`, `t_ms`, `round`, `trace_id` (decimal
//!   **string** — 64-bit ids exceed JSON's interoperable integer
//!   range), `role` (`initiator`/`server`), `peer`, `generation`,
//!   `kind`, `bytes`, `outcome`, and
//!   `connect_us`/`push_us`/`reply_us`/`commit_us`.
//! * `membership` — `node`, `t_ms`, `round`, `joined`, `suspected`,
//!   `died`.
//!
//! `t_ms` is milliseconds since the sink was created (production) or
//! since simulation start (sim) — a per-node monotonic offset, not a
//! cross-node clock; cross-node joining uses `trace_id`
//! (`docs/PROTOCOL.md` §2), never timestamps.

use super::registry::Counter;
use super::trace::{ExchangeSpan, RoundPhase, RoundTrace};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Bounded queue depth between the gossip hot path and the writer
/// thread. At ~200 bytes per event this is under 1 MiB of backlog; a
/// writer stalled longer than that loses events (counted) rather than
/// stalling rounds.
const EVENT_QUEUE_DEPTH: usize = 4096;

/// A bounded, non-blocking JSONL event writer. Construct with
/// [`EventSink::create`]; emit with the typed `emit_*` methods (or raw
/// [`EventSink::emit`]). Dropping the sink closes the channel and joins
/// the writer thread, flushing everything still queued.
#[derive(Debug)]
pub struct EventSink {
    tx: Option<SyncSender<String>>,
    writer: Option<JoinHandle<()>>,
    dropped: Counter,
    node: String,
    born: Instant,
}

impl EventSink {
    /// Open (truncating) `path` and start the writer thread. `node` is
    /// the label stamped on every event (the node's serve address);
    /// `dropped` is incremented once per event lost to a lagging
    /// writer.
    pub fn create(path: &Path, node: &str, dropped: Counter) -> std::io::Result<EventSink> {
        let file = File::create(path)?;
        let (tx, rx) = sync_channel::<String>(EVENT_QUEUE_DEPTH);
        let writer = std::thread::Builder::new()
            .name("dudd-event-log".into())
            .spawn(move || write_loop(rx, file))?;
        Ok(EventSink {
            tx: Some(tx),
            writer: Some(writer),
            dropped,
            node: node.to_string(),
            born: Instant::now(),
        })
    }

    /// Events dropped so far because the writer lagged.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    /// Queue one pre-encoded JSON line. Non-blocking: a full queue (or
    /// a dead writer) drops the event and bumps the drop counter.
    pub fn emit(&self, line: String) {
        let Some(tx) = self.tx.as_ref() else {
            self.dropped.inc();
            return;
        };
        if tx.try_send(line).is_err() {
            self.dropped.inc();
        }
    }

    fn t_ms(&self) -> u64 {
        self.born.elapsed().as_millis() as u64
    }

    /// Emit one `round` event from a completed round's trace.
    pub fn emit_round(&self, trace: &RoundTrace) {
        self.emit(encode_round_event(&self.node, self.t_ms(), trace));
    }

    /// Emit one `exchange` event. `round` is the initiating (or
    /// serving) node's round counter at emission.
    pub fn emit_exchange(&self, round: u64, span: &ExchangeSpan) {
        self.emit(encode_exchange_event(&self.node, self.t_ms(), round, span));
    }

    /// Emit one `membership` event (only called on rounds where the
    /// member table actually changed).
    pub fn emit_membership(&self, round: u64, joined: u64, suspected: u64, died: u64) {
        self.emit(encode_membership_event(
            &self.node,
            self.t_ms(),
            round,
            joined,
            suspected,
            died,
        ));
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel: writer drains + exits
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

fn write_loop(rx: Receiver<String>, file: File) {
    let mut out = BufWriter::new(file);
    // Block for the next event, then opportunistically drain whatever
    // else is queued before flushing — one syscall per burst, and the
    // file is line-complete whenever the queue is empty.
    while let Ok(line) = rx.recv() {
        if out.write_all(line.as_bytes()).is_err() {
            return; // disk gone; senders see a closed channel and count drops
        }
        let _ = out.write_all(b"\n");
        loop {
            match rx.try_recv() {
                Ok(line) => {
                    let _ = out.write_all(line.as_bytes());
                    let _ = out.write_all(b"\n");
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    let _ = out.flush();
                    return;
                }
            }
        }
        let _ = out.flush();
    }
    let _ = out.flush();
}

// ---- encoding (also used by the simulator for schema parity) ----

/// Encode one `round` event line (no trailing newline).
pub fn encode_round_event(node: &str, t_ms: u64, trace: &RoundTrace) -> String {
    let mut out = String::with_capacity(256);
    out.push_str("{\"event\":\"round\",\"node\":");
    push_json_str(&mut out, node);
    out.push_str(&format!(
        ",\"t_ms\":{},\"round\":{},\"generation\":{},\"reseeded\":{}",
        t_ms, trace.round, trace.generation, trace.reseeded
    ));
    out.push_str(",\"restart_cause\":");
    match trace.restart_cause {
        Some(cause) => push_json_str(&mut out, cause),
        None => out.push_str("null"),
    }
    out.push_str(&format!(
        ",\"exchanges\":{},\"failed\":{},\"bytes\":{},\"total_us\":{}",
        trace.exchanges,
        trace.failed,
        trace.bytes,
        trace.total.as_micros()
    ));
    out.push_str(&format!(
        ",\"refresh_us\":{},\"exchange_us\":{},\"membership_us\":{},\"publish_us\":{}}}",
        trace.phase(RoundPhase::Refresh).as_micros(),
        trace.phase(RoundPhase::Exchange).as_micros(),
        trace.phase(RoundPhase::Membership).as_micros(),
        trace.phase(RoundPhase::Publish).as_micros()
    ));
    out
}

/// Encode one `exchange` event line (no trailing newline).
pub fn encode_exchange_event(node: &str, t_ms: u64, round: u64, span: &ExchangeSpan) -> String {
    let mut out = String::with_capacity(256);
    out.push_str("{\"event\":\"exchange\",\"node\":");
    push_json_str(&mut out, node);
    out.push_str(&format!(",\"t_ms\":{t_ms},\"round\":{round},\"trace_id\":"));
    push_json_str(&mut out, &span.trace_id.to_string());
    out.push_str(",\"role\":");
    push_json_str(&mut out, if span.initiator { "initiator" } else { "server" });
    out.push_str(",\"peer\":");
    push_json_str(&mut out, &span.peer);
    out.push_str(&format!(",\"generation\":{},\"kind\":", span.generation));
    push_json_str(&mut out, span.kind);
    out.push_str(&format!(",\"bytes\":{},\"outcome\":", span.bytes));
    push_json_str(&mut out, span.outcome);
    out.push_str(&format!(
        ",\"connect_us\":{},\"push_us\":{},\"reply_us\":{},\"commit_us\":{}}}",
        span.connect.as_micros(),
        span.push.as_micros(),
        span.reply.as_micros(),
        span.commit.as_micros()
    ));
    out
}

/// Encode one `membership` event line (no trailing newline).
pub fn encode_membership_event(
    node: &str,
    t_ms: u64,
    round: u64,
    joined: u64,
    suspected: u64,
    died: u64,
) -> String {
    let mut out = String::with_capacity(128);
    out.push_str("{\"event\":\"membership\",\"node\":");
    push_json_str(&mut out, node);
    out.push_str(&format!(
        ",\"t_ms\":{t_ms},\"round\":{round},\"joined\":{joined},\
         \"suspected\":{suspected},\"died\":{died}}}"
    ));
    out
}

/// Append `s` as a JSON string literal (shared with `obs::observe`'s
/// report renderer).
pub(crate) fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- decoding (dudd-observe + property tests) ----

/// A parsed flat-JSON value — the whole vocabulary the event schema
/// uses.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (the schema emits integers only, but they are
    /// parsed through `f64` like every interoperable JSON reader).
    Num(f64),
    /// A string literal, unescaped.
    Str(String),
}

impl JsonValue {
    /// The value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a u64 (numbers only, truncating).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_num().map(|n| n as u64)
    }
}

/// Parse one flat JSON object line (`{"k":v,...}`, no nesting — the
/// event schema is flat by design) into its key → value map. Returns
/// `None` on anything malformed, including trailing garbage.
pub fn parse_flat_json(line: &str) -> Option<BTreeMap<String, JsonValue>> {
    let mut p = Parser {
        b: line.as_bytes(),
        i: 0,
    };
    p.ws();
    p.expect(b'{')?;
    let mut map = BTreeMap::new();
    p.ws();
    if p.peek() == Some(b'}') {
        p.i += 1;
    } else {
        loop {
            p.ws();
            let key = p.string()?;
            p.ws();
            p.expect(b':')?;
            p.ws();
            let value = p.value()?;
            map.insert(key, value);
            p.ws();
            match p.next()? {
                b',' => continue,
                b'}' => break,
                _ => return None,
            }
        }
    }
    p.ws();
    if p.i != p.b.len() {
        return None; // trailing garbage
    }
    Some(map)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn expect(&mut self, want: u8) -> Option<()> {
        (self.next()? == want).then_some(())
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Option<JsonValue> {
        match self.peek()? {
            b'"' => Some(JsonValue::Str(self.string()?)),
            b'n' => self.literal("null", JsonValue::Null),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Option<JsonValue> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Some(v)
        } else {
            None
        }
    }

    fn number(&mut self) -> Option<JsonValue> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()?
            .parse::<f64>()
            .ok()
            .map(JsonValue::Num)
    }

    fn string(&mut self) -> Option<String> {
        self.expect(b'"')?;
        // Collect raw bytes to the closing quote, then decode escapes
        // on chars (the input is a &str, so the bytes are valid UTF-8).
        let start = self.i;
        loop {
            match self.next()? {
                b'"' => break,
                b'\\' => {
                    self.next()?; // skip the escaped byte (incl. \")
                }
                _ => {}
            }
        }
        let raw = std::str::from_utf8(&self.b[start..self.i - 1]).ok()?;
        let mut out = String::with_capacity(raw.len());
        let mut chars = raw.chars();
        while let Some(c) = chars.next() {
            if c != '\\' {
                out.push(c);
                continue;
            }
            match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                '/' => out.push('/'),
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'r' => out.push('\r'),
                'b' => out.push('\u{0008}'),
                'f' => out.push('\u{000C}'),
                'u' => {
                    let hex: String = (&mut chars).take(4).collect();
                    if hex.len() != 4 {
                        return None;
                    }
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                _ => return None,
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(trace_id: u64) -> ExchangeSpan {
        ExchangeSpan {
            trace_id,
            initiator: true,
            peer: "127.0.0.1:7401".into(),
            generation: 3,
            kind: "delta",
            bytes: 98,
            outcome: "ok",
            connect: Duration::from_micros(120),
            push: Duration::from_micros(80),
            reply: Duration::from_micros(400),
            commit: Duration::from_micros(15),
        }
    }

    #[test]
    fn exchange_event_round_trips_through_the_parser() {
        let line = encode_exchange_event("n1", 42, 7, &span(u64::MAX));
        let obj = parse_flat_json(&line).expect("parses");
        assert_eq!(obj["event"].as_str(), Some("exchange"));
        assert_eq!(obj["node"].as_str(), Some("n1"));
        assert_eq!(obj["t_ms"].as_u64(), Some(42));
        assert_eq!(obj["round"].as_u64(), Some(7));
        // u64::MAX survives because trace ids travel as strings.
        assert_eq!(obj["trace_id"].as_str(), Some("18446744073709551615"));
        assert_eq!(obj["role"].as_str(), Some("initiator"));
        assert_eq!(obj["kind"].as_str(), Some("delta"));
        assert_eq!(obj["outcome"].as_str(), Some("ok"));
        assert_eq!(obj["bytes"].as_u64(), Some(98));
        assert_eq!(obj["reply_us"].as_u64(), Some(400));
    }

    #[test]
    fn round_event_carries_cause_and_phases() {
        let mut t =
            RoundTrace::default().with_phase(RoundPhase::Exchange, Duration::from_micros(900));
        t.round = 9;
        t.generation = 2;
        t.reseeded = true;
        t.restart_cause = Some("view_change");
        t.exchanges = 3;
        t.failed = 1;
        t.bytes = 4096;
        t.total = Duration::from_micros(1500);
        let obj = parse_flat_json(&encode_round_event("n2", 10, &t)).unwrap();
        assert_eq!(obj["event"].as_str(), Some("round"));
        assert_eq!(obj["restart_cause"].as_str(), Some("view_change"));
        assert_eq!(obj["exchange_us"].as_u64(), Some(900));
        assert_eq!(obj["reseeded"], JsonValue::Bool(true));
        let no_cause = RoundTrace::default();
        let obj = parse_flat_json(&encode_round_event("n2", 0, &no_cause)).unwrap();
        assert_eq!(obj["restart_cause"], JsonValue::Null);
    }

    #[test]
    fn hostile_strings_survive_the_encode_decode_pair() {
        let mut s = span(1);
        s.peer = "quote\" back\\slash \nnewline \u{0001}ctl".into();
        let obj = parse_flat_json(&encode_exchange_event("node\"x\"", 0, 0, &s)).unwrap();
        assert_eq!(obj["peer"].as_str(), Some(s.peer.as_str()));
        assert_eq!(obj["node"].as_str(), Some("node\"x\""));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        for bad in [
            "",
            "{",
            "{\"a\":}",
            "{\"a\":1,}",
            "{\"a\":1} trailing",
            "{\"a\" 1}",
            "{\"a\":\"unterminated}",
            "[1,2]",
        ] {
            assert!(parse_flat_json(bad).is_none(), "{bad:?}");
        }
    }

    #[test]
    fn sink_writes_lines_and_drop_counter_stays_zero_when_keeping_up() {
        let dir = std::env::temp_dir().join(format!(
            "dudd-export-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let dropped = Counter::default();
        {
            let sink = EventSink::create(&path, "n1", dropped.clone()).unwrap();
            for round in 0..100u64 {
                sink.emit_exchange(round, &span(round + 1));
            }
            sink.emit_membership(100, 1, 0, 0);
            assert_eq!(sink.dropped(), 0);
        } // drop: closes + joins, flushing everything
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 101);
        for line in &lines {
            assert!(parse_flat_json(line).is_some(), "{line}");
        }
        assert_eq!(dropped.get(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
