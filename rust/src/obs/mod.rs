//! Node-wide observability plane: self-hosted sketch histograms,
//! structured round tracing, and the `/metrics` exposition endpoint.
//!
//! The rest of the crate *distributes* UDDSketch; this module turns the
//! same instrument on the node itself. Three pieces:
//!
//! * **[`MetricsRegistry`]** ([`registry`]) — named families of atomic
//!   [`Counter`]s / [`Gauge`]s and [`UddSketch`](crate::sketch::UddSketch)-backed
//!   latency [`Histogram`]s behind cheap `Arc` handles, rendered as
//!   Prometheus text exposition. The latency quantiles (`p50`/`p99`/
//!   `p999`) inherit the paper's relative-error guarantee, because they
//!   *are* the paper's sketch.
//! * **[`TraceRing`]** ([`trace`]) — a bounded ring of structured
//!   [`RoundTrace`] spans, one per gossip round, timing the
//!   refresh → exchange → membership → publish phases.
//! * **[`MetricsServer`]** ([`http`]) — a tiny `std::net` HTTP listener
//!   answering `GET /metrics`, wired through
//!   [`NodeBuilder::metrics_bind`](crate::service::NodeBuilder::metrics_bind)
//!   or the `metrics_bind` config key.
//!
//! [`NodeMetrics`] is the node's pre-registered handle bundle: one
//! sub-bundle per instrumented layer (ingest service, gossip loop,
//! transport, membership), all attached to one shared registry so a
//! single scrape sees the whole node. The full metric-name catalogue
//! and label conventions live in `docs/OBSERVABILITY.md`.
//!
//! ```
//! use duddsketch::obs::{MetricsRegistry, NodeMetrics};
//! use std::sync::Arc;
//!
//! let registry = Arc::new(MetricsRegistry::new());
//! let obs = NodeMetrics::register(&registry).unwrap();
//! obs.gossip.exchanges.inc();
//! obs.transport.exchange_rtt.observe(0.0012);
//! let text = registry.render();
//! assert!(text.contains("dudd_exchanges_total 1"));
//! assert!(text.contains("dudd_exchange_rtt_seconds_count 1"));
//! ```

#![forbid(unsafe_code)]

mod export;
mod http;
pub mod observe;
mod registry;
mod trace;

pub use export::{
    encode_exchange_event, encode_membership_event, encode_round_event, parse_flat_json,
    EventSink, JsonValue,
};
pub use http::{MembersSource, MetricsServer};
pub use registry::{Counter, Gauge, Histogram, MetricsRegistry, SUMMARY_QUANTILES};
pub use trace::{ExchangeSpan, RoundPhase, RoundTrace, TraceRing, DEFAULT_TRACE_CAPACITY};

use crate::service::RestartCause;
use crate::sketch::RejectReason;
use anyhow::Result;
use std::sync::{Arc, OnceLock};

/// Ingest-layer handles (`service/shard.rs` + `coordinator.rs`).
#[derive(Clone, Debug)]
pub struct ServiceMetrics {
    /// `dudd_ingest_values_total` — finite values folded by the shards.
    pub values: Counter,
    /// `dudd_ingest_batches_total` — shard batches consumed.
    pub batches: Counter,
    /// `dudd_ingest_dropped_total` — non-finite values dropped.
    pub dropped: Counter,
    /// `dudd_epochs_total` — epoch folds published.
    pub epochs: Counter,
    /// `dudd_epoch_fold_seconds` — drain + fold + publish latency.
    pub epoch_fold: Histogram,
}

/// Gossip-loop handles (`service/gossip_loop.rs`). The per-round
/// [`GossipRoundReport`](crate::service::GossipRoundReport) is derived
/// from snapshots of these counters — one source of truth.
#[derive(Clone, Debug)]
pub struct GossipMetrics {
    /// `dudd_rounds_total` — gossip rounds executed.
    pub rounds: Counter,
    /// `dudd_reseeds_total` — protocol restarts (reseed rounds).
    pub reseeds: Counter,
    /// `dudd_exchanges_total` — completed initiated push–pulls.
    pub exchanges: Counter,
    /// `dudd_exchanges_failed_total` — cancelled initiated exchanges.
    pub failed: Counter,
    /// `dudd_exchange_bytes_total` — data-plane wire bytes moved by
    /// initiated exchanges.
    pub exchange_bytes: Counter,
    /// `dudd_membership_bytes_total` — membership anti-entropy bytes.
    pub membership_bytes: Counter,
    /// `dudd_generation` — current restart generation.
    pub generation: Gauge,
    /// `dudd_drift` — largest relative probe drift of the last round.
    pub drift: Gauge,
    /// `dudd_converged` — 1 once drift fell to the threshold, else 0.
    pub converged: Gauge,
    /// `dudd_union_rel_err_bound` — the live Theorem 2 relative-error
    /// bound of the union estimate (`theorem2_bound(α₀, collapses)`).
    pub union_bound: Gauge,
    /// `dudd_restarts_total{cause=...}` — protocol restarts by
    /// [`RestartCause`].
    pub restarts: RestartCounters,
    /// `dudd_events_dropped_total` — event-log lines lost to a lagging
    /// writer ([`EventSink`] is non-blocking by contract).
    pub events_dropped: Counter,
    /// `dudd_round_seconds` — whole-round wall clock.
    pub round_seconds: Histogram,
    phases: [Histogram; 4],
}

impl GossipMetrics {
    /// The `dudd_round_phase_seconds{phase=...}` histogram for `phase`.
    pub fn phase(&self, phase: RoundPhase) -> &Histogram {
        let idx = RoundPhase::ALL
            .iter()
            .position(|p| *p == phase)
            .expect("RoundPhase::ALL is exhaustive");
        &self.phases[idx]
    }
}

/// Per-[`RejectReason`] counters (one labeled family).
#[derive(Clone, Debug)]
pub struct RejectCounters {
    /// `reason="busy"` — partner mid-exchange on its slot.
    pub busy: Counter,
    /// `reason="stale_generation"` — exchange behind a fleet restart.
    pub stale_generation: Counter,
    /// `reason="lineage"` — α₀ lineage mismatch.
    pub lineage: Counter,
    /// `reason="malformed"` — undecodable frame.
    pub malformed: Counter,
    /// `reason="baseline_mismatch"` — delta frame against a baseline
    /// the receiver no longer holds.
    pub baseline_mismatch: Counter,
    /// `reason="no_membership"` — membership frame at a static node.
    pub no_membership: Counter,
}

impl RejectCounters {
    fn register(registry: &MetricsRegistry, name: &str, help: &str) -> Result<Self> {
        let c = |reason: &str| registry.counter_with(name, help, &[("reason", reason)]);
        Ok(RejectCounters {
            busy: c("busy")?,
            stale_generation: c("stale_generation")?,
            lineage: c("lineage")?,
            malformed: c("malformed")?,
            baseline_mismatch: c("baseline_mismatch")?,
            no_membership: c("no_membership")?,
        })
    }

    /// The counter for `reason`.
    pub fn reason(&self, reason: RejectReason) -> &Counter {
        match reason {
            RejectReason::Busy => &self.busy,
            RejectReason::StaleGeneration => &self.stale_generation,
            RejectReason::Lineage => &self.lineage,
            RejectReason::Malformed => &self.malformed,
            RejectReason::BaselineMismatch => &self.baseline_mismatch,
            RejectReason::NoMembership => &self.no_membership,
        }
    }
}

/// Per-[`RestartCause`] counters (one labeled family,
/// `dudd_restarts_total{cause=...}`).
#[derive(Clone, Debug)]
pub struct RestartCounters {
    /// `cause="epoch_advance"` — epoch advance with restart-free carry
    /// disabled.
    pub epoch_advance: Counter,
    /// `cause="view_change"` — the membership view re-anchored.
    pub view_change: Counter,
    /// `cause="generation_catch_up"` — a partner was heard at a newer
    /// generation.
    pub generation_catch_up: Counter,
    /// `cause="epoch_fallback"` — restart-free epoch carry was
    /// undefined and fell back to a restart.
    pub epoch_fallback: Counter,
}

impl RestartCounters {
    fn register(registry: &MetricsRegistry, name: &str, help: &str) -> Result<Self> {
        let c = |cause: &str| registry.counter_with(name, help, &[("cause", cause)]);
        Ok(RestartCounters {
            epoch_advance: c("epoch_advance")?,
            view_change: c("view_change")?,
            generation_catch_up: c("generation_catch_up")?,
            epoch_fallback: c("epoch_fallback")?,
        })
    }

    /// The counter for `cause`.
    pub fn cause(&self, cause: RestartCause) -> &Counter {
        match cause {
            RestartCause::EpochAdvance => &self.epoch_advance,
            RestartCause::ViewChange => &self.view_change,
            RestartCause::GenerationCatchUp => &self.generation_catch_up,
            RestartCause::EpochFallback => &self.epoch_fallback,
        }
    }
}

/// Transport-layer handles (`service/transport.rs`), installed into a
/// transport via [`Transport::install_metrics`](crate::service::Transport::install_metrics).
#[derive(Clone, Debug)]
pub struct TransportMetrics {
    /// `dudd_pool_fresh_connects_total` — connections dialed fresh.
    pub pool_fresh_connects: Counter,
    /// `dudd_pool_reused_total` — pooled connections checked out.
    pub pool_reused: Counter,
    /// `dudd_pool_stale_discarded_total` — pooled connections found
    /// dead and dropped.
    pub pool_stale_discarded: Counter,
    /// `dudd_pool_expired_total` — pooled connections idle past the
    /// configured timeout.
    pub pool_expired: Counter,
    /// `dudd_frames_delta_total` — exchanges pushed as delta frames.
    pub frames_delta: Counter,
    /// `dudd_frames_full_total` — exchanges pushed as full frames.
    pub frames_full: Counter,
    /// `dudd_wire_bytes_total` — socket bytes moved by initiated
    /// exchanges (push + reply, length prefixes included).
    pub wire_bytes: Counter,
    /// `dudd_exchange_rtt_seconds` — initiated-exchange round-trip time
    /// (push write through reply decode, stale-channel retry included).
    pub exchange_rtt: Histogram,
    /// `dudd_rejects_total{reason=...}` — rejects *received* as an
    /// initiator.
    pub rejects: RejectCounters,
    /// `dudd_serve_rejects_total{reason=...}` — rejects *written* while
    /// serving inbound exchanges.
    pub serve_rejects: RejectCounters,
}

/// Membership-plane handles (`service/membership.rs`), installed via
/// `Membership::install_metrics`.
#[derive(Clone, Debug)]
pub struct MembershipMetrics {
    /// `dudd_members_alive` — members currently alive (self included).
    pub alive: Gauge,
    /// `dudd_members_suspect` — members currently suspect.
    pub suspect: Gauge,
    /// `dudd_members_dead` — tombstones currently held.
    pub dead: Gauge,
    /// `dudd_member_joins_total` — new member ids learned.
    pub joins: Counter,
    /// `dudd_member_suspicions_total` — members turned suspect.
    pub suspicions: Counter,
    /// `dudd_member_deaths_total` — members declared dead.
    pub deaths: Counter,
    /// `dudd_member_refutations_total` — suspicions about *this* node
    /// refuted by an incarnation bump.
    pub refutations: Counter,
}

/// The node's full pre-registered handle bundle: every instrumented
/// layer's metrics, attached to one shared [`MetricsRegistry`], plus
/// the round-trace ring. Cloning shares every underlying metric.
#[derive(Clone, Debug)]
pub struct NodeMetrics {
    registry: Arc<MetricsRegistry>,
    /// Ingest-layer handles.
    pub service: ServiceMetrics,
    /// Gossip-loop handles.
    pub gossip: GossipMetrics,
    /// Transport-layer handles.
    pub transport: Arc<TransportMetrics>,
    /// Membership-plane handles.
    pub membership: Arc<MembershipMetrics>,
    /// The bounded round-trace ring the gossip loop writes.
    pub trace: Arc<TraceRing>,
    /// The JSONL event-log sink, installed by the builder when
    /// `obs_event_log` is configured (empty slot = export disabled).
    pub export: Arc<ObsSlot<EventSink>>,
}

impl NodeMetrics {
    /// Register every `dudd_*` family on `registry` and return the
    /// handle bundle. Idempotent per registry: registering twice hands
    /// back handles to the same underlying metrics.
    pub fn register(registry: &Arc<MetricsRegistry>) -> Result<NodeMetrics> {
        let r = registry.as_ref();
        let service = ServiceMetrics {
            values: r.counter(
                "dudd_ingest_values_total",
                "Finite values folded by the ingest shards.",
            )?,
            batches: r.counter(
                "dudd_ingest_batches_total",
                "Ingest/update batches consumed by the shards.",
            )?,
            dropped: r.counter(
                "dudd_ingest_dropped_total",
                "Non-finite values dropped at the shards.",
            )?,
            epochs: r.counter("dudd_epochs_total", "Epoch folds published.")?,
            epoch_fold: r.histogram(
                "dudd_epoch_fold_seconds",
                "Epoch drain + fold + publish latency in seconds.",
            )?,
        };
        let phase_hist = |phase: RoundPhase| {
            r.histogram_with(
                "dudd_round_phase_seconds",
                "Wall clock per gossip-round phase in seconds.",
                &[("phase", phase.name())],
            )
        };
        let gossip = GossipMetrics {
            rounds: r.counter("dudd_rounds_total", "Gossip rounds executed.")?,
            reseeds: r.counter(
                "dudd_reseeds_total",
                "Protocol restarts (rounds that reseeded the local members).",
            )?,
            exchanges: r.counter(
                "dudd_exchanges_total",
                "Completed initiated push-pull exchanges.",
            )?,
            failed: r.counter(
                "dudd_exchanges_failed_total",
                "Initiated exchanges cancelled (transport failure, busy or stale partner).",
            )?,
            exchange_bytes: r.counter(
                "dudd_exchange_bytes_total",
                "Data-plane wire bytes moved by initiated exchanges.",
            )?,
            membership_bytes: r.counter(
                "dudd_membership_bytes_total",
                "Membership anti-entropy wire bytes moved.",
            )?,
            generation: r.gauge("dudd_generation", "Current restart generation.")?,
            drift: r.gauge(
                "dudd_drift",
                "Largest relative probe-quantile drift of the last round.",
            )?,
            converged: r.gauge(
                "dudd_converged",
                "1 once the probe drift fell to the configured threshold, else 0.",
            )?,
            union_bound: r.gauge(
                "dudd_union_rel_err_bound",
                "Theorem 2 relative-error bound of the union estimate.",
            )?,
            restarts: RestartCounters::register(
                r,
                "dudd_restarts_total",
                "Protocol restarts by cause.",
            )?,
            events_dropped: r.counter(
                "dudd_events_dropped_total",
                "Event-log lines dropped because the writer lagged.",
            )?,
            round_seconds: r.histogram(
                "dudd_round_seconds",
                "Whole gossip-round wall clock in seconds.",
            )?,
            phases: [
                phase_hist(RoundPhase::Refresh)?,
                phase_hist(RoundPhase::Exchange)?,
                phase_hist(RoundPhase::Membership)?,
                phase_hist(RoundPhase::Publish)?,
            ],
        };
        let transport = Arc::new(TransportMetrics {
            pool_fresh_connects: r.counter(
                "dudd_pool_fresh_connects_total",
                "Exchange connections dialed fresh.",
            )?,
            pool_reused: r.counter(
                "dudd_pool_reused_total",
                "Pooled exchange connections checked out.",
            )?,
            pool_stale_discarded: r.counter(
                "dudd_pool_stale_discarded_total",
                "Pooled connections found dead and discarded.",
            )?,
            pool_expired: r.counter(
                "dudd_pool_expired_total",
                "Pooled connections expired idle.",
            )?,
            frames_delta: r.counter(
                "dudd_frames_delta_total",
                "Initiated exchanges pushed as delta frames.",
            )?,
            frames_full: r.counter(
                "dudd_frames_full_total",
                "Initiated exchanges pushed as full frames.",
            )?,
            wire_bytes: r.counter(
                "dudd_wire_bytes_total",
                "Socket bytes moved by initiated exchanges (push + reply).",
            )?,
            exchange_rtt: r.histogram(
                "dudd_exchange_rtt_seconds",
                "Initiated-exchange round-trip time in seconds.",
            )?,
            rejects: RejectCounters::register(
                r,
                "dudd_rejects_total",
                "Exchange rejects received as an initiator, by reason.",
            )?,
            serve_rejects: RejectCounters::register(
                r,
                "dudd_serve_rejects_total",
                "Exchange rejects written while serving, by reason.",
            )?,
        });
        let membership = Arc::new(MembershipMetrics {
            alive: r.gauge("dudd_members_alive", "Members currently alive (self included).")?,
            suspect: r.gauge("dudd_members_suspect", "Members currently suspect.")?,
            dead: r.gauge("dudd_members_dead", "Tombstones currently held.")?,
            joins: r.counter("dudd_member_joins_total", "New member ids learned.")?,
            suspicions: r.counter(
                "dudd_member_suspicions_total",
                "Members turned suspect.",
            )?,
            deaths: r.counter("dudd_member_deaths_total", "Members declared dead.")?,
            refutations: r.counter(
                "dudd_member_refutations_total",
                "Suspicions about this node refuted by an incarnation bump.",
            )?,
        });
        Ok(NodeMetrics {
            registry: registry.clone(),
            service,
            gossip,
            transport,
            membership,
            trace: Arc::new(TraceRing::default()),
            export: Arc::new(ObsSlot::new()),
        })
    }

    /// A standalone bundle on its own private registry — what a
    /// [`GossipLoop`](crate::service::GossipLoop) constructed outside
    /// [`Node::builder`](crate::service::Node::builder) instruments
    /// itself with.
    pub fn standalone() -> NodeMetrics {
        Self::register(&Arc::new(MetricsRegistry::new()))
            .expect("dudd_* families are statically valid")
    }

    /// The registry every handle in this bundle is attached to.
    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }
}

/// A write-once slot a component exposes so the builder can install
/// metric handles *after* the component was constructed (a
/// [`TcpTransport`](crate::service::TcpTransport) is bound before the
/// node that owns it exists). Reads are lock-free; the first install
/// wins and later installs are ignored.
#[derive(Debug, Default)]
pub struct ObsSlot<T>(OnceLock<Arc<T>>);

impl<T> ObsSlot<T> {
    /// An empty slot.
    pub fn new() -> Self {
        ObsSlot(OnceLock::new())
    }

    /// Install `value`; a no-op if something was installed already.
    pub fn install(&self, value: Arc<T>) {
        let _ = self.0.set(value);
    }

    /// The installed value, if any.
    #[inline]
    pub fn get(&self) -> Option<&Arc<T>> {
        self.0.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_metrics_register_is_idempotent() {
        let registry = Arc::new(MetricsRegistry::new());
        let a = NodeMetrics::register(&registry).unwrap();
        let b = NodeMetrics::register(&registry).unwrap();
        a.gossip.exchanges.add(3);
        b.gossip.exchanges.add(4);
        assert_eq!(a.gossip.exchanges.get(), 7, "same underlying counter");
        // One family block despite double registration.
        let text = registry.render();
        assert_eq!(text.matches("# TYPE dudd_exchanges_total").count(), 1);
    }

    #[test]
    fn reject_counters_map_every_reason() {
        let registry = MetricsRegistry::new();
        let rc = RejectCounters::register(&registry, "t_r_total", "x").unwrap();
        use crate::sketch::RejectReason as R;
        for reason in [
            R::Busy,
            R::StaleGeneration,
            R::Lineage,
            R::Malformed,
            R::BaselineMismatch,
            R::NoMembership,
        ] {
            rc.reason(reason).inc();
        }
        for c in [
            &rc.busy,
            &rc.stale_generation,
            &rc.lineage,
            &rc.malformed,
            &rc.baseline_mismatch,
            &rc.no_membership,
        ] {
            assert_eq!(c.get(), 1);
        }
    }

    #[test]
    fn restart_counters_map_every_cause() {
        let registry = MetricsRegistry::new();
        let rc = RestartCounters::register(&registry, "t_restarts_total", "x").unwrap();
        use crate::service::RestartCause as C;
        for cause in [
            C::EpochAdvance,
            C::ViewChange,
            C::GenerationCatchUp,
            C::EpochFallback,
        ] {
            rc.cause(cause).inc();
        }
        for c in [
            &rc.epoch_advance,
            &rc.view_change,
            &rc.generation_catch_up,
            &rc.epoch_fallback,
        ] {
            assert_eq!(c.get(), 1);
        }
    }

    #[test]
    fn obs_slot_first_install_wins() {
        let slot: ObsSlot<u32> = ObsSlot::new();
        assert!(slot.get().is_none());
        slot.install(Arc::new(1));
        slot.install(Arc::new(2));
        assert_eq!(**slot.get().unwrap(), 1);
    }

    #[test]
    fn phase_histograms_are_distinct() {
        let obs = NodeMetrics::standalone();
        obs.gossip.phase(RoundPhase::Refresh).observe(0.5);
        assert_eq!(obs.gossip.phase(RoundPhase::Refresh).count(), 1);
        assert_eq!(obs.gossip.phase(RoundPhase::Exchange).count(), 0);
    }
}
