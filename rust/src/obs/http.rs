//! The `GET /metrics` exposition endpoint: a tiny hand-rolled HTTP/1.1
//! listener over `std::net` (no HTTP dependency exists offline, and a
//! scrape endpoint needs exactly two verbs-worth of routing).
//!
//! One background thread accepts connections (non-blocking accept +
//! short sleep, so shutdown never hangs on `accept`), reads the request
//! head under a **whole-request deadline**, and answers:
//!
//! * `GET /metrics` → `200` with [`MetricsRegistry::render`] output
//!   (`text/plain; version=0.0.4`),
//! * `GET /members` → `200` with the node's gossiped member table as
//!   JSON lines (one flat object per member), when a
//!   [`MembersSource`] was installed at bind time — `404` otherwise,
//! * any other path → `404`,
//! * any other method → `405`.
//!
//! Every response closes the connection — scrapers poll at multi-second
//! intervals, so keep-alive buys nothing and connection state costs.
//!
//! **Slow-client hardening.** Connections are served inline on the
//! accept thread, so one stalled client would head-of-line-block every
//! scrape. Per-`read` timeouts alone don't bound that: a slow-loris
//! client dripping one header byte per interval resets the timeout on
//! each byte and can hold the thread indefinitely. Both directions are
//! therefore capped by absolute deadlines — [`REQUEST_DEADLINE`] from
//! accept to end-of-head, [`RESPONSE_DEADLINE`] for writing the
//! response — enforced by re-arming the socket timeout with the time
//! *remaining* before every read/write.

use super::registry::MetricsRegistry;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cap on the request head we buffer (a scrape request line is tiny;
/// anything larger is junk).
const MAX_REQUEST_HEAD: usize = 4096;

/// Absolute budget from accept to the end of the request head. A client
/// that hasn't produced a complete head by then — silent *or* dripping
/// bytes — is disconnected.
const REQUEST_DEADLINE: Duration = Duration::from_secs(2);

/// Absolute budget for writing one response to a (possibly slow)
/// reader.
const RESPONSE_DEADLINE: Duration = Duration::from_secs(5);

/// Provider of the `GET /members` body: returns the node's current
/// member table as JSON lines, one flat object per member (see
/// `docs/OBSERVABILITY.md` for the schema). Installed by
/// [`MetricsServer::bind_with_members`]; called per request, so the
/// body always reflects the live gossiped view.
pub type MembersSource = Arc<dyn Fn() -> String + Send + Sync>;

/// A running `/metrics` HTTP listener. Binding happens in
/// [`MetricsServer::bind`]; dropping (or [`MetricsServer::shutdown`])
/// stops the accept thread.
#[derive(Debug)]
pub struct MetricsServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back via
    /// [`MetricsServer::local_addr`]) and start serving `registry`.
    /// `GET /members` answers `404` on a server bound this way; use
    /// [`MetricsServer::bind_with_members`] to install a source.
    pub fn bind(addr: SocketAddr, registry: Arc<MetricsRegistry>) -> Result<Self> {
        Self::bind_with_members(addr, registry, None)
    }

    /// [`MetricsServer::bind`] plus a [`MembersSource`] answering
    /// `GET /members` with the node's gossiped member table (fleet
    /// discovery for `dudd-observe`).
    pub fn bind_with_members(
        addr: SocketAddr,
        registry: Arc<MetricsRegistry>,
        members: Option<MembersSource>,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding /metrics on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("metrics listener non-blocking mode")?;
        let local_addr = listener.local_addr().context("metrics listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("dudd-metrics".into())
                .spawn(move || accept_loop(&listener, &registry, members.as_ref(), &stop))
                .context("spawning metrics listener thread")?
        };
        Ok(MetricsServer {
            local_addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop the accept thread and release the port.
    pub fn shutdown(mut self) {
        self.stop_thread();
    }

    fn stop_thread(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

fn accept_loop(
    listener: &TcpListener,
    registry: &Arc<MetricsRegistry>,
    members: Option<&MembersSource>,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Errors on one scrape connection (reset mid-response,
                // slow client timing out) must not take the endpoint
                // down.
                let _ = serve_conn(stream, registry, members);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn serve_conn(
    mut stream: TcpStream,
    registry: &Arc<MetricsRegistry>,
    members: Option<&MembersSource>,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    let head = read_request_head(&mut stream, Instant::now() + REQUEST_DEADLINE)?;
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut content_type = "text/plain; version=0.0.4; charset=utf-8";
    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", "method not allowed\n".to_string())
    } else if path == "/metrics" || path.starts_with("/metrics?") {
        ("200 OK", registry.render())
    } else if path == "/members" {
        match members {
            Some(source) => {
                content_type = "application/x-ndjson";
                ("200 OK", source())
            }
            None => (
                "404 Not Found",
                "no member table on this node (static fleet?)\n".to_string(),
            ),
        }
    } else {
        ("404 Not Found", "not found (try /metrics)\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n{body}",
        body.len()
    );
    write_deadlined(&mut stream, response.as_bytes(), Instant::now() + RESPONSE_DEADLINE)?;
    stream.flush()
}

/// Read until the blank line ending the request head, the size cap, or
/// `deadline` — whichever comes first. The socket read timeout is
/// re-armed with the *remaining* budget before every read, so a client
/// dripping single bytes cannot extend its stay (the slow-loris fix).
/// The body, if any, is ignored — GET has none.
fn read_request_head(stream: &mut TcpStream, deadline: Instant) -> std::io::Result<String> {
    let mut buf = Vec::with_capacity(256);
    let mut chunk = [0u8; 512];
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request head deadline exceeded",
            ));
        }
        stream.set_read_timeout(Some(remaining))?;
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() >= MAX_REQUEST_HEAD {
            break;
        }
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// `write_all` under an absolute deadline: the write timeout is
/// re-armed with the remaining budget before every partial write, so a
/// client draining the response one byte at a time is bounded by
/// `deadline` overall, not per write.
fn write_deadlined(
    stream: &mut TcpStream,
    mut bytes: &[u8],
    deadline: Instant,
) -> std::io::Result<()> {
    while !bytes.is_empty() {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "response write deadline exceeded",
            ));
        }
        stream.set_write_timeout(Some(remaining))?;
        match stream.write(bytes) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "connection closed mid-response",
                ))
            }
            Ok(n) => bytes = &bytes[n..],
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_elsewhere() {
        let registry = Arc::new(MetricsRegistry::new());
        let c = registry.counter("t_http_total", "scrapes").unwrap();
        c.add(9);
        let srv =
            MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry.clone()).unwrap();
        let addr = srv.local_addr();

        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        assert!(ok.contains("t_http_total 9"), "{ok}");
        // Content-Length matches the body exactly.
        let (head, body) = ok.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        // The registry is read live: a later increment shows up.
        c.add(1);
        assert!(get(addr, "/metrics").contains("t_http_total 10"));
        srv.shutdown();
    }

    #[test]
    fn rejects_non_get_methods() {
        let registry = Arc::new(MetricsRegistry::new());
        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry).unwrap();
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 405"), "{out}");
    }

    #[test]
    fn members_endpoint_serves_installed_source_and_404s_without_one() {
        let registry = Arc::new(MetricsRegistry::new());
        let source: MembersSource = Arc::new(|| {
            "{\"id\":0,\"addr\":\"10.0.0.1:7400\",\"status\":\"alive\"}\n\
             {\"id\":1,\"addr\":\"10.0.0.2:7400\",\"status\":\"suspect\"}\n"
                .to_string()
        });
        let srv = MetricsServer::bind_with_members(
            "127.0.0.1:0".parse().unwrap(),
            registry.clone(),
            Some(source),
        )
        .unwrap();
        let out = get(srv.local_addr(), "/members");
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");
        assert!(out.contains("application/x-ndjson"), "{out}");
        assert!(out.contains("\"addr\":\"10.0.0.2:7400\""), "{out}");
        // /metrics still serves next to it.
        assert!(get(srv.local_addr(), "/metrics").starts_with("HTTP/1.1 200"));
        srv.shutdown();

        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry).unwrap();
        let out = get(srv.local_addr(), "/members");
        assert!(out.starts_with("HTTP/1.1 404"), "{out}");
        srv.shutdown();
    }

    /// Slow-loris regression: a client dripping header bytes (each
    /// arriving well inside any per-read timeout) is disconnected once
    /// the whole-request deadline expires, and the endpoint serves the
    /// next scrape normally afterwards.
    #[test]
    fn drip_fed_request_head_is_cut_at_the_deadline() {
        let registry = Arc::new(MetricsRegistry::new());
        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry).unwrap();
        let addr = srv.local_addr();

        let started = std::time::Instant::now();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut out = Vec::new();
        // Drip one byte per 100 ms, never completing the head. Without
        // an absolute deadline each byte re-arms the read timeout and
        // the connection (and with it the single accept thread) hangs
        // until the head cap — minutes, not seconds.
        for b in b"GET /metrics HTTP/1.1\r\nHost: x\r\nX-Drip: ".iter().cycle() {
            if s.write_all(std::slice::from_ref(b)).is_err() {
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
            s.set_read_timeout(Some(Duration::from_millis(1))).unwrap();
            match s.read_to_end(&mut out) {
                Ok(_) => break, // server closed the connection
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(_) => break, // reset also counts as disconnection
            }
            assert!(
                started.elapsed() < REQUEST_DEADLINE + Duration::from_secs(3),
                "server kept a dripping client past the request deadline"
            );
        }
        assert!(
            started.elapsed() >= Duration::from_millis(300),
            "client was cut before it even started dripping"
        );
        assert!(out.is_empty(), "no response owed to a timed-out request");

        // The endpoint is healthy again for the next scrape.
        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        srv.shutdown();
    }

    #[test]
    fn shutdown_releases_the_port() {
        let registry = Arc::new(MetricsRegistry::new());
        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry.clone()).unwrap();
        let addr = srv.local_addr();
        srv.shutdown();
        // Rebinding the exact address succeeds once the thread exits.
        let srv2 = MetricsServer::bind(addr, registry).unwrap();
        assert_eq!(srv2.local_addr(), addr);
    }
}
