//! The `GET /metrics` exposition endpoint: a tiny hand-rolled HTTP/1.1
//! listener over `std::net` (no HTTP dependency exists offline, and a
//! scrape endpoint needs exactly one verb and one path).
//!
//! One background thread accepts connections (non-blocking accept +
//! short sleep, so shutdown never hangs on `accept`), reads the request
//! head with a read timeout, and answers:
//!
//! * `GET /metrics` → `200` with [`MetricsRegistry::render`] output
//!   (`text/plain; version=0.0.4`),
//! * any other path → `404`,
//! * any other method → `405`.
//!
//! Every response closes the connection — scrapers poll at multi-second
//! intervals, so keep-alive buys nothing and connection state costs.

use super::registry::MetricsRegistry;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Cap on the request head we buffer (a scrape request line is tiny;
/// anything larger is junk).
const MAX_REQUEST_HEAD: usize = 4096;

/// A running `/metrics` HTTP listener. Binding happens in
/// [`MetricsServer::bind`]; dropping (or [`MetricsServer::shutdown`])
/// stops the accept thread.
#[derive(Debug)]
pub struct MetricsServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back via
    /// [`MetricsServer::local_addr`]) and start serving `registry`.
    pub fn bind(addr: SocketAddr, registry: Arc<MetricsRegistry>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding /metrics on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("metrics listener non-blocking mode")?;
        let local_addr = listener.local_addr().context("metrics listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("dudd-metrics".into())
                .spawn(move || accept_loop(&listener, &registry, &stop))
                .context("spawning metrics listener thread")?
        };
        Ok(MetricsServer {
            local_addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop the accept thread and release the port.
    pub fn shutdown(mut self) {
        self.stop_thread();
    }

    fn stop_thread(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

fn accept_loop(listener: &TcpListener, registry: &Arc<MetricsRegistry>, stop: &AtomicBool) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Errors on one scrape connection (reset mid-response,
                // slow client timing out) must not take the endpoint
                // down.
                let _ = serve_conn(stream, registry);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn serve_conn(mut stream: TcpStream, registry: &Arc<MetricsRegistry>) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let head = read_request_head(&mut stream)?;
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", "method not allowed\n".to_string())
    } else if path == "/metrics" || path.starts_with("/metrics?") {
        ("200 OK", registry.render())
    } else {
        ("404 Not Found", "not found (try /metrics)\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Read until the blank line ending the request head (or the size cap /
/// read timeout). The body, if any, is ignored — GET has none.
fn read_request_head(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut buf = Vec::with_capacity(256);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() >= MAX_REQUEST_HEAD {
            break;
        }
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_elsewhere() {
        let registry = Arc::new(MetricsRegistry::new());
        let c = registry.counter("t_http_total", "scrapes").unwrap();
        c.add(9);
        let srv =
            MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry.clone()).unwrap();
        let addr = srv.local_addr();

        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        assert!(ok.contains("t_http_total 9"), "{ok}");
        // Content-Length matches the body exactly.
        let (head, body) = ok.split_once("\r\n\r\n").unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        // The registry is read live: a later increment shows up.
        c.add(1);
        assert!(get(addr, "/metrics").contains("t_http_total 10"));
        srv.shutdown();
    }

    #[test]
    fn rejects_non_get_methods() {
        let registry = Arc::new(MetricsRegistry::new());
        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry).unwrap();
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 405"), "{out}");
    }

    #[test]
    fn shutdown_releases_the_port() {
        let registry = Arc::new(MetricsRegistry::new());
        let srv = MetricsServer::bind("127.0.0.1:0".parse().unwrap(), registry.clone()).unwrap();
        let addr = srv.local_addr();
        srv.shutdown();
        // Rebinding the exact address succeeds once the thread exits.
        let srv2 = MetricsServer::bind(addr, registry).unwrap();
        assert_eq!(srv2.local_addr(), addr);
    }
}
