//! The metrics registry: atomic counters/gauges, `UddSketch`-backed
//! latency histograms, and the Prometheus text-format renderer.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones registered once at node construction and then updated from
//! the hot paths with no registry involvement at all: a counter update
//! is one relaxed `fetch_add`, a gauge update one relaxed `store`, and
//! a histogram observation a short mutex-guarded sketch insert (the
//! sketch itself is the crate's own [`UddSketch`] — the node dogfoods
//! the very instrument it serves, so `/metrics` quantiles inherit the
//! paper's relative-error guarantee).
//!
//! [`MetricsRegistry::render`] walks the registered families in
//! registration order and emits Prometheus exposition text (version
//! 0.0.4): counters and gauges as single samples, histograms as
//! *summaries* with `quantile="0.5|0.9|0.99|0.999"` sample lines plus
//! `_sum`/`_count`.

use crate::sketch::{DenseStore, UddSketch};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Sketch accuracy for latency histograms: 1% relative error is far
/// below anything a latency dashboard can resolve.
const HIST_ALPHA: f64 = 0.01;
/// Bucket budget per latency histogram (~2 KiB resident; spans
/// nanoseconds to hours at α = 1%).
const HIST_BUCKETS: usize = 512;
/// The quantiles a histogram family exposes as summary samples.
pub const SUMMARY_QUANTILES: [f64; 4] = [0.5, 0.9, 0.99, 0.999];

/// A monotonically increasing `u64` metric handle. Cloning shares the
/// underlying atomic; updates are relaxed (`/metrics` is a statistical
/// read, not a synchronization point).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An `f64` gauge handle (value stored as bits in one atomic — set and
/// read are single relaxed operations, never torn).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Set from an integer count (membership gauges and the like).
    #[inline]
    pub fn set_usize(&self, v: usize) {
        self.set(v as f64);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistState {
    sketch: UddSketch<DenseStore>,
    sum: f64,
    count: u64,
}

/// A latency histogram handle backed by a [`UddSketch`]: observations
/// fold into the sketch (relative-error quantiles), exported as a
/// Prometheus summary. The short internal mutex is held only across one
/// sketch insert — observation sites are per-batch or per-exchange,
/// never per-value.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<Mutex<HistState>>);

impl Histogram {
    fn new() -> Self {
        Histogram(Arc::new(Mutex::new(HistState {
            sketch: UddSketch::new(HIST_ALPHA, HIST_BUCKETS)
                .expect("histogram sketch parameters are compile-time constants"),
            sum: 0.0,
            count: 0,
        })))
    }

    fn lock_state(&self) -> std::sync::MutexGuard<'_, HistState> {
        self.0.lock().expect("histogram poisoned")
    }

    /// Record one observation (seconds, for latency families).
    /// Non-finite values are dropped — a poisoned timer must not poison
    /// the histogram.
    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut h = self.lock_state();
        h.sketch.insert(v);
        h.sum += v;
        h.count += 1;
    }

    /// The q-quantile of everything observed, or `None` while empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        self.lock_state().sketch.quantile(q).ok()
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.lock_state().count
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.lock_state().sum
    }

    /// Snapshot `(quantile values for `SUMMARY_QUANTILES`, sum, count)`
    /// under one lock acquisition (render path).
    fn summary(&self) -> ([Option<f64>; 4], f64, u64) {
        let h = self.lock_state();
        let mut qs = [None; 4];
        for (slot, &q) in qs.iter_mut().zip(SUMMARY_QUANTILES.iter()) {
            *slot = h.sketch.quantile(q).ok();
        }
        (qs, h.sum, h.count)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum MetricKind {
    Counter,
    Gauge,
    Summary,
}

impl MetricKind {
    fn exposition(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Summary => "summary",
        }
    }
}

#[derive(Debug)]
enum SampleValue {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug)]
struct Sample {
    labels: Vec<(String, String)>,
    value: SampleValue,
}

#[derive(Debug)]
struct Family {
    name: String,
    help: String,
    kind: MetricKind,
    samples: Vec<Sample>,
}

/// The node-wide metric registry: named families of counters, gauges,
/// and histograms, rendered on demand as Prometheus exposition text.
///
/// Registration is idempotent: registering a name+label set that
/// already exists (with the same kind) returns a handle to the
/// **same** underlying metric, so independently-constructed components
/// can share families safely. A kind conflict is an error.
///
/// ```
/// use duddsketch::obs::MetricsRegistry;
///
/// let reg = MetricsRegistry::new();
/// let c = reg.counter("demo_ops_total", "operations served").unwrap();
/// c.add(3);
/// let text = reg.render();
/// assert!(text.contains("# TYPE demo_ops_total counter"));
/// assert!(text.contains("demo_ops_total 3"));
/// ```
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    families: Mutex<Vec<Family>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn lock_families(&self) -> std::sync::MutexGuard<'_, Vec<Family>> {
        self.families.lock().expect("metric registry poisoned")
    }

    /// Register (or look up) an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Result<Counter> {
        self.counter_with(name, help, &[])
    }

    /// Register (or look up) a counter with the given labels.
    pub fn counter_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Result<Counter> {
        let v = self.register(name, help, MetricKind::Counter, labels, || {
            SampleValue::Counter(Counter::default())
        })?;
        match v {
            SampleValue::Counter(c) => Ok(c),
            _ => unreachable!("kind checked by register"),
        }
    }

    /// Register (or look up) an unlabeled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Result<Gauge> {
        self.gauge_with(name, help, &[])
    }

    /// Register (or look up) a gauge with the given labels.
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Result<Gauge> {
        let v = self.register(name, help, MetricKind::Gauge, labels, || {
            SampleValue::Gauge(Gauge::default())
        })?;
        match v {
            SampleValue::Gauge(g) => Ok(g),
            _ => unreachable!("kind checked by register"),
        }
    }

    /// Register (or look up) an unlabeled latency histogram (exported
    /// as a summary family).
    pub fn histogram(&self, name: &str, help: &str) -> Result<Histogram> {
        self.histogram_with(name, help, &[])
    }

    /// Register (or look up) a latency histogram with the given labels.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Result<Histogram> {
        let v = self.register(name, help, MetricKind::Summary, labels, || {
            SampleValue::Histogram(Histogram::new())
        })?;
        match v {
            SampleValue::Histogram(h) => Ok(h),
            _ => unreachable!("kind checked by register"),
        }
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
        mk: impl FnOnce() -> SampleValue,
    ) -> Result<SampleValue> {
        if !valid_metric_name(name) {
            bail!("invalid metric name {name:?} (want [a-zA-Z_:][a-zA-Z0-9_:]*)");
        }
        for (k, _) in labels {
            if !valid_label_name(k) {
                bail!("invalid label name {k:?} on metric {name} (want [a-zA-Z_][a-zA-Z0-9_]*)");
            }
            if *k == "quantile" {
                bail!("label name \"quantile\" on metric {name} is reserved for summaries");
            }
        }
        let labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        let mut families = self.lock_families();
        let fam = match families.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                if f.kind != kind {
                    bail!(
                        "metric {name} already registered as a {}, not a {}",
                        f.kind.exposition(),
                        kind.exposition()
                    );
                }
                f
            }
            None => {
                families.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    samples: Vec::new(),
                });
                families.last_mut().expect("just pushed")
            }
        };
        if let Some(existing) = fam.samples.iter().find(|s| s.labels == labels) {
            return Ok(clone_value(&existing.value));
        }
        let value = mk();
        let out = clone_value(&value);
        fam.samples.push(Sample { labels, value });
        Ok(out)
    }

    /// Render every registered family as Prometheus text exposition
    /// (content type `text/plain; version=0.0.4`), families in
    /// registration order.
    pub fn render(&self) -> String {
        let families = self.lock_families();
        let mut out = String::with_capacity(4096);
        for f in families.iter() {
            out.push_str("# HELP ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(&escape_help(&f.help));
            out.push('\n');
            out.push_str("# TYPE ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(f.kind.exposition());
            out.push('\n');
            for s in &f.samples {
                match &s.value {
                    SampleValue::Counter(c) => {
                        sample_line(&mut out, &f.name, "", &s.labels, None, c.get() as f64);
                    }
                    SampleValue::Gauge(g) => {
                        sample_line(&mut out, &f.name, "", &s.labels, None, g.get());
                    }
                    SampleValue::Histogram(h) => {
                        let (qs, sum, count) = h.summary();
                        for (q, v) in SUMMARY_QUANTILES.iter().zip(qs.iter()) {
                            sample_line(
                                &mut out,
                                &f.name,
                                "",
                                &s.labels,
                                Some(*q),
                                v.unwrap_or(f64::NAN),
                            );
                        }
                        sample_line(&mut out, &f.name, "_sum", &s.labels, None, sum);
                        sample_line(&mut out, &f.name, "_count", &s.labels, None, count as f64);
                    }
                }
            }
        }
        out
    }
}

fn clone_value(v: &SampleValue) -> SampleValue {
    match v {
        SampleValue::Counter(c) => SampleValue::Counter(c.clone()),
        SampleValue::Gauge(g) => SampleValue::Gauge(g.clone()),
        SampleValue::Histogram(h) => SampleValue::Histogram(h.clone()),
    }
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label_value(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Prometheus text floats: `NaN`, `+Inf`, `-Inf`, plain decimal
/// otherwise (Rust's `{}` for finite f64 round-trips exactly).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn sample_line(
    out: &mut String,
    name: &str,
    suffix: &str,
    labels: &[(String, String)],
    quantile: Option<f64>,
    value: f64,
) {
    out.push_str(name);
    out.push_str(suffix);
    if !labels.is_empty() || quantile.is_some() {
        out.push('{');
        let mut first = true;
        for (k, v) in labels {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label_value(v));
            out.push('"');
        }
        if let Some(q) = quantile {
            if !first {
                out.push(',');
            }
            out.push_str("quantile=\"");
            out.push_str(&fmt_value(q));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_value(value));
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::ExactQuantiles;
    use std::collections::HashMap;

    #[test]
    fn concurrent_counter_and_histogram_updates_are_exact() {
        let reg = Arc::new(MetricsRegistry::new());
        let c = reg.counter("t_ops_total", "ops").unwrap();
        let h = reg.histogram("t_lat_seconds", "latency").unwrap();
        const THREADS: usize = 8;
        const PER: u64 = 10_000;
        let handles: Vec<_> = (0..THREADS)
            .map(|k| {
                let c = c.clone();
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        c.inc();
                        // Distinct per-thread values keep the sum exact
                        // in f64 (all values are small integers).
                        h.observe((k as u64 * PER + i) as f64);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(c.get(), THREADS as u64 * PER);
        assert_eq!(h.count(), THREADS as u64 * PER);
        let n = THREADS as u64 * PER;
        assert_eq!(h.sum(), (n * (n - 1) / 2) as f64);
    }

    #[test]
    fn histogram_quantiles_match_exact_within_alpha() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("t_q_seconds", "q").unwrap();
        let data: Vec<f64> = (1..=10_000).map(|i| (i as f64).powf(1.3)).collect();
        for &x in &data {
            h.observe(x);
        }
        let exact = ExactQuantiles::new(&data);
        for q in [0.5, 0.9, 0.99, 0.999] {
            let est = h.quantile(q).unwrap();
            let want = exact.quantile(q).unwrap();
            let rel = (est - want).abs() / want.abs();
            assert!(rel <= HIST_ALPHA + 1e-9, "q={q}: est {est} vs exact {want}");
        }
    }

    #[test]
    fn registration_is_idempotent_and_kind_checked() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("t_same_total", "x").unwrap();
        let b = reg.counter("t_same_total", "x").unwrap();
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same handle behind both registrations");
        assert!(reg.gauge("t_same_total", "x").is_err(), "kind conflict");
        assert!(reg.counter("0bad", "x").is_err(), "bad metric name");
        assert!(
            reg.counter_with("t_lbl_total", "x", &[("bad-label", "v")])
                .is_err(),
            "bad label name"
        );
        assert!(
            reg.counter_with("t_lbl_total", "x", &[("quantile", "v")])
                .is_err(),
            "reserved label"
        );
    }

    #[test]
    fn labeled_samples_share_one_family_block() {
        let reg = MetricsRegistry::new();
        let busy = reg
            .counter_with("t_rej_total", "rejects", &[("reason", "busy")])
            .unwrap();
        let stale = reg
            .counter_with("t_rej_total", "rejects", &[("reason", "stale")])
            .unwrap();
        busy.add(2);
        stale.add(5);
        let text = reg.render();
        assert_eq!(text.matches("# TYPE t_rej_total counter").count(), 1);
        assert!(text.contains("t_rej_total{reason=\"busy\"} 2"), "{text}");
        assert!(text.contains("t_rej_total{reason=\"stale\"} 5"), "{text}");
    }

    /// Exposition round-trip: every rendered sample line parses back
    /// into (name, labels, float value), every family has HELP + TYPE
    /// before its first sample, and the parsed values match the
    /// handles.
    #[test]
    fn exposition_round_trips_through_a_parser() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("t_c_total", "counter help").unwrap();
        let g = reg.gauge("t_g", "gauge \"help\"\nwith newline").unwrap();
        let h = reg.histogram("t_h_seconds", "hist").unwrap();
        c.add(42);
        g.set(-1.5);
        for i in 1..=100 {
            h.observe(i as f64 / 100.0);
        }
        let text = reg.render();

        let mut typed: HashMap<String, String> = HashMap::new();
        let mut helped: HashMap<String, String> = HashMap::new();
        let mut values: HashMap<String, f64> = HashMap::new();
        for line in text.lines() {
            assert!(!line.is_empty(), "no blank lines in exposition");
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').expect("HELP has name + text");
                assert!(
                    !helped.contains_key(name),
                    "HELP emitted once per family: {name}"
                );
                helped.insert(name.to_string(), help.to_string());
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, kind) = rest.split_once(' ').expect("TYPE has name + kind");
                assert!(helped.contains_key(name), "HELP precedes TYPE: {line}");
                assert!(
                    matches!(kind, "counter" | "gauge" | "summary"),
                    "known kind: {line}"
                );
                typed.insert(name.to_string(), kind.to_string());
                continue;
            }
            // Sample line: name[{labels}] value
            let (key, value) = line.rsplit_once(' ').expect("sample has value");
            let v: f64 = value.parse().unwrap_or_else(|_| {
                panic!("sample value parses as f64: {line}")
            });
            let name = key.split('{').next().unwrap();
            let family = name
                .strip_suffix("_sum")
                .or_else(|| name.strip_suffix("_count"))
                .filter(|base| typed.get(*base).map(String::as_str) == Some("summary"))
                .unwrap_or(name);
            assert!(typed.contains_key(family), "TYPE precedes samples: {line}");
            values.insert(key.to_string(), v);
        }
        assert_eq!(values["t_c_total"], 42.0);
        assert_eq!(values["t_g"], -1.5);
        assert_eq!(values["t_h_seconds_count"], 100.0);
        assert!((values["t_h_seconds_sum"] - 50.5).abs() < 1e-9);
        let p50 = values["t_h_seconds{quantile=\"0.5\"}"];
        assert!((p50 - 0.5).abs() / 0.5 <= HIST_ALPHA + 1e-9, "p50 {p50}");
        assert_eq!(
            helped["t_g"], "gauge \"help\"\\nwith newline",
            "help newline escaped"
        );
    }

    #[test]
    fn empty_histogram_renders_nan_quantiles_and_zero_count() {
        let reg = MetricsRegistry::new();
        reg.histogram("t_empty_seconds", "no data yet").unwrap();
        let text = reg.render();
        assert!(
            text.contains("t_empty_seconds{quantile=\"0.5\"} NaN"),
            "{text}"
        );
        assert!(text.contains("t_empty_seconds_count 0"), "{text}");
        // "NaN" is a parseable Prometheus float.
        assert!("NaN".parse::<f64>().unwrap().is_nan());
    }

    #[test]
    fn gauge_stores_any_f64() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(f64::INFINITY);
        assert_eq!(g.get(), f64::INFINITY);
        assert_eq!(fmt_value(g.get()), "+Inf");
        g.set_usize(7);
        assert_eq!(g.get(), 7.0);
    }
}
