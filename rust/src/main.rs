//! `duddsketch` binary — the Layer-3 coordinator entry point.
//!
//! See `duddsketch help` (or [`duddsketch::cli::USAGE`]) for subcommands.

#![forbid(unsafe_code)]

use duddsketch::cli;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::Args::parse(&raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{}", cli::USAGE);
            std::process::exit(2);
        }
    };
    match cli::dispatch(&args) {
        Ok(text) => print!("{text}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
