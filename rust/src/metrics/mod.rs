//! Error metrics and summary statistics used by the evaluation (§7).

#![forbid(unsafe_code)]

/// Relative error `|est − truth| / |truth|` (Eq. 10's per-peer term).
#[inline]
pub fn relative_error(est: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        if est == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (est - truth).abs() / truth.abs()
    }
}

/// Average Relative Error across peers (Eq. 10):
/// `ARE_q = (1/p) Σ_i |x̃_{q,i} − x̂_q| / x̂_q`.
pub fn average_relative_error(estimates: &[f64], truth: f64) -> f64 {
    if estimates.is_empty() {
        return 0.0;
    }
    estimates
        .iter()
        .map(|&e| relative_error(e, truth))
        .sum::<f64>()
        / estimates.len() as f64
}

/// Sample variance of Jelasity's variance-reduction analysis (Eq. 5):
/// `σ² = 1/(p−1) Σ (w_l − w̄)²` around the supplied true mean `w̄`.
pub fn variance_around(values: &[f64], mean: f64) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    values.iter().map(|&w| (w - mean) * (w - mean)).sum::<f64>()
        / (values.len() - 1) as f64
}

/// Mean of a slice (0 for empty).
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

/// Box-and-whisker summary matching the paper's plots: quartiles plus
/// whiskers at the most extreme points within 1.5·IQR (Tukey), and the
/// count of outliers beyond them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxSummary {
    /// Lower whisker (min point ≥ Q1 − 1.5 IQR).
    pub whisker_lo: f64,
    /// First quartile.
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    /// Upper whisker (max point ≤ Q3 + 1.5 IQR).
    pub whisker_hi: f64,
    /// Minimum observation.
    pub min: f64,
    /// Maximum observation.
    pub max: f64,
    /// Observations outside the whiskers.
    pub outliers: usize,
}

impl BoxSummary {
    /// Compute from unsorted data; returns `None` on empty input.
    pub fn from_data(data: &[f64]) -> Option<Self> {
        if data.is_empty() {
            return None;
        }
        let mut s = data.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in BoxSummary input"));
        let q = |p: f64| -> f64 {
            // Linear interpolation between closest ranks (type-7, the
            // matplotlib/numpy default used by the paper's plots).
            let h = p * (s.len() as f64 - 1.0);
            let lo = h.floor() as usize;
            let hi = h.ceil() as usize;
            s[lo] + (h - h.floor()) * (s[hi] - s[lo])
        };
        let (q1, median, q3) = (q(0.25), q(0.5), q(0.75));
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = s
            .iter()
            .copied()
            .find(|&x| x >= lo_fence)
            .unwrap_or(s[0]);
        let whisker_hi = s
            .iter()
            .rev()
            .copied()
            .find(|&x| x <= hi_fence)
            .unwrap_or(s[s.len() - 1]);
        let outliers =
            s.iter().filter(|&&x| x < whisker_lo || x > whisker_hi).count();
        Some(Self {
            whisker_lo,
            q1,
            median,
            q3,
            whisker_hi,
            min: s[0],
            max: s[s.len() - 1],
            outliers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_basics() {
        assert_eq!(relative_error(110.0, 100.0), 0.1);
        assert_eq!(relative_error(90.0, 100.0), 0.1);
        assert_eq!(relative_error(0.0, 0.0), 0.0);
        assert!(relative_error(1.0, 0.0).is_infinite());
    }

    #[test]
    fn are_eq10() {
        // Three peers estimating truth=100 with 90, 100, 120:
        // ARE = (0.1 + 0 + 0.2)/3 = 0.1
        let are = average_relative_error(&[90.0, 100.0, 120.0], 100.0);
        assert!((are - 0.1).abs() < 1e-12);
    }

    #[test]
    fn variance_eq5() {
        // values {1,2,3}, mean 2 -> (1+0+1)/2 = 1
        assert_eq!(variance_around(&[1.0, 2.0, 3.0], 2.0), 1.0);
        assert_eq!(variance_around(&[5.0], 5.0), 0.0);
    }

    #[test]
    fn box_summary_quartiles() {
        let data: Vec<f64> = (1..=9).map(|x| x as f64).collect();
        let b = BoxSummary::from_data(&data).unwrap();
        assert_eq!(b.median, 5.0);
        assert_eq!(b.q1, 3.0);
        assert_eq!(b.q3, 7.0);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 9.0);
        assert_eq!(b.outliers, 0);
        assert_eq!(b.whisker_lo, 1.0);
        assert_eq!(b.whisker_hi, 9.0);
    }

    #[test]
    fn box_summary_flags_outliers() {
        let mut data: Vec<f64> = (1..=20).map(|x| x as f64).collect();
        data.push(1000.0);
        let b = BoxSummary::from_data(&data).unwrap();
        assert_eq!(b.outliers, 1);
        assert!(b.whisker_hi <= 20.0);
        assert_eq!(b.max, 1000.0);
    }

    #[test]
    fn box_summary_empty_and_singleton() {
        assert!(BoxSummary::from_data(&[]).is_none());
        let b = BoxSummary::from_data(&[7.0]).unwrap();
        assert_eq!(b.median, 7.0);
        assert_eq!(b.q1, 7.0);
        assert_eq!(b.whisker_hi, 7.0);
    }
}
