//! PJRT runtime: loads AOT-compiled XLA artifacts (HLO text produced by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path interface to the compiled kernels. The interchange
//! format is HLO **text** — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` binding crate is not in the offline registry, so the whole
//! execution path is gated behind the `pjrt` cargo feature. Without it,
//! [`Runtime::cpu`] returns a clean error and everything that would run an
//! artifact (the PJRT executor, `figure` legs, benches) degrades to the
//! native path; artifact *inventory* ([`artifacts_dir`],
//! [`list_shaped_artifacts`]) works in every build.

#![forbid(unsafe_code)]

use std::path::PathBuf;

/// Directory holding `*.hlo.txt` artifacts (env `DUDD_ARTIFACTS` wins,
/// default `artifacts/` relative to the working directory).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DUDD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::artifacts_dir;
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;

    /// A loaded, compiled artifact ready to execute.
    pub struct Executable {
        name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    impl std::fmt::Debug for Executable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Executable({})", self.name)
        }
    }

    impl Executable {
        /// Artifact name (file stem).
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with the given inputs; returns the outputs of the lowered
        /// function (the AOT path lowers with `return_tuple=True`, so the
        /// single device output tuple is decomposed).
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let buffers = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing artifact {}", self.name))?;
            let lit = buffers
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| anyhow!("artifact {} returned no buffers", self.name))?
                .to_literal_sync()?;
            Ok(lit.to_tuple()?)
        }

        /// Execute and expect exactly one output.
        pub fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
            let mut outs = self.run(inputs)?;
            if outs.len() != 1 {
                bail!(
                    "artifact {} returned {} outputs, expected 1",
                    self.name,
                    outs.len()
                );
            }
            Ok(outs.remove(0))
        }
    }

    /// PJRT CPU client wrapper with an artifact compile cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<String, std::rc::Rc<Executable>>,
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(
                f,
                "Runtime(platform={}, cached={})",
                self.client.platform_name(),
                self.cache.len()
            )
        }
    }

    impl Runtime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                cache: HashMap::new(),
            })
        }

        /// PJRT platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile an HLO-text artifact file (memoized by stem).
        pub fn load_path(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("artifact")
                .trim_end_matches(".hlo") // file_stem of x.hlo.txt is x.hlo
                .to_string();
            if let Some(e) = self.cache.get(&name) {
                return Ok(e.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            let e = std::rc::Rc::new(Executable {
                name: name.clone(),
                exe,
            });
            self.cache.insert(name, e.clone());
            Ok(e)
        }

        /// Load `<artifacts_dir>/<name>.hlo.txt`.
        pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
            let path = artifacts_dir().join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "artifact {} not found (run `make artifacts`)",
                    path.display()
                );
            }
            self.load_path(&path)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub for the compiled-artifact handle: the `pjrt` feature is off, so
    /// no value of this type can ever be constructed.
    #[derive(Debug)]
    pub struct Executable {
        _never: std::convert::Infallible,
    }

    impl Executable {
        /// Artifact name (file stem).
        pub fn name(&self) -> &str {
            match self._never {}
        }
    }

    /// Stub PJRT client: construction always fails with a clear message, so
    /// every caller degrades along its normal "PJRT unavailable" path.
    #[derive(Debug)]
    pub struct Runtime {
        _never: std::convert::Infallible,
    }

    impl Runtime {
        /// Always fails: PJRT support is not compiled into this build.
        pub fn cpu() -> Result<Self> {
            bail!(
                "PJRT support not compiled in (rebuild with `--features pjrt` \
                 and an `xla` path dependency)"
            )
        }

        /// PJRT platform name (unreachable — see [`Runtime::cpu`]).
        pub fn platform(&self) -> String {
            match self._never {}
        }

        /// Load an artifact file (unreachable — see [`Runtime::cpu`]).
        pub fn load_path(&mut self, _path: &Path) -> Result<std::rc::Rc<Executable>> {
            match self._never {}
        }

        /// Load a named artifact (unreachable — see [`Runtime::cpu`]).
        pub fn load(&mut self, _name: &str) -> Result<std::rc::Rc<Executable>> {
            match self._never {}
        }
    }
}

pub use pjrt_impl::{Executable, Runtime};

/// Parse `<prefix>_p<P>_w<W>` style artifact names.
pub fn parse_shape_suffix(stem: &str, prefix: &str) -> Option<(usize, usize)> {
    let rest = stem.strip_prefix(prefix)?.strip_prefix("_p")?;
    let (p, w) = rest.split_once("_w")?;
    Some((p.parse().ok()?, w.parse().ok()?))
}

/// List `(P, W, path)` for artifacts named `<prefix>_p<P>_w<W>.hlo.txt`,
/// sorted by P then W.
pub fn list_shaped_artifacts(prefix: &str) -> Vec<(usize, usize, PathBuf)> {
    let dir = artifacts_dir();
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            let path = e.path();
            let stem = match path.file_name().and_then(|s| s.to_str()) {
                Some(s) if s.ends_with(".hlo.txt") => {
                    s.trim_end_matches(".hlo.txt").to_string()
                }
                _ => continue,
            };
            if let Some((p, w)) = parse_shape_suffix(&stem, prefix) {
                out.push((p, w, path));
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shape_suffix_works() {
        assert_eq!(
            parse_shape_suffix("avg_pairs_p256_w1024", "avg_pairs"),
            Some((256, 1024))
        );
        assert_eq!(parse_shape_suffix("avg_pairs_p256", "avg_pairs"), None);
        assert_eq!(parse_shape_suffix("other_p1_w2", "avg_pairs"), None);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT plugin (or feature) in this build
        };
        let err = rt.load("definitely_not_there").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
