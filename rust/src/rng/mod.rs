//! Deterministic pseudo-random number generation and the samplers used by
//! the paper's workloads (§7.1, Table 1) and churn models (§7.2).
//!
//! The offline crate registry does not carry `rand`, so this module is a
//! self-contained substrate: a [`SplitMix64`] seeder, the [`Xoshiro256pp`]
//! generator (Blackman–Vigna xoshiro256++, period 2^256−1), and the
//! distribution samplers the experiments need. Everything is deterministic
//! given a seed, which the experiment harness relies on for reproducible
//! figures.

#![forbid(unsafe_code)]

mod distributions;

pub use distributions::{Exponential, Normal, Sample, ShiftedPareto, Uniform};

/// Minimal RNG interface: a source of uniform `u64`s plus derived helpers.
pub trait Rng {
    /// Next uniformly distributed 64-bit value.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits / 2^53 — the standard unbiased construction.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the open interval `(0, 1]` (safe for `ln`).
    #[inline]
    fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below: bound must be positive");
        // Unbiased bounded sampling (Lemire 2019).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` index in `[0, bound)`.
    #[inline]
    fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Passes BigCrush on its own; here it only seeds [`Xoshiro256pp`], the
/// construction recommended by the xoshiro authors.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the crate's default generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for subcomponent `tag` (peer id,
    /// dataset id, …). Streams from distinct tags are effectively
    /// uncorrelated because the tag passes through SplitMix64 diffusion.
    pub fn derive(&self, tag: u64) -> Self {
        let mut sm = SplitMix64::new(
            self.s[0] ^ tag.wrapping_mul(0xA24BAED4963EE407),
        );
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

/// Default seeded generator used across the crate.
pub fn default_rng(seed: u64) -> Xoshiro256pp {
    Xoshiro256pp::seed_from_u64(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain
        // splitmix64.c by Sebastiano Vigna.
        let mut sm = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(got[0], 6457827717110365317);
        assert_eq!(got[1], 3203168211198807973);
        assert_eq!(got[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = default_rng(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn next_below_is_in_range_and_roughly_uniform() {
        let mut r = default_rng(11);
        let bound = 10u64;
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            let v = r.next_below(bound);
            assert!(v < bound);
            counts[v as usize] += 1;
        }
        // Each bin expects n/10 = 10_000; allow ±5% (well beyond 6σ).
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (9_500..=10_500).contains(&c),
                "bin {i} count {c} out of tolerance"
            );
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = default_rng(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn derived_streams_differ() {
        let base = Xoshiro256pp::seed_from_u64(5);
        let mut d1 = base.derive(1);
        let mut d2 = base.derive(2);
        let v1: Vec<u64> = (0..4).map(|_| d1.next_u64()).collect();
        let v2: Vec<u64> = (0..4).map(|_| d2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = default_rng(9);
        let mut xs: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let mut sorted_before = xs.clone();
        sorted_before.sort_unstable();
        r.shuffle(&mut xs);
        xs.sort_unstable();
        assert_eq!(xs, sorted_before);
    }
}
