//! Distribution samplers for the paper's workloads and churn models.
//!
//! * [`Uniform`] — Table 1 `uniform` / `adversarial` inputs.
//! * [`Exponential`] — Table 1 `exponential` input and the Yao-exponential
//!   rejoin times (§7.2).
//! * [`Normal`] — Table 1 `normal` input (Box–Muller).
//! * [`ShiftedPareto`] — Yao churn lifetimes/off-times (§7.2): the
//!   three-parameter Pareto with shape `alpha`, scale `beta`, shift `mu`.

use super::Rng;

/// Common sampling interface.
pub trait Sample {
    /// Draw one variate.
    fn sample<R: Rng>(&self, rng: &mut R) -> f64;

    /// Draw `n` variates.
    fn sample_n<R: Rng>(&self, rng: &mut R, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Continuous uniform on `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uniform {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl Uniform {
    /// New uniform distribution; panics if `hi <= lo`.
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi > lo, "Uniform: hi ({hi}) must exceed lo ({lo})");
        Self { lo, hi }
    }
}

impl Sample for Uniform {
    #[inline]
    fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Exponential with rate `lambda` (mean `1/lambda`), via inversion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Exponential {
    /// Rate parameter λ > 0.
    pub lambda: f64,
}

impl Exponential {
    /// New exponential distribution; panics unless `lambda > 0`.
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential: lambda must be positive");
        Self { lambda }
    }
}

impl Sample for Exponential {
    #[inline]
    fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        // Inversion on (0,1]: -ln(U)/λ.
        -rng.next_f64_open().ln() / self.lambda
    }
}

/// Normal(mean, sd) via Box–Muller (the cached second variate is dropped to
/// keep the sampler stateless; throughput is not a concern for data gen).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normal {
    /// Mean μ.
    pub mean: f64,
    /// Standard deviation σ > 0.
    pub sd: f64,
}

impl Normal {
    /// New normal distribution; panics unless `sd > 0`.
    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd > 0.0, "Normal: sd must be positive");
        Self { mean, sd }
    }
}

impl Sample for Normal {
    #[inline]
    fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        let u1 = rng.next_f64_open();
        let u2 = rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.mean + self.sd * r * theta.cos()
    }
}

/// Shifted (three-parameter) Pareto used by the Yao churn model [28]:
///
/// CDF `F(x) = 1 − (1 + (x − μ)/β)^(−α)` for `x ≥ μ`.
///
/// The paper uses α=3, μ=1.01 with β=1 for lifetimes and β=2 for off-times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShiftedPareto {
    /// Shape α > 0.
    pub alpha: f64,
    /// Scale β > 0.
    pub beta: f64,
    /// Shift μ (minimum value).
    pub mu: f64,
}

impl ShiftedPareto {
    /// New shifted Pareto; panics unless `alpha > 0 && beta > 0`.
    pub fn new(alpha: f64, beta: f64, mu: f64) -> Self {
        assert!(alpha > 0.0 && beta > 0.0, "ShiftedPareto: alpha, beta > 0");
        Self { alpha, beta, mu }
    }

    /// Mean `μ + β/(α−1)` (finite for α > 1).
    pub fn mean(&self) -> f64 {
        assert!(self.alpha > 1.0, "mean undefined for alpha <= 1");
        self.mu + self.beta / (self.alpha - 1.0)
    }
}

impl Sample for ShiftedPareto {
    #[inline]
    fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        // Inversion: x = μ + β((1−U)^(−1/α) − 1), U uniform in [0,1).
        let u = rng.next_f64();
        self.mu + self.beta * ((1.0 - u).powf(-1.0 / self.alpha) - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    fn mean_sd(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1.0);
        (m, v.sqrt())
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = default_rng(1);
        let d = Uniform::new(10.0, 20.0);
        let xs = d.sample_n(&mut r, 50_000);
        assert!(xs.iter().all(|&x| (10.0..20.0).contains(&x)));
        let (m, _) = mean_sd(&xs);
        assert!((m - 15.0).abs() < 0.05, "mean {m}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = default_rng(2);
        let d = Exponential::new(0.5);
        let xs = d.sample_n(&mut r, 100_000);
        assert!(xs.iter().all(|&x| x >= 0.0));
        let (m, _) = mean_sd(&xs);
        assert!((m - 2.0).abs() < 0.05, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = default_rng(3);
        let d = Normal::new(100.0, 15.0);
        let xs = d.sample_n(&mut r, 100_000);
        let (m, s) = mean_sd(&xs);
        assert!((m - 100.0).abs() < 0.3, "mean {m}");
        assert!((s - 15.0).abs() < 0.3, "sd {s}");
    }

    #[test]
    fn shifted_pareto_support_and_mean() {
        let mut r = default_rng(4);
        // Paper's lifetime parameters.
        let d = ShiftedPareto::new(3.0, 1.0, 1.01);
        let xs = d.sample_n(&mut r, 200_000);
        assert!(xs.iter().all(|&x| x >= 1.01));
        let (m, _) = mean_sd(&xs);
        // mean = 1.01 + 1/(3-1) = 1.51
        assert!((m - d.mean()).abs() < 0.02, "mean {m} vs {}", d.mean());
    }

    #[test]
    fn pareto_tail_heavier_than_exponential() {
        // Sanity on the heavy tail: P(X > mu + 5*beta) should exceed the
        // exponential (same mean) tail by a wide margin.
        let mut r = default_rng(5);
        let p = ShiftedPareto::new(3.0, 1.0, 1.01);
        let e = Exponential::new(1.0 / (p.mean() - 1.01));
        let n = 200_000;
        let pt = (0..n).filter(|_| p.sample(&mut r) > 6.01).count() as f64;
        let et = (0..n).filter(|_| 1.01 + e.sample(&mut r) > 6.01).count() as f64;
        assert!(pt > et, "pareto tail {pt} <= exp tail {et}");
    }

    #[test]
    #[should_panic]
    fn uniform_rejects_empty_interval() {
        let _ = Uniform::new(5.0, 5.0);
    }
}
