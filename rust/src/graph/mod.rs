//! Unstructured P2P overlay topologies.
//!
//! The paper evaluates on random graphs generated with iGraph 0.7.1:
//! **Barabási–Albert** (preferential attachment, 5 outgoing edges per
//! vertex, attachment power and attractiveness 1) and **Erdős–Rényi**
//! (G(n, p) with p = 10/n). This module re-implements both generators plus
//! the structural queries the simulator needs (neighbour lists, connected
//! components — churn can disconnect the overlay, §7.2).

#![forbid(unsafe_code)]

use crate::rng::Rng;

/// An undirected graph stored as adjacency lists.
///
/// Vertices are `0..n`. Edges are stored once per endpoint; the structure
/// is immutable after generation except for [`Graph::remove_vertex`]-style
/// masking which the churn layer performs logically (peers go offline, the
/// overlay itself is static per §4's model).
#[derive(Debug, Clone)]
pub struct Graph {
    adj: Vec<Vec<usize>>,
    edges: usize,
}

impl Graph {
    /// Build from an explicit edge list over `n` vertices.
    ///
    /// Self-loops and duplicate edges are rejected with a panic — both
    /// generators below never produce them, and the gossip engine relies on
    /// neighbour lists being sets.
    pub fn from_edges(n: usize, edge_list: &[(usize, usize)]) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in edge_list {
            assert!(u != v, "self-loop {u}");
            assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
            assert!(!adj[u].contains(&v), "duplicate edge ({u},{v})");
            adj[u].push(v);
            adj[v].push(u);
        }
        Self {
            adj,
            edges: edge_list.len(),
        }
    }

    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.adj.len()
    }

    /// True when the graph has no vertices.
    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.edges
    }

    /// Neighbours of `v`.
    pub fn neighbours(&self, v: usize) -> &[usize] {
        &self.adj[v]
    }

    /// Degree of `v`.
    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Mean degree `2|E|/|V|`.
    pub fn mean_degree(&self) -> f64 {
        if self.adj.is_empty() {
            0.0
        } else {
            2.0 * self.edges as f64 / self.adj.len() as f64
        }
    }

    /// Connected-component label per vertex (labels are component minima),
    /// restricted to vertices for which `alive[v]` is true. Dead vertices
    /// get label `usize::MAX`.
    pub fn components_masked(&self, alive: &[bool]) -> Vec<usize> {
        assert_eq!(alive.len(), self.len());
        let mut label = vec![usize::MAX; self.len()];
        let mut stack = Vec::new();
        for start in 0..self.len() {
            if !alive[start] || label[start] != usize::MAX {
                continue;
            }
            label[start] = start;
            stack.push(start);
            while let Some(u) = stack.pop() {
                for &w in &self.adj[u] {
                    if alive[w] && label[w] == usize::MAX {
                        label[w] = start;
                        stack.push(w);
                    }
                }
            }
        }
        label
    }

    /// Connected-component label per vertex (all vertices alive).
    pub fn components(&self) -> Vec<usize> {
        self.components_masked(&vec![true; self.len()])
    }

    /// True when every vertex is reachable from vertex 0.
    pub fn is_connected(&self) -> bool {
        if self.is_empty() {
            return true;
        }
        let labels = self.components();
        labels.iter().all(|&l| l == labels[0])
    }

    /// Number of connected components among `alive` vertices.
    pub fn component_count_masked(&self, alive: &[bool]) -> usize {
        let labels = self.components_masked(alive);
        let mut uniq: Vec<usize> = labels
            .into_iter()
            .filter(|&l| l != usize::MAX)
            .collect();
        uniq.sort_unstable();
        uniq.dedup();
        uniq.len()
    }
}

/// Barabási–Albert preferential-attachment graph.
///
/// Matches the paper's generation parameters: each incoming vertex attaches
/// `m` edges to existing vertices with probability proportional to
/// (degree + attractiveness), attractiveness = 1, linear preferential
/// attachment (power = 1). The first `m + 1` vertices form a clique seed so
/// the graph is always connected.
pub fn barabasi_albert<R: Rng>(n: usize, m: usize, rng: &mut R) -> Graph {
    assert!(m >= 1, "BA: m >= 1");
    assert!(n > m, "BA: need n > m (n={n}, m={m})");
    let mut edge_list: Vec<(usize, usize)> = Vec::with_capacity(n * m);
    // `targets` holds one entry per half-edge plus one per vertex
    // (the +1 attractiveness term), so sampling uniformly from it samples
    // proportionally to degree+1.
    let mut targets: Vec<usize> = Vec::with_capacity(2 * n * m + n);

    // Clique seed over m+1 vertices keeps the graph connected.
    for u in 0..=m {
        targets.push(u); // attractiveness term
        for v in (u + 1)..=m {
            edge_list.push((u, v));
            targets.push(u);
            targets.push(v);
        }
    }

    for v in (m + 1)..n {
        let mut chosen: Vec<usize> = Vec::with_capacity(m);
        // Sample m distinct targets by rejection; the target pool is large
        // relative to m so rejection terminates fast.
        while chosen.len() < m {
            let t = targets[rng.index(targets.len())];
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        targets.push(v); // attractiveness term for the new vertex
        for &t in &chosen {
            edge_list.push((v, t));
            targets.push(v);
            targets.push(t);
        }
    }
    Graph::from_edges(n, &edge_list)
}

/// Erdős–Rényi G(n, p) graph.
///
/// The paper uses `p = 10/n` (expected mean degree 10). Generation uses the
/// geometric skipping method (Batagelj–Brandes) — O(|E|) rather than O(n²).
pub fn erdos_renyi<R: Rng>(n: usize, p: f64, rng: &mut R) -> Graph {
    assert!((0.0..=1.0).contains(&p), "ER: p in [0,1]");
    let mut edge_list: Vec<(usize, usize)> = Vec::new();
    if p <= 0.0 || n < 2 {
        return Graph::from_edges(n, &edge_list);
    }
    if p >= 1.0 {
        for u in 0..n {
            for v in (u + 1)..n {
                edge_list.push((u, v));
            }
        }
        return Graph::from_edges(n, &edge_list);
    }
    let lq = (1.0 - p).ln();
    // Walk the strictly-upper-triangular adjacency matrix in row-major
    // order, skipping a geometric number of non-edges each step.
    let (mut u, mut v) = (0usize, 0usize); // v is the column cursor
    loop {
        let skip = ((rng.next_f64_open().ln() / lq).floor()) as usize + 1;
        v += skip;
        while v >= n {
            u += 1;
            v = u + 1 + (v - n);
            if u >= n - 1 {
                return Graph::from_edges(n, &edge_list);
            }
        }
        edge_list.push((u, v));
    }
}

/// Ring lattice: each vertex connects to its `k` nearest neighbours on
/// each side (the Watts–Strogatz substrate; also useful as a worst-case
/// high-diameter overlay for convergence ablations).
pub fn ring_lattice(n: usize, k: usize) -> Graph {
    assert!(k >= 1 && 2 * k < n, "ring: need 1 <= k < n/2");
    let mut edges = Vec::with_capacity(n * k);
    for u in 0..n {
        for d in 1..=k {
            edges.push((u, (u + d) % n));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Watts–Strogatz small-world graph: ring lattice with each edge rewired
/// with probability `beta` (duplicate/self rewires are skipped, keeping
/// the graph simple). `beta = 0` is the pure lattice, `beta = 1`
/// approaches a random graph; small β already collapses the diameter —
/// the regime where gossip converges almost as fast as on BA/ER.
pub fn watts_strogatz<R: Rng>(n: usize, k: usize, beta: f64, rng: &mut R) -> Graph {
    assert!((0.0..=1.0).contains(&beta));
    assert!(k >= 1 && 2 * k < n, "ws: need 1 <= k < n/2");
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * k);
    let has = |adj: &Vec<Vec<usize>>, a: usize, b: usize| adj[a].contains(&b);
    for u in 0..n {
        for d in 1..=k {
            let v = (u + d) % n;
            let (a, b) = if rng.chance(beta) {
                // Rewire the far endpoint to a random vertex.
                let mut w = rng.index(n);
                let mut tries = 0;
                while (w == u || has(&adj, u, w)) && tries < 32 {
                    w = rng.index(n);
                    tries += 1;
                }
                if w == u || has(&adj, u, w) {
                    (u, v) // give up, keep lattice edge
                } else {
                    (u, w)
                }
            } else {
                (u, v)
            };
            if a != b && !has(&adj, a, b) {
                adj[a].push(b);
                adj[b].push(a);
                edges.push((a, b));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Complete graph K_n: every pair of vertices adjacent.
///
/// The natural overlay for *small* gossip fleets (a handful of service
/// nodes fronted by [`crate::service`]'s gossip loop): every exchange
/// partner is reachable, convergence is as fast as distributed averaging
/// allows, and no generator randomness is involved. Edge count is
/// n(n−1)/2 — do not use for the paper-scale simulations.
pub fn complete(n: usize) -> Graph {
    assert!(n >= 2, "complete: need n >= 2");
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Convenience: the paper's default overlay for `n` peers.
pub fn paper_ba<R: Rng>(n: usize, rng: &mut R) -> Graph {
    barabasi_albert(n, 5, rng)
}

/// Convenience: the paper's ER overlay for `n` peers (p = 10/n).
pub fn paper_er<R: Rng>(n: usize, rng: &mut R) -> Graph {
    erdos_renyi(n, (10.0 / n as f64).min(1.0), rng)
}

/// Build the overlay prescribed by `kind` over `n` vertices, with the
/// generation parameters fixed throughout the evaluation (BA m=5,
/// ER p=10/n, WS k=5 β=0.1, ring k=5) — the single construction point
/// shared by the experiment runner and the service gossip loop.
pub fn from_kind<R: Rng>(kind: crate::config::GraphKind, n: usize, rng: &mut R) -> Graph {
    use crate::config::GraphKind;
    match kind {
        GraphKind::BarabasiAlbert => paper_ba(n, rng),
        GraphKind::ErdosRenyi => paper_er(n, rng),
        GraphKind::WattsStrogatz => watts_strogatz(n, 5, 0.1, rng),
        GraphKind::Ring => ring_lattice(n, 5),
        GraphKind::Complete => complete(n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    #[test]
    fn ba_structure() {
        let mut r = default_rng(1);
        let g = barabasi_albert(500, 5, &mut r);
        assert_eq!(g.len(), 500);
        // Clique seed: C(6,2)=15 edges; then (500-6)*5 edges.
        assert_eq!(g.edge_count(), 15 + 494 * 5);
        assert!(g.is_connected());
        // Every non-seed vertex has degree >= m.
        for v in 6..500 {
            assert!(g.degree(v) >= 5, "v={v} degree {}", g.degree(v));
        }
    }

    #[test]
    fn ba_has_hubs() {
        // Preferential attachment must generate a heavy degree tail:
        // max degree far above the mean.
        let mut r = default_rng(2);
        let g = barabasi_albert(2000, 5, &mut r);
        let max_deg = (0..g.len()).map(|v| g.degree(v)).max().unwrap();
        assert!(
            max_deg as f64 > 4.0 * g.mean_degree(),
            "max degree {max_deg} vs mean {}",
            g.mean_degree()
        );
    }

    #[test]
    fn er_edge_count_near_expectation() {
        let mut r = default_rng(3);
        let n = 2000;
        let p = 10.0 / n as f64;
        let g = erdos_renyi(n, p, &mut r);
        let expected = p * (n * (n - 1) / 2) as f64; // = 9995
        let got = g.edge_count() as f64;
        assert!(
            (got - expected).abs() < 0.1 * expected,
            "edges {got} vs expected {expected}"
        );
    }

    #[test]
    fn er_p1_is_complete_and_p0_is_empty() {
        let mut r = default_rng(4);
        let g1 = erdos_renyi(20, 1.0, &mut r);
        assert_eq!(g1.edge_count(), 190);
        let g0 = erdos_renyi(20, 0.0, &mut r);
        assert_eq!(g0.edge_count(), 0);
    }

    #[test]
    fn er_paper_density_is_connected_whp() {
        // Mean degree 10 >> ln(n) for n=1000; connectivity should hold.
        let mut r = default_rng(5);
        let g = paper_er(1000, &mut r);
        assert!(g.is_connected());
    }

    #[test]
    fn components_masked_counts_islands() {
        // Path 0-1-2  and isolated 3,4.
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        assert_eq!(g.component_count_masked(&[true; 5]), 2);
        // Killing vertex 1 splits the path.
        assert_eq!(
            g.component_count_masked(&[true, false, true, true, true]),
            3
        );
        assert!(!g.is_connected());
    }

    #[test]
    fn degrees_symmetric() {
        let mut r = default_rng(6);
        let g = paper_ba(300, &mut r);
        // Sum of degrees = 2|E|.
        let sum: usize = (0..g.len()).map(|v| g.degree(v)).sum();
        assert_eq!(sum, 2 * g.edge_count());
        // Adjacency symmetry.
        for u in 0..g.len() {
            for &v in g.neighbours(u) {
                assert!(g.neighbours(v).contains(&u));
            }
        }
    }

    #[test]
    fn ring_lattice_structure() {
        let g = ring_lattice(10, 2);
        assert_eq!(g.edge_count(), 20);
        assert!(g.is_connected());
        for v in 0..10 {
            assert_eq!(g.degree(v), 4);
        }
    }

    #[test]
    fn watts_strogatz_beta_zero_is_lattice() {
        let mut r = default_rng(7);
        let ws = watts_strogatz(50, 3, 0.0, &mut r);
        let ring = ring_lattice(50, 3);
        assert_eq!(ws.edge_count(), ring.edge_count());
        for v in 0..50 {
            assert_eq!(ws.degree(v), ring.degree(v));
        }
    }

    #[test]
    fn watts_strogatz_rewiring_keeps_graph_simple_and_connected() {
        let mut r = default_rng(8);
        for beta in [0.1, 0.5, 1.0] {
            let g = watts_strogatz(300, 4, beta, &mut r);
            // Simple graph invariants enforced by from_edges; connectivity
            // holds w.h.p. at mean degree 8.
            assert!(g.is_connected(), "beta={beta}");
            let sum: usize = (0..g.len()).map(|v| g.degree(v)).sum();
            assert_eq!(sum, 2 * g.edge_count());
        }
    }

    #[test]
    fn complete_graph_structure() {
        let g = complete(6);
        assert_eq!(g.len(), 6);
        assert_eq!(g.edge_count(), 15);
        assert!(g.is_connected());
        for v in 0..6 {
            assert_eq!(g.degree(v), 5);
        }
        // The smallest legal fleet.
        let g2 = complete(2);
        assert_eq!(g2.edge_count(), 1);
        assert_eq!(g2.neighbours(0), &[1]);
    }

    /// Sorted adjacency lists — a structural fingerprint two graphs can
    /// be compared by (the `Graph` type deliberately has no `PartialEq`).
    fn fingerprint(g: &Graph) -> Vec<Vec<usize>> {
        (0..g.len())
            .map(|v| {
                let mut ns = g.neighbours(v).to_vec();
                ns.sort_unstable();
                ns
            })
            .collect()
    }

    #[test]
    fn from_kind_is_deterministic_per_seed() {
        use crate::config::GraphKind;
        for kind in [GraphKind::BarabasiAlbert, GraphKind::ErdosRenyi] {
            let a = from_kind(kind, 400, &mut default_rng(41));
            let b = from_kind(kind, 400, &mut default_rng(41));
            assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "{kind:?}: the same seed must rebuild the identical overlay"
            );
            let c = from_kind(kind, 400, &mut default_rng(42));
            assert_ne!(
                fingerprint(&a),
                fingerprint(&c),
                "{kind:?}: a different seed must produce a different overlay"
            );
        }
    }

    #[test]
    fn from_kind_ba_connectivity_and_degree_bounds() {
        use crate::config::GraphKind;
        let n = 300;
        for seed in [1u64, 9, 77] {
            let g = from_kind(GraphKind::BarabasiAlbert, n, &mut default_rng(seed));
            assert_eq!(g.len(), n);
            // Connected by construction: the clique seed plus m edges
            // from every later vertex into the existing component.
            assert!(g.is_connected(), "seed {seed}");
            // Exact edge count: C(6,2) clique + 5 per attached vertex.
            assert_eq!(g.edge_count(), 15 + (n - 6) * 5, "seed {seed}");
            for v in 0..n {
                let d = g.degree(v);
                assert!(
                    (5..n).contains(&d),
                    "seed {seed} v={v}: degree {d} outside [m, n)"
                );
            }
        }
    }

    #[test]
    fn from_kind_er_density_and_giant_component() {
        use crate::config::GraphKind;
        let n = 600;
        for seed in [1u64, 9, 77] {
            let g = from_kind(GraphKind::ErdosRenyi, n, &mut default_rng(seed));
            assert_eq!(g.len(), n);
            // Edge count near the paper's p = 10/n expectation,
            // E[|E|] = p·C(n,2) = 5(n−1).
            let expected = 5.0 * (n as f64 - 1.0);
            let got = g.edge_count() as f64;
            assert!(
                (got - expected).abs() < 0.2 * expected,
                "seed {seed}: {got} edges vs expected {expected}"
            );
            // Simple-graph degree bound.
            for v in 0..n {
                assert!(g.degree(v) < n, "seed {seed} v={v}");
            }
            // At mean degree 10 ≫ ln n the giant component takes
            // essentially every vertex; a handful of stragglers is the
            // most randomness can leave behind, so the bound is loose
            // enough to hold for every seed.
            let labels = g.components();
            let mut counts = std::collections::BTreeMap::new();
            for l in labels {
                *counts.entry(l).or_insert(0usize) += 1;
            }
            let giant = counts.values().copied().max().unwrap();
            assert!(
                giant * 100 >= n * 99,
                "seed {seed}: giant component {giant}/{n}"
            );
            assert!(counts.len() <= 4, "seed {seed}: {} components", counts.len());
        }
    }

    #[test]
    #[should_panic]
    fn complete_rejects_singleton() {
        let _ = complete(1);
    }

    #[test]
    #[should_panic]
    fn from_edges_rejects_self_loop() {
        let _ = Graph::from_edges(3, &[(1, 1)]);
    }

    #[test]
    #[should_panic]
    fn from_edges_rejects_duplicate() {
        let _ = Graph::from_edges(3, &[(0, 1), (1, 0)]);
    }
}
