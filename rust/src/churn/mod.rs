//! Churn models of §7.2.
//!
//! * **Fail & Stop** — every online peer fails each round with probability
//!   `p` (paper: 0.01) and never returns; the overlay can disconnect,
//!   which is what stalls convergence on the adversarial input.
//! * **Yao** (two variants) — the heterogeneous churn model of Yao et
//!   al. [28]: every peer `i` draws an average lifetime `l_i` from
//!   ShiftedPareto(α=3, β=1, μ=1.01) and an average off-time `d_i` from
//!   ShiftedPareto(α=3, β=2, μ=1.01). Whenever peer `i` changes state, the
//!   duration of the new state is drawn from the peer's own distribution:
//!   on-line durations from a shifted Pareto with mean `l_i`; off-line
//!   durations either from a shifted Pareto with mean `d_i`
//!   ([`ChurnKind::YaoPareto`]) or from an exponential with rate `1/l_i`
//!   ([`ChurnKind::YaoExponential`]).
//!
//! Durations are measured in rounds (the protocol's only clock).

#![forbid(unsafe_code)]

use crate::rng::{Exponential, Rng, Sample, ShiftedPareto, Xoshiro256pp};

/// Which churn model a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChurnKind {
    /// No churn (§7.1 experiments).
    None,
    /// Fail & Stop with per-round failure probability 0.01.
    FailStop,
    /// Yao model, shifted-Pareto rejoin.
    YaoPareto,
    /// Yao model, exponential rejoin.
    YaoExponential,
}

impl ChurnKind {
    /// CSV/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            ChurnKind::None => "none",
            ChurnKind::FailStop => "failstop",
            ChurnKind::YaoPareto => "yao",
            ChurnKind::YaoExponential => "yaoexp",
        }
    }
}

impl std::str::FromStr for ChurnKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Ok(ChurnKind::None),
            "failstop" | "fail-stop" | "fail_stop" => Ok(ChurnKind::FailStop),
            "yao" | "yao-pareto" | "yaopareto" => Ok(ChurnKind::YaoPareto),
            "yaoexp" | "yao-exp" | "yao-exponential" => Ok(ChurnKind::YaoExponential),
            other => Err(format!(
                "unknown churn '{other}' (expected none|failstop|yao|yaoexp)"
            )),
        }
    }
}

/// Default Fail&Stop per-round failure probability (§7.2).
pub const FAILSTOP_P: f64 = 0.01;

/// Yao lifetime distribution parameters (§7.2).
pub const YAO_LIFETIME: ShiftedPareto = ShiftedPareto {
    alpha: 3.0,
    beta: 1.0,
    mu: 1.01,
};

/// Yao off-time distribution parameters (§7.2).
pub const YAO_OFFTIME: ShiftedPareto = ShiftedPareto {
    alpha: 3.0,
    beta: 2.0,
    mu: 1.01,
};

#[derive(Debug, Clone)]
enum ModelState {
    None,
    FailStop {
        alive: Vec<bool>,
        p: f64,
    },
    Yao {
        online: Vec<bool>,
        /// Rounds remaining in the current state.
        remaining: Vec<f64>,
        /// Per-peer mean lifetime `l_i`.
        lifetime: Vec<f64>,
        /// Per-peer mean off-time `d_i`.
        offtime: Vec<f64>,
        exponential_rejoin: bool,
    },
}

/// Per-round churn driver: tracks each peer's online/offline status.
#[derive(Debug, Clone)]
pub struct ChurnModel {
    kind: ChurnKind,
    rng: Xoshiro256pp,
    state: ModelState,
}

impl ChurnModel {
    /// Instantiate for `peers` peers; RNG stream derived from `master`.
    pub fn new(kind: ChurnKind, peers: usize, master: &Xoshiro256pp) -> Self {
        let mut rng = master.derive(0xC4A2_0000);
        let state = match kind {
            ChurnKind::None => ModelState::None,
            ChurnKind::FailStop => ModelState::FailStop {
                alive: vec![true; peers],
                p: FAILSTOP_P,
            },
            ChurnKind::YaoPareto | ChurnKind::YaoExponential => {
                let lifetime: Vec<f64> =
                    (0..peers).map(|_| YAO_LIFETIME.sample(&mut rng)).collect();
                let offtime: Vec<f64> =
                    (0..peers).map(|_| YAO_OFFTIME.sample(&mut rng)).collect();
                // All peers start online with a fresh lifetime draw.
                let remaining: Vec<f64> = lifetime
                    .iter()
                    .map(|&l| Self::draw_online(&mut rng, l))
                    .collect();
                ModelState::Yao {
                    online: vec![true; peers],
                    remaining,
                    lifetime,
                    offtime,
                    exponential_rejoin: kind == ChurnKind::YaoExponential,
                }
            }
        };
        Self { kind, rng, state }
    }

    /// Online-duration draw: shifted Pareto with the peer's mean `l_i`
    /// (α = 3 kept, β matched so the mean equals `l_i`).
    fn draw_online<R: Rng>(rng: &mut R, l_i: f64) -> f64 {
        let beta = ((l_i - YAO_LIFETIME.mu) * (YAO_LIFETIME.alpha - 1.0)).max(1e-6);
        ShiftedPareto::new(YAO_LIFETIME.alpha, beta, YAO_LIFETIME.mu).sample(rng)
    }

    /// Off-duration draw for the two Yao variants.
    fn draw_offline<R: Rng>(rng: &mut R, d_i: f64, l_i: f64, exponential: bool) -> f64 {
        if exponential {
            Exponential::new(1.0 / l_i).sample(rng)
        } else {
            let beta = ((d_i - YAO_OFFTIME.mu) * (YAO_OFFTIME.alpha - 1.0)).max(1e-6);
            ShiftedPareto::new(YAO_OFFTIME.alpha, beta, YAO_OFFTIME.mu).sample(rng)
        }
    }

    /// The configured model.
    pub fn kind(&self) -> ChurnKind {
        self.kind
    }

    /// Advance one round: apply failures/rejoins.
    pub fn step(&mut self) {
        match &mut self.state {
            ModelState::None => {}
            ModelState::FailStop { alive, p } => {
                for a in alive.iter_mut() {
                    if *a && self.rng.chance(*p) {
                        *a = false;
                    }
                }
            }
            ModelState::Yao {
                online,
                remaining,
                lifetime,
                offtime,
                exponential_rejoin,
            } => {
                for i in 0..online.len() {
                    remaining[i] -= 1.0;
                    if remaining[i] <= 0.0 {
                        online[i] = !online[i];
                        remaining[i] = if online[i] {
                            Self::draw_online(&mut self.rng, lifetime[i])
                        } else {
                            Self::draw_offline(
                                &mut self.rng,
                                offtime[i],
                                lifetime[i],
                                *exponential_rejoin,
                            )
                        };
                    }
                }
            }
        }
    }

    /// Is peer `l` currently online?
    pub fn is_online(&self, l: usize) -> bool {
        match &self.state {
            ModelState::None => true,
            ModelState::FailStop { alive, .. } => alive[l],
            ModelState::Yao { online, .. } => online[l],
        }
    }

    /// Online mask over all peers.
    pub fn online_mask(&self, peers: usize) -> Vec<bool> {
        (0..peers).map(|l| self.is_online(l)).collect()
    }

    /// Number of online peers.
    pub fn online_count(&self, peers: usize) -> usize {
        (0..peers).filter(|&l| self.is_online(l)).count()
    }

    /// Pre-compute the online mask of the next `rounds` rounds — the
    /// deterministic **churn schedule** a live-fleet demo replays
    /// against real TCP nodes (`integration_membership`): row `r` is
    /// the mask *after* round `r+1`'s churn step. Works on a clone, so
    /// `self` is not advanced; calling it twice (or stepping a clone by
    /// hand) yields the identical schedule.
    pub fn schedule(&self, rounds: usize, peers: usize) -> Vec<Vec<bool>> {
        let mut model = self.clone();
        (0..rounds)
            .map(|_| {
                model.step();
                model.online_mask(peers)
            })
            .collect()
    }

    /// The first `(round, peer)` at which the schedule takes a peer
    /// offline, if any within `rounds` — how a churn demo picks its
    /// crash point deterministically from the model.
    pub fn first_failure(&self, rounds: usize, peers: usize) -> Option<(usize, usize)> {
        for (r, mask) in self.schedule(rounds, peers).into_iter().enumerate() {
            if let Some(l) = mask.iter().position(|&b| !b) {
                return Some((r, l));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    #[test]
    fn none_never_fails() {
        let m = default_rng(1);
        let mut c = ChurnModel::new(ChurnKind::None, 100, &m);
        for _ in 0..50 {
            c.step();
        }
        assert_eq!(c.online_count(100), 100);
    }

    #[test]
    fn failstop_monotone_decay() {
        let m = default_rng(2);
        let mut c = ChurnModel::new(ChurnKind::FailStop, 2000, &m);
        let mut last = 2000;
        for _ in 0..25 {
            c.step();
            let now = c.online_count(2000);
            assert!(now <= last, "fail&stop peers never rejoin");
            last = now;
        }
        // E[survival over 25 rounds] = 0.99^25 ≈ 0.778.
        let frac = last as f64 / 2000.0;
        assert!((0.70..0.85).contains(&frac), "survivors {frac}");
    }

    #[test]
    fn yao_peers_rejoin() {
        let m = default_rng(3);
        let mut c = ChurnModel::new(ChurnKind::YaoPareto, 500, &m);
        let mut went_down_and_up = false;
        let mut was_offline = vec![false; 500];
        for _ in 0..60 {
            c.step();
            for l in 0..500 {
                if !c.is_online(l) {
                    was_offline[l] = true;
                } else if was_offline[l] {
                    went_down_and_up = true;
                }
            }
        }
        assert!(went_down_and_up, "yao churn must allow rejoin");
        // Network never collapses: most peers remain online on average
        // (mean lifetime 1.51 vs off-time 2.01 rounds -> minority offline
        //  at any instant is possible; just require non-trivial presence).
        assert!(c.online_count(500) > 50);
    }

    #[test]
    fn yao_exponential_variant_differs_from_pareto() {
        let m = default_rng(4);
        let mut a = ChurnModel::new(ChurnKind::YaoPareto, 300, &m);
        let mut b = ChurnModel::new(ChurnKind::YaoExponential, 300, &m);
        let mut diverged = false;
        for _ in 0..40 {
            a.step();
            b.step();
            if a.online_mask(300) != b.online_mask(300) {
                diverged = true;
            }
        }
        assert!(diverged);
    }

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            ChurnKind::None,
            ChurnKind::FailStop,
            ChurnKind::YaoPareto,
            ChurnKind::YaoExponential,
        ] {
            assert_eq!(k.name().parse::<ChurnKind>().unwrap(), k);
        }
        assert!("bogus".parse::<ChurnKind>().is_err());
    }

    #[test]
    fn deterministic_given_master_seed() {
        let m = default_rng(5);
        let mut a = ChurnModel::new(ChurnKind::YaoPareto, 100, &m);
        let mut b = ChurnModel::new(ChurnKind::YaoPareto, 100, &m);
        for _ in 0..20 {
            a.step();
            b.step();
            assert_eq!(a.online_mask(100), b.online_mask(100));
        }
    }

    #[test]
    fn schedule_is_deterministic_and_leaves_model_untouched() {
        let m = default_rng(7);
        let c = ChurnModel::new(ChurnKind::YaoPareto, 50, &m);
        let s1 = c.schedule(30, 50);
        let s2 = c.schedule(30, 50);
        assert_eq!(s1, s2, "schedule must be a pure function of the model");
        assert_eq!(s1.len(), 30);
        assert_eq!(
            c.online_count(50),
            50,
            "schedule generation must not advance the model"
        );
        // Stepping a clone by hand reproduces the schedule row for row.
        let mut manual = c.clone();
        for (r, row) in s1.iter().enumerate() {
            manual.step();
            assert_eq!(&manual.online_mask(50), row, "round {r}");
        }
    }

    #[test]
    fn schedule_matches_model_semantics_per_kind() {
        let m = default_rng(8);
        // No churn: every row all-online, no first failure.
        let none = ChurnModel::new(ChurnKind::None, 20, &m);
        assert!(none
            .schedule(10, 20)
            .iter()
            .all(|row| row.iter().all(|&b| b)));
        assert_eq!(none.first_failure(10, 20), None);

        // Fail&stop: once offline, offline in every later row.
        let fs = ChurnModel::new(ChurnKind::FailStop, 200, &m);
        let sched = fs.schedule(40, 200);
        for l in 0..200 {
            let mut down = false;
            for row in &sched {
                if down {
                    assert!(!row[l], "fail&stop peer {l} must never rejoin");
                }
                down |= !row[l];
            }
        }
        // The paper's p=0.01 over 200 peers × 40 rounds fails someone.
        let (r, l) = fs.first_failure(40, 200).expect("some peer fails");
        assert!(!sched[r][l]);
        assert!(sched[..r].iter().all(|row| row.iter().all(|&b| b)));

        // Yao: someone goes down and comes back within the schedule.
        let yao = ChurnModel::new(ChurnKind::YaoPareto, 100, &m);
        let sched = yao.schedule(60, 100);
        let rejoined = (0..100).any(|l| {
            let mut was_down = false;
            sched.iter().any(|row| {
                if !row[l] {
                    was_down = true;
                    false
                } else {
                    was_down
                }
            })
        });
        assert!(rejoined, "yao schedules must contain a rejoin");
    }
}
