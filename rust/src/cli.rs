//! Hand-rolled command-line interface (clap is unavailable offline —
//! DESIGN.md §6).
//!
//! Subcommands:
//!
//! * `run` — one distributed experiment, ARE table per quantile.
//! * `figure` — regenerate a paper figure/table (`--list`, `--all`).
//! * `quantiles` — sequential UDDSketch over a file or generated data.
//! * `serve-bench` — sharded ingest service throughput vs sequential.
//! * `serve-gossip` — live ingest + continuous gossip loop, per-round
//!   convergence metrics, global view verified against the union stream.
//! * `serve-remote` — a fleet of real nodes gossiping over loopback TCP
//!   (length-prefixed codec frames, accept loop per node), converging to
//!   the sequential union sketch while ingest continues.
//! * `sim-fleet` — deterministic whole-fleet simulation (1000+ members
//!   in one process) under scripted faults, verified against the exact
//!   oracle each virtual round.
//! * `observe` (alias `dudd-observe`) — the convergence observatory:
//!   scrape a running fleet's `/metrics` + `/members` endpoints and
//!   render a fleet-wide convergence report (docs/OBSERVABILITY.md).
//! * `info` — build/runtime/artifact diagnostics.

#![forbid(unsafe_code)]

use crate::config::ExperimentConfig;
use crate::data::DatasetKind;
use crate::experiments::{figure_ids, run_figure, run_with_snapshots};
use crate::runtime::{artifacts_dir, list_shaped_artifacts};
use crate::sketch::UddSketch;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand name.
    pub command: String,
    /// `--flag value` pairs (flags without values map to "true").
    pub flags: Vec<(String, String)>,
    /// Free `key=value` config overrides.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    /// Parse raw args (excluding argv[0]).
    pub fn parse(raw: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with('-') => out.command = cmd.clone(),
            Some(cmd) => bail!("expected a subcommand before '{cmd}'"),
            None => {
                out.command = "help".into();
                return Ok(out);
            }
        }
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                let takes_value = it
                    .peek()
                    .map(|n| !n.starts_with("--") && !n.contains('='))
                    .unwrap_or(false);
                if takes_value {
                    out.flags.push((flag.to_string(), it.next().unwrap().clone()));
                } else {
                    out.flags.push((flag.to_string(), "true".to_string()));
                }
            } else if let Some((k, v)) = a.split_once('=') {
                out.overrides.push((k.to_string(), v.to_string()));
            } else {
                bail!("unexpected argument '{a}' (flags are --name, overrides key=value)");
            }
        }
        Ok(out)
    }

    /// Last value of a flag.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Boolean flag presence.
    pub fn has(&self, name: &str) -> bool {
        self.flag(name).is_some()
    }
}

/// Usage text.
pub const USAGE: &str = "\
duddsketch — distributed P2P quantile tracking with relative value error

USAGE:
  duddsketch run [--config FILE] [--paper-scale] [key=value ...]
      keys: dataset peers rounds fan_out alpha m items graph churn seed
            executor quantiles
  duddsketch figure (--id ID | --all | --list) [--paper-scale] [--out DIR]
      regenerate the paper's tables/figures (CSV + ASCII panels)
  duddsketch sweep --key KEY --values V1,V2,... [key=value ...]
      run one experiment per value of KEY; print worst-ARE per run
  duddsketch quantiles (--input FILE | --dataset NAME --items N)
            [--q Q1,Q2,...] [--alpha A] [--m M]
      sequential UDDSketch over a newline-separated value file
  duddsketch serve-bench [--dataset NAME] [--items N] [--shards S1,S2,...]
            [--q Q1,Q2,...] [--seed X] [key=value ...]
      drive a workload through the sharded ingest service at each shard
      count; report throughput vs the sequential baseline and verify the
      snapshot quantiles against it
      keys: alpha m shards batch queue epoch_ms window
  duddsketch serve-gossip [--dataset NAME] [--items N] [--nodes P]
            [--rounds R] [--q Q1,Q2,...] [--seed X] [key=value ...]
      run one live ingest service plus P-1 simulated peers through the
      continuous gossip loop: ingest lands in chunks between rounds, each
      round reports exchanges/drift/estimated fleet size, and the final
      global-view quantiles are verified against a sequential UDDSketch
      over the union stream
      keys: serve-bench keys plus gossip_fanout gossip_graph gossip_drift
            gossip_probes gossip_seed
  duddsketch serve-remote [--dataset NAME] [--items N] [--nodes P]
            [--rounds R] [--q Q1,Q2,...] [--seed X] [--no-delta]
            [--no-pool] [--metrics-bind HOST:PORT] [key=value ...]
      run P real nodes on loopback TCP: every node binds a serve loop,
      lists the others as remote peers, and gossips framed PeerStates
      (push–pull with per-exchange deadlines, §7.2 cancellation) while
      its own ingest continues; every node's global view is verified
      against a sequential UDDSketch over the union stream. Connection
      pooling and delta frames (docs/PROTOCOL.md) are on by default;
      --no-pool forces a fresh connect per exchange and --no-delta
      forces full frames (handy for A/B-ing the hot-path wins).
      --metrics-bind serves every node's Prometheus /metrics endpoint
      (node k on port+k; port 0 picks an ephemeral port per node — see
      docs/OBSERVABILITY.md)
      keys: serve-gossip keys plus gossip_deadline_ms
            gossip_pool_connections gossip_pool_idle_ms
            gossip_delta_exchanges metrics_bind (shards defaults to 2
            per node here)
  duddsketch serve-remote --membership [--nodes P] [--rounds R]
            [--join-after S] [--kill-after S] [key=value ...]
      live-churn demo on the dynamic membership plane (docs/PROTOCOL.md
      §9): node 0 bootstraps the fleet (member id 0), the others join it
      (dudd-join handshake), and partners are drawn from the live member
      table each round. --join-after S adds one more node mid-run at
      sweep S; --kill-after S crashes the last initial node at sweep S —
      no restart anywhere: survivors suspect it, declare it dead, bump
      the restart generation, and re-converge to the union of the
      SURVIVING streams; final member tables must be byte-identical
      keys: serve-remote keys plus gossip_suspect_after_ms
            gossip_tombstone_ttl_ms
  duddsketch serve-remote --join SEED_ADDR [--bind HOST:PORT]
            [--items N] [--rounds R]
      stand up ONE node that joins a fleet already running elsewhere
      (any member can be the seed), ingest a workload, and report this
      node's per-round convergence. The bound address is what the
      member table advertises, so joining a fleet on other machines
      needs --bind with an address they can route to (the default
      127.0.0.1:0 only works for same-host fleets)
  duddsketch sim-fleet [--scenario NAME|FILE] [--seed X] [--members N]
            [--rounds R] [--items N] [--alpha A] [--m M] [--fan-out F]
            [--graph KIND] [--dataset NAME] [--churn KIND]
            [--drop-prob P] [--restart-free BOOL] [--json-log FILE]
            [--trace FILE] [--events FILE] [--quiet]
      run a whole simulated fleet in one process (docs/SIMULATION.md):
      the production gossip loop + membership plane over simulated
      links with injectable faults, driven round by round on a virtual
      clock. --scenario names a built-in (baseline, churn-storm,
      join-storm, lossy, partition) or a scenario file; the flags
      override its knobs. Every round checks the fleet's union estimate against the
      exact oracle; the run fails unless the fleet converges within
      the bound by the final round. --json-log writes the per-round
      JSON log, --trace the deterministic event trace (same seed ⇒
      byte-identical — diff two runs to prove it), --events the
      structured JSONL event log in the production schema
      (docs/OBSERVABILITY.md), also byte-identical per seed
  duddsketch observe --scrape HOST:PORT[,HOST:PORT...] [--json]
            [--watch [SECS]] [--iterations N] [--timeout-ms MS]
      the convergence observatory (alias: dudd-observe): scrape every
      listed node's Prometheus /metrics endpoint (plus the gossiped
      member table from the first node answering /members), merge the
      per-node summaries, and print a fleet table — rounds, restart
      generation, drift vs the live Theorem 2 bound, exchange RTT
      p50/p99, restart causes — with a one-word fleet verdict
      (converged / converging / degraded / no-data). --json emits the
      same report as one machine-readable JSON object; --watch
      re-scrapes every SECS seconds (default 2) until interrupted or
      --iterations N reports have been printed. --self-test runs the
      observatory's built-in end-to-end check and exits
  duddsketch info
      platform, artifact inventory, defaults

EXAMPLES:
  duddsketch run dataset=adversarial peers=500 rounds=25
  duddsketch figure --id fig3
  duddsketch quantiles --dataset power --items 100000 --q 0.5,0.95,0.99
";

/// Build an experiment config from flags/overrides.
fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))
            .map_err(anyhow::Error::msg)?,
        None => ExperimentConfig::default(),
    };
    if args.has("paper-scale") {
        cfg = cfg.paper_scale();
    }
    for (k, v) in &args.overrides {
        cfg.set(k, v).map_err(anyhow::Error::msg)?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<String> {
    let cfg = config_from(args)?;
    let mut out = format!("run: {}\n", cfg.summary());
    let result = run_with_snapshots(&cfg, &[cfg.rounds])?;
    let snap = result
        .snapshots
        .last()
        .context("no snapshot produced")?;
    out.push_str(&format!(
        "rounds={} online={}/{} seq_alpha={:.6} wall={:.2}s\n",
        snap.rounds, snap.online, cfg.peers, result.seq_alpha, result.wall_s
    ));
    out.push_str("  q       seq-estimate      ARE          median-RE\n");
    for qs in &snap.quantiles {
        out.push_str(&format!(
            "  {:<6}  {:<16.8e}  {:<11.4e}  {:<11.4e}\n",
            qs.q, qs.truth, qs.are, qs.box_summary.median
        ));
    }
    Ok(out)
}

fn cmd_sweep(args: &Args) -> Result<String> {
    let key = args.flag("key").context("sweep: need --key")?.to_string();
    let values: Vec<String> = args
        .flag("values")
        .context("sweep: need --values v1,v2,...")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let base = config_from(args)?;
    let mut out = format!("sweep over {key}: base {}\n", base.summary());
    out.push_str(&format!(
        "  {key:<12}  worst-ARE     mean-ARE      exchanges  MiB-traffic  wall\n"
    ));
    for v in values {
        let mut cfg = base.clone();
        cfg.set(&key, &v).map_err(anyhow::Error::msg)?;
        cfg.validate().map_err(anyhow::Error::msg)?;
        let result = run_with_snapshots(&cfg, &[cfg.rounds])?;
        let snap = result.snapshots.last().context("no snapshot")?;
        let worst = snap.quantiles.iter().map(|q| q.are).fold(0.0f64, f64::max);
        let mean = snap.quantiles.iter().map(|q| q.are).sum::<f64>()
            / snap.quantiles.len().max(1) as f64;
        out.push_str(&format!(
            "  {v:<12}  {worst:<12.4e}  {mean:<12.4e}  {:<9}  {:<11.2}  {:.2}s\n",
            result.exchanges,
            result.bytes as f64 / (1024.0 * 1024.0),
            result.wall_s
        ));
    }
    Ok(out)
}

fn cmd_figure(args: &Args) -> Result<String> {
    if args.has("list") {
        return Ok(format!("available ids: {}\n", figure_ids().join(" ")));
    }
    let out_dir = PathBuf::from(args.flag("out").unwrap_or("results"));
    let paper = args.has("paper-scale");
    let ids: Vec<String> = if args.has("all") {
        figure_ids().iter().map(|s| s.to_string()).collect()
    } else {
        vec![args
            .flag("id")
            .context("figure: need --id <id>, --all or --list")?
            .to_string()]
    };
    let mut out = String::new();
    for id in ids {
        let report = run_figure(&id, paper, &out_dir)?;
        out.push_str(&format!("=== {} ===\n{}", report.id, report.text));
        if !report.csv_path.is_empty() {
            out.push_str(&format!("csv: {}\n", report.csv_path));
        }
    }
    Ok(out)
}

fn cmd_quantiles(args: &Args) -> Result<String> {
    let alpha: f64 = args.flag("alpha").unwrap_or("0.001").parse()?;
    let m: usize = args.flag("m").unwrap_or("1024").parse()?;
    let qs: Vec<f64> = args
        .flag("q")
        .unwrap_or("0.5,0.9,0.95,0.99")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()?;
    let data: Vec<f64> = if let Some(path) = args.flag("input") {
        std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?
            .split_whitespace()
            .map(|t| t.parse::<f64>())
            .collect::<Result<_, _>>()?
    } else {
        let kind: DatasetKind = args
            .flag("dataset")
            .context("quantiles: need --input FILE or --dataset NAME")?
            .parse()
            .map_err(anyhow::Error::msg)?;
        let items: usize = args.flag("items").unwrap_or("100000").parse()?;
        let master = crate::rng::default_rng(
            args.flag("seed").unwrap_or("42").parse()?,
        );
        crate::data::peer_dataset(kind, 0, items, &master)
    };
    if data.is_empty() {
        bail!("no input values");
    }
    let mut sketch: UddSketch = UddSketch::new(alpha, m).map_err(anyhow::Error::msg)?;
    sketch.extend(&data);
    let mut out = format!(
        "n={} buckets={} collapses={} alpha={:.6}\n",
        data.len(),
        sketch.bucket_count(),
        sketch.collapses(),
        sketch.alpha()
    );
    for q in qs {
        out.push_str(&format!(
            "  q={:<5} -> {:.8e}\n",
            q,
            sketch.quantile(q).map_err(anyhow::Error::msg)?
        ));
    }
    Ok(out)
}

fn cmd_serve_bench(args: &Args) -> Result<String> {
    let kind: DatasetKind = args
        .flag("dataset")
        .unwrap_or("uniform")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let items: usize = args.flag("items").unwrap_or("200000").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    let qs: Vec<f64> = args
        .flag("q")
        .unwrap_or("0.01,0.5,0.99")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()?;
    let shard_list: Vec<usize> = args
        .flag("shards")
        .unwrap_or("1,2,4")
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()?;
    let mut base = crate::config::ServiceConfig::default();
    for (k, v) in &args.overrides {
        base.set(k, v).map_err(anyhow::Error::msg)?;
    }
    base.validate().map_err(anyhow::Error::msg)?;
    if items == 0 {
        bail!("serve-bench: need --items >= 1");
    }

    let master = crate::rng::default_rng(seed);
    let data = crate::data::peer_dataset(kind, 0, items, &master);

    let sw = crate::util::Stopwatch::start();
    let mut seq: UddSketch =
        UddSketch::new(base.alpha, base.max_buckets).map_err(anyhow::Error::msg)?;
    seq.extend(&data);
    let seq_secs = sw.secs();

    let mut out = format!(
        "serve-bench: dataset={} items={} {}\n",
        kind.name(),
        items,
        base.summary()
    );
    out.push_str(&format!(
        "  sequential baseline: {:.3}s  ({:.2} Mitems/s)\n",
        seq_secs,
        items as f64 / seq_secs.max(1e-12) / 1e6
    ));
    out.push_str("  shards  writers  wall-s   Mitems/s  speedup  worst-rel-diff\n");
    for &shards in &shard_list {
        let shards = shards.max(1);
        let mut cfg = base.clone();
        cfg.shards = shards;
        let svc = crate::service::QuantileService::start(cfg)?;
        let writers = shards;
        let chunk = items.div_ceil(writers);
        let sw = crate::util::Stopwatch::start();
        std::thread::scope(|scope| {
            for part in data.chunks(chunk) {
                let mut w = svc.writer();
                scope.spawn(move || {
                    w.insert_batch(part);
                    w.flush();
                });
            }
        });
        let snap = svc.flush();
        let secs = sw.secs();
        // Snapshot-vs-sequential verification only makes sense in
        // cumulative mode: a windowed run (window=K, possibly with a
        // background ticker) legitimately evicts older epochs, so the
        // snapshot is not the whole stream.
        let windowed = base.window_slots > 0;
        let diff_col = if windowed {
            "n/a (windowed)".to_string()
        } else {
            let mut worst = 0.0f64;
            for &q in &qs {
                let est = snap.quantile(q).map_err(anyhow::Error::msg)?;
                let truth = seq.quantile(q).map_err(anyhow::Error::msg)?;
                worst = worst.max(crate::metrics::relative_error(est, truth));
            }
            if snap.count() != items as f64 {
                bail!(
                    "service snapshot holds {} items, expected {items}",
                    snap.count()
                );
            }
            format!("{worst:.3e}")
        };
        svc.shutdown();
        out.push_str(&format!(
            "  {shards:<6}  {writers:<7}  {:<7.3}  {:<8.2}  {:<7.2}  {diff_col}\n",
            secs,
            items as f64 / secs.max(1e-12) / 1e6,
            seq_secs / secs.max(1e-12),
        ));
    }
    out.push_str(
        "(worst-rel-diff compares snapshot quantiles to the sequential \
         sketch; 0 = identical, n/a under windowed eviction)\n",
    );
    Ok(out)
}

fn cmd_serve_gossip(args: &Args) -> Result<String> {
    let kind: DatasetKind = args
        .flag("dataset")
        .unwrap_or("exponential")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let items: usize = args.flag("items").unwrap_or("20000").parse()?;
    let nodes: usize = args.flag("nodes").unwrap_or("8").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("30").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    let qs: Vec<f64> = args
        .flag("q")
        .unwrap_or("0.5,0.9,0.99")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()?;
    let mut cfg = crate::config::ServiceConfig::default();
    for (k, v) in &args.overrides {
        cfg.set(k, v).map_err(anyhow::Error::msg)?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    if nodes < 2 {
        bail!("serve-gossip: need --nodes >= 2");
    }
    if items == 0 {
        bail!("serve-gossip: need --items >= 1");
    }
    if rounds == 0 {
        bail!("serve-gossip: need --rounds >= 1");
    }
    if cfg.window_slots > 0 {
        bail!(
            "serve-gossip: windowed mode evicts epochs, so the union-stream \
             verification is undefined — use window=0"
        );
    }

    // One local stream per node, as in the paper's per-peer workloads.
    let master = crate::rng::default_rng(seed);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| crate::data::peer_dataset(kind, i, items, &master))
        .collect();

    // Sequential reference over the union stream — the convergence target.
    let mut seq: UddSketch =
        UddSketch::new(cfg.alpha, cfg.max_buckets).map_err(anyhow::Error::msg)?;
    for d in &datasets {
        seq.extend(d);
    }

    // Node 0 is a live ingest service; nodes 1..P are simulated remote
    // peers with their streams pre-summarized.
    let svc = crate::service::QuantileService::start_shared(cfg.clone())?;
    let mut members = vec![crate::service::GossipMember::service(svc.clone())];
    for d in &datasets[1..] {
        members.push(crate::service::GossipMember::from_dataset(
            d,
            cfg.alpha,
            cfg.max_buckets,
        )?);
    }
    let mut gcfg = cfg.gossip.clone();
    gcfg.round_interval_ms = 0; // the CLI is the clock: one step per row
    if args.has("q") {
        // An explicit --q list drives the drift metric too; otherwise a
        // gossip_probes= override (or the default) stays in charge.
        gcfg.probe_quantiles = qs.clone();
    }
    let gl = crate::service::GossipLoop::start(gcfg.clone(), members)?;

    let mut out = format!(
        "serve-gossip: dataset={} items/node={} nodes={} rounds<={} {}\n",
        kind.name(),
        items,
        nodes,
        rounds,
        gcfg.summary()
    );
    out.push_str(&format!("  service: {}\n", cfg.summary()));
    out.push_str("  round  gen  reseed  exchanges  KiB     drift       p-est\n");

    // Live ingest: node 0's stream lands in chunks between rounds, so the
    // loop reseeds mid-run exactly as a production fleet would.
    let chunks: Vec<&[f64]> = datasets[0].chunks(items.div_ceil(4).max(1)).collect();
    let mut chunk_iter = chunks.iter();
    {
        let mut w = svc.writer();
        for _ in 1..=rounds {
            if let Some(chunk) = chunk_iter.next() {
                w.insert_batch(chunk);
                w.flush();
                svc.flush();
            }
            let r = gl.step();
            let v = gl.view();
            out.push_str(&format!(
                "  {:<5}  {:<3}  {:<6}  {:<9}  {:<6.1}  {:<10.3e}  {}\n",
                r.round,
                r.generation,
                if r.reseeded { "yes" } else { "-" },
                r.exchanges,
                r.bytes as f64 / 1024.0,
                r.drift,
                v.estimated_peers(),
            ));
            if r.converged && chunk_iter.as_slice().is_empty() {
                break;
            }
        }
        // Rounds exhausted before the stream: finish ingest, then let the
        // verification phase below reseed and re-converge.
        for chunk in chunk_iter {
            w.insert_batch(chunk);
            w.flush();
        }
    }
    svc.flush();

    // Converge on the final epoch (bounded), then verify the global view
    // against the sequential union sketch. Three consecutive converged
    // rounds guard against probe estimates that merely paused in one
    // bucket while counters were still settling.
    let mut verify_rounds = 0usize;
    let mut streak = 0usize;
    let converged = loop {
        let r = gl.step();
        verify_rounds += 1;
        streak = if r.converged { streak + 1 } else { 0 };
        if streak >= 3 {
            break true;
        }
        if verify_rounds >= 300 {
            break false;
        }
    };
    let v = gl.view();
    out.push_str(&format!(
        "  final: +{verify_rounds} verify rounds, converged={converged}, \
         epoch={}, p-est={}, N-est={}\n",
        v.epoch(),
        v.estimated_peers(),
        v.estimated_total(),
    ));
    out.push_str("  q       global-view       sequential        rel-diff\n");
    let alpha_bound = seq.alpha();
    let mut worst = 0.0f64;
    for &q in &qs {
        let est = v.query(q).map_err(anyhow::Error::msg)?;
        let truth = seq.quantile(q).map_err(anyhow::Error::msg)?;
        let re = crate::metrics::relative_error(est, truth);
        worst = worst.max(re);
        out.push_str(&format!("  {q:<6}  {est:<16.8e}  {truth:<16.8e}  {re:.3e}\n"));
    }
    gl.shutdown();
    if let Ok(svc) = std::sync::Arc::try_unwrap(svc) {
        svc.shutdown();
    }
    if worst > alpha_bound + 1e-9 {
        bail!(
            "global view did not converge to the sequential union sketch: \
             worst rel-diff {worst:.3e} > alpha {alpha_bound:.3e}"
        );
    }
    out.push_str(&format!(
        "  OK: worst rel-diff {worst:.3e} <= alpha {alpha_bound:.3e}\n"
    ));
    Ok(out)
}

fn cmd_serve_remote(args: &Args) -> Result<String> {
    use crate::service::{Node, TcpTransport, TcpTransportOptions};
    use std::net::SocketAddr;

    if let Some(addr) = args.flag("join") {
        let addr: SocketAddr = addr
            .parse()
            .map_err(|_| anyhow::anyhow!("--join needs a host:port address, got '{addr}'"))?;
        return cmd_serve_remote_join(args, addr);
    }
    if args.has("membership") || args.has("join-after") || args.has("kill-after") {
        return cmd_serve_remote_membership(args);
    }

    let kind: DatasetKind = args
        .flag("dataset")
        .unwrap_or("exponential")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let items: usize = args.flag("items").unwrap_or("8000").parse()?;
    let nodes: usize = args.flag("nodes").unwrap_or("4").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("40").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    let qs: Vec<f64> = args
        .flag("q")
        .unwrap_or("0.5,0.9,0.99")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()?;
    let mut cfg = crate::config::ServiceConfig::default();
    // Each node runs its own service; one-shard-per-core per node would
    // oversubscribe the machine `nodes`-fold. Overridable via shards=.
    cfg.shards = 2;
    for (k, v) in &args.overrides {
        cfg.set(k, v).map_err(anyhow::Error::msg)?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    if nodes < 2 {
        bail!("serve-remote: need --nodes >= 2");
    }
    if items == 0 {
        bail!("serve-remote: need --items >= 1");
    }
    if rounds == 0 {
        bail!("serve-remote: need --rounds >= 1");
    }
    if cfg.window_slots > 0 {
        bail!(
            "serve-remote: windowed mode evicts epochs, so the union-stream \
             verification is undefined — use window=0"
        );
    }

    // One local stream per node, as in the paper's per-peer workloads.
    let master = crate::rng::default_rng(seed);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| crate::data::peer_dataset(kind, i, items, &master))
        .collect();

    // Sequential reference over the union stream — the convergence target.
    let mut seq: UddSketch =
        UddSketch::new(cfg.alpha, cfg.max_buckets).map_err(anyhow::Error::msg)?;
    for d in &datasets {
        seq.extend(d);
    }

    // Bind every node's transport first so the full address book exists
    // before any loop starts, then build the fleet: node k's own service
    // sits at global member index k, everyone else is a remote peer.
    let mut gcfg = cfg.gossip.clone();
    gcfg.round_interval_ms = 0; // the CLI is the clock: one step per row
    if args.has("no-delta") {
        gcfg.delta_exchanges = false;
    }
    if args.has("no-pool") {
        gcfg.pool_connections = 0;
    }
    // --metrics-bind HOST:PORT serves every node's /metrics: node k
    // binds port+k, so one flag covers the whole loopback fleet. Port 0
    // gives each node its own ephemeral port instead.
    let metrics_bind: Option<SocketAddr> = match args.flag("metrics-bind") {
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("--metrics-bind needs a host:port address, got '{v}'")
        })?),
        None => None,
    };
    let opts = TcpTransportOptions::from_gossip(&gcfg);
    let transports: Vec<TcpTransport> = (0..nodes)
        .map(|_| TcpTransport::bind_with("127.0.0.1:0", opts.clone()))
        .collect::<Result<_>>()?;
    let addrs: Vec<SocketAddr> = transports
        .iter()
        .map(|t| t.listen_addr().expect("bound transport has an address"))
        .collect();
    let mut svc_cfg = cfg.clone();
    svc_cfg.gossip = gcfg.clone();
    let mut fleet: Vec<Node> = Vec::with_capacity(nodes);
    for (k, t) in transports.into_iter().enumerate() {
        let mut b = Node::builder()
            .config(svc_cfg.clone())
            .self_index(k)
            .transport(t);
        if let Some(base) = metrics_bind {
            let mut addr = base;
            if base.port() != 0 {
                let port = base
                    .port()
                    .checked_add(k as u16)
                    .context("--metrics-bind port + node index overflows")?;
                addr.set_port(port);
            }
            b = b.metrics_bind(addr);
        }
        for (j, &addr) in addrs.iter().enumerate() {
            if j != k {
                b = b.remote_peer(addr);
            }
        }
        fleet.push(b.build()?);
    }

    let mut out = format!(
        "serve-remote: dataset={} items/node={} nodes={} rounds<={} {}\n",
        kind.name(),
        items,
        nodes,
        rounds,
        gcfg.summary()
    );
    out.push_str(&format!("  service: {}\n", cfg.summary()));
    for (k, node) in fleet.iter().enumerate() {
        out.push_str(&format!(
            "  node {k}: listening on {}\n",
            node.listen_addr().expect("tcp node listens")
        ));
        if let Some(m) = node.metrics_addr() {
            out.push_str(&format!("  node {k}: metrics on http://{m}/metrics\n"));
        }
    }
    out.push_str("  sweep  exchanges  failed  KiB     gen(max)  drift(node0)\n");

    // Live ingest: every node's stream lands in chunks between sweeps, so
    // nodes reseed (and propagate restart generations) mid-run exactly as
    // a production fleet would.
    let chunks: Vec<Vec<&[f64]>> = datasets
        .iter()
        .map(|d| d.chunks(items.div_ceil(4).max(1)).collect())
        .collect();
    let mut writers: Vec<_> = fleet.iter().map(|n| n.writer()).collect();
    let mut fed = 0usize;
    for sweep in 1..=rounds {
        if fed < 4 {
            for (k, node) in fleet.iter().enumerate() {
                if let Some(chunk) = chunks[k].get(fed) {
                    writers[k].insert_batch(chunk);
                    writers[k].flush();
                    node.flush();
                }
            }
            fed += 1;
        }
        let mut exchanges = 0usize;
        let mut failed = 0usize;
        let mut bytes = 0usize;
        for node in &fleet {
            let r = node.step().expect("gossip enabled");
            exchanges += r.exchanges;
            failed += r.failed;
            bytes += r.bytes;
        }
        let gen_max = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled").generation())
            .max()
            .unwrap_or(0);
        let drift0 = fleet[0].global_view().expect("gossip enabled").drift();
        out.push_str(&format!(
            "  {sweep:<5}  {exchanges:<9}  {failed:<6}  {:<6.1}  {gen_max:<8}  {drift0:.3e}\n",
            bytes as f64 / 1024.0,
        ));
    }
    // Drain any chunks the round budget did not cover.
    for (k, node) in fleet.iter().enumerate() {
        for chunk in chunks[k].iter().skip(fed) {
            writers[k].insert_batch(chunk);
            writers[k].flush();
        }
        node.flush();
    }
    drop(writers);

    // Converge on the final epochs (bounded), then verify every node's
    // global view against the sequential union sketch.
    let total = (nodes * items) as f64;
    let mut sweeps = 0usize;
    let converged = loop {
        sweeps += 1;
        for node in &fleet {
            node.step();
        }
        let views: Vec<_> = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled"))
            .collect();
        let gen0 = views[0].generation();
        let all = views.iter().all(|v| {
            v.generation() == gen0 && v.converged() && v.estimated_total() == total
        });
        if all {
            break true;
        }
        if sweeps >= 400 {
            break false;
        }
    };
    let v0 = fleet[0].global_view().expect("gossip enabled");
    out.push_str(&format!(
        "  final: +{sweeps} verify sweeps, converged={converged}, \
         generation={}, p-est={}, N-est={}\n",
        v0.generation(),
        v0.estimated_peers(),
        v0.estimated_total(),
    ));

    out.push_str("  q       worst-node-view   sequential        rel-diff\n");
    let alpha_bound = seq.alpha();
    let mut worst = 0.0f64;
    for &q in &qs {
        let truth = seq.quantile(q).map_err(anyhow::Error::msg)?;
        let mut worst_q = 0.0f64;
        let mut worst_est = f64::NAN;
        for node in &fleet {
            let v = node.global_view().expect("gossip enabled");
            let est = v.query(q).map_err(anyhow::Error::msg)?;
            let re = crate::metrics::relative_error(est, truth);
            if re >= worst_q {
                worst_q = re;
                worst_est = est;
            }
        }
        worst = worst.max(worst_q);
        out.push_str(&format!(
            "  {q:<6}  {worst_est:<16.8e}  {truth:<16.8e}  {worst_q:.3e}\n"
        ));
    }
    for node in fleet {
        node.shutdown();
    }
    if worst > alpha_bound + 1e-9 {
        bail!(
            "remote fleet did not converge to the sequential union sketch: \
             worst rel-diff {worst:.3e} > alpha {alpha_bound:.3e}"
        );
    }
    out.push_str(&format!(
        "  OK: worst rel-diff {worst:.3e} <= alpha {alpha_bound:.3e} across {nodes} nodes\n"
    ));
    Ok(out)
}

/// A live-churn fleet demo (`serve-remote --membership`): node 0
/// bootstraps the membership plane, the others join it, and the
/// `--join-after`/`--kill-after` flags replay a join and a crash against
/// the running fleet — no restart, survivors re-converge to the union of
/// the surviving streams and their member tables settle byte-identical.
fn cmd_serve_remote_membership(args: &Args) -> Result<String> {
    use crate::service::{MemberStatus, Node, TcpTransport, TcpTransportOptions};
    use std::time::Duration;

    let kind: DatasetKind = args
        .flag("dataset")
        .unwrap_or("exponential")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let items: usize = args.flag("items").unwrap_or("4000").parse()?;
    let nodes: usize = args.flag("nodes").unwrap_or("3").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("12").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    let qs: Vec<f64> = args
        .flag("q")
        .unwrap_or("0.5,0.9,0.99")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()?;
    let join_after: Option<usize> = match args.flag("join-after") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let kill_after: Option<usize> = match args.flag("kill-after") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let mut cfg = crate::config::ServiceConfig::default();
    cfg.shards = 2;
    // Demo-friendly suspicion clock (a crashed node turns dead within ~1s
    // of failures); key overrides below still win.
    cfg.gossip.suspect_after_ms = 400;
    for (k, v) in &args.overrides {
        cfg.set(k, v).map_err(anyhow::Error::msg)?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    if nodes < 2 {
        bail!("serve-remote --membership: need --nodes >= 2");
    }
    if items == 0 {
        bail!("serve-remote --membership: need --items >= 1");
    }
    if let Some(s) = kill_after {
        if s == 0 || s > rounds {
            bail!("--kill-after must be within 1..=rounds");
        }
    }
    if let Some(s) = join_after {
        if s == 0 || s > rounds {
            bail!("--join-after must be within 1..=rounds");
        }
    }
    if cfg.window_slots > 0 {
        bail!("serve-remote --membership: use window=0 (union verification)");
    }
    // The CLI is the clock: one step per row. A background round thread
    // would race the sweep loop and drain the per-round telemetry
    // (membership events, pool deltas) out from under the report.
    cfg.gossip.round_interval_ms = 0;

    let total_nodes = nodes + usize::from(join_after.is_some());
    let master = crate::rng::default_rng(seed);
    let datasets: Vec<Vec<f64>> = (0..total_nodes)
        .map(|i| crate::data::peer_dataset(kind, i, items, &master))
        .collect();

    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let build_node = |seed_addr: Option<std::net::SocketAddr>| -> Result<Node> {
        let t = TcpTransport::bind_with("127.0.0.1:0", opts.clone())?;
        let mut b = Node::builder().config(cfg.clone()).transport(t);
        b = match seed_addr {
            None => b.membership_bootstrap(),
            Some(a) => b.join(a),
        };
        b.build()
    };

    let mut fleet: Vec<Node> = vec![build_node(None)?];
    let seed_addr = fleet[0].listen_addr().expect("bootstrap node listens");
    for _ in 1..nodes {
        fleet.push(build_node(Some(seed_addr))?);
    }
    let mut out = format!(
        "serve-remote --membership: dataset={} items/node={} nodes={} rounds<={} {}\n",
        kind.name(),
        items,
        nodes,
        rounds,
        cfg.gossip.summary()
    );
    for (k, node) in fleet.iter().enumerate() {
        out.push_str(&format!(
            "  node {k}: member id {} on {}\n",
            node.membership().expect("membership on").self_id(),
            node.listen_addr().expect("tcp node listens"),
        ));
    }
    out.push_str(
        "  sweep  exchanges  failed  KiB     alive/sus/dead  gen(max)  event\n",
    );

    // Live ingest in chunks, with the join/kill events firing mid-run.
    let mut writers: Vec<_> = fleet.iter().map(|n| n.writer()).collect();
    let mut surviving: Vec<usize> = (0..nodes).collect(); // dataset indices
    let mut fed = 0usize;
    for sweep in 1..=rounds {
        let mut event = String::new();
        if Some(sweep) == join_after {
            let joiner = build_node(Some(seed_addr))?;
            let mut w = joiner.writer();
            w.insert_batch(&datasets[nodes]);
            w.flush();
            joiner.flush();
            event = format!(
                "node joins (member id {})",
                joiner.membership().expect("membership on").self_id()
            );
            writers.push(w);
            fleet.push(joiner);
            surviving.push(nodes);
        }
        if Some(sweep) == kill_after {
            // Kill the last *initial* node: its stream leaves the union.
            let victim = nodes - 1;
            writers.remove(victim);
            let node = fleet.remove(victim);
            if !event.is_empty() {
                event.push_str(" + ");
            }
            event.push_str(&format!("node killed (member id {victim})"));
            node.shutdown();
            surviving.retain(|&d| d != victim);
        }
        if fed < 4 {
            for (slot, &d) in surviving.iter().enumerate() {
                let chunk = items.div_ceil(4).max(1);
                if let Some(part) = datasets[d].chunks(chunk).nth(fed) {
                    if d < nodes {
                        // Initial nodes stream in; the joiner ingested at join.
                        writers[slot].insert_batch(part);
                        writers[slot].flush();
                        fleet[slot].flush();
                    }
                }
            }
            fed += 1;
        }
        let mut exchanges = 0usize;
        let mut failed = 0usize;
        let mut bytes = 0usize;
        // Worst view across the fleet this sweep: max suspects/tombstones
        // held anywhere, min alive — the interesting number while a death
        // is still propagating by anti-entropy.
        let mut mem = (usize::MAX, 0usize, 0usize);
        for node in &fleet {
            let r = node.step().expect("gossip enabled");
            exchanges += r.exchanges;
            failed += r.failed;
            bytes += r.bytes + r.membership.map_or(0, |m| m.bytes);
            if let Some(m) = r.membership {
                mem = (mem.0.min(m.alive), mem.1.max(m.suspect), mem.2.max(m.dead));
            }
        }
        if mem.0 == usize::MAX {
            mem.0 = 0;
        }
        let gen_max = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled").generation())
            .max()
            .unwrap_or(0);
        out.push_str(&format!(
            "  {sweep:<5}  {exchanges:<9}  {failed:<6}  {:<6.1}  {}/{}/{:<10}  {gen_max:<8}  {event}\n",
            bytes as f64 / 1024.0,
            mem.0,
            mem.1,
            mem.2,
        ));
        std::thread::sleep(Duration::from_millis(30));
    }
    // Drain remaining chunks.
    for (slot, &d) in surviving.iter().enumerate() {
        if d < nodes {
            let chunk = items.div_ceil(4).max(1);
            for part in datasets[d].chunks(chunk).skip(fed) {
                writers[slot].insert_batch(part);
                writers[slot].flush();
            }
            fleet[slot].flush();
        }
    }
    drop(writers);

    // Sequential union over the *surviving* streams — the target.
    let mut seq: UddSketch =
        UddSketch::new(cfg.alpha, cfg.max_buckets).map_err(anyhow::Error::msg)?;
    for &d in &surviving {
        seq.extend(&datasets[d]);
    }
    let total: f64 = surviving.iter().map(|&d| datasets[d].len() as f64).sum();

    // Converge (suspicion + anti-entropy need wall time, hence sleeps).
    let mut sweeps = 0usize;
    let converged = loop {
        sweeps += 1;
        for node in &fleet {
            node.step();
        }
        let views: Vec<_> = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled"))
            .collect();
        let gen0 = views[0].generation();
        if views.iter().all(|v| {
            v.generation() == gen0 && v.converged() && v.estimated_total() == total
        }) {
            break true;
        }
        if sweeps >= 600 {
            break false;
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    let v0 = fleet[0].global_view().expect("gossip enabled");
    out.push_str(&format!(
        "  final: +{sweeps} verify sweeps, converged={converged}, generation={}, \
         p-est={}, N-est={}\n",
        v0.generation(),
        v0.estimated_peers(),
        v0.estimated_total(),
    ));

    // Member tables must agree byte for byte across the survivors.
    let tables: Vec<Vec<u8>> = fleet
        .iter()
        .map(|n| n.membership().expect("membership on").encoded_table())
        .collect();
    let tables_agree = tables.iter().all(|t| t == &tables[0]);
    out.push_str(&format!("  member tables byte-identical: {tables_agree}\n"));
    if kill_after.is_some() {
        let dead = fleet[0]
            .membership()
            .expect("membership on")
            .table()
            .iter()
            .filter(|e| e.status == MemberStatus::Dead)
            .count();
        out.push_str(&format!("  tombstones held: {dead}\n"));
    }

    out.push_str("  q       worst-node-view   sequential        rel-diff\n");
    let alpha_bound = seq.alpha();
    let mut worst = 0.0f64;
    for &q in &qs {
        let truth = seq.quantile(q).map_err(anyhow::Error::msg)?;
        let mut worst_q = 0.0f64;
        let mut worst_est = f64::NAN;
        for node in &fleet {
            let v = node.global_view().expect("gossip enabled");
            let est = v.query(q).map_err(anyhow::Error::msg)?;
            let re = crate::metrics::relative_error(est, truth);
            if re >= worst_q {
                worst_q = re;
                worst_est = est;
            }
        }
        worst = worst.max(worst_q);
        out.push_str(&format!(
            "  {q:<6}  {worst_est:<16.8e}  {truth:<16.8e}  {worst_q:.3e}\n"
        ));
    }
    for node in fleet {
        node.shutdown();
    }
    if !tables_agree {
        bail!("surviving member tables diverged");
    }
    if worst > alpha_bound + 1e-9 {
        bail!(
            "membership fleet did not converge to the surviving union sketch: \
             worst rel-diff {worst:.3e} > alpha {alpha_bound:.3e}"
        );
    }
    out.push_str(&format!(
        "  OK: worst rel-diff {worst:.3e} <= alpha {alpha_bound:.3e} across {} survivors\n",
        surviving.len(),
    ));
    Ok(out)
}

/// `serve-remote --join <seed-addr>`: stand up ONE node that joins a
/// fleet already running elsewhere (any member can be the seed), ingest
/// a workload, and report per-round convergence of this node's global
/// view. No union verification — the rest of the fleet's streams live
/// on other machines.
fn cmd_serve_remote_join(args: &Args, seed_addr: std::net::SocketAddr) -> Result<String> {
    use crate::service::{Node, TcpTransport, TcpTransportOptions};

    let kind: DatasetKind = args
        .flag("dataset")
        .unwrap_or("exponential")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let items: usize = args.flag("items").unwrap_or("8000").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("40").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    let mut cfg = crate::config::ServiceConfig::default();
    cfg.shards = 2;
    for (k, v) in &args.overrides {
        cfg.set(k, v).map_err(anyhow::Error::msg)?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    if rounds == 0 {
        bail!("serve-remote --join: need --rounds >= 1");
    }
    cfg.gossip.round_interval_ms = 0; // the CLI is the clock: one step per row

    let master = crate::rng::default_rng(seed);
    let data = crate::data::peer_dataset(kind, 0, items, &master);
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    // The bound address is what the member table advertises, so a node
    // joining a fleet on other machines must bind an address those
    // machines can route to (--bind), not the loopback default.
    let bind = args.flag("bind").unwrap_or("127.0.0.1:0");
    let node = Node::builder()
        .config(cfg)
        .transport(TcpTransport::bind_with(bind, opts)?)
        .join(seed_addr)
        .build()?;
    let m = node.membership().expect("membership on").clone();
    let mut out = format!(
        "serve-remote --join {seed_addr}: assigned member id {} (listening on {})\n",
        m.self_id(),
        node.listen_addr().expect("tcp node listens"),
    );
    let mut w = node.writer();
    w.insert_batch(&data);
    w.flush();
    node.flush();
    out.push_str("  round  gen  exchanges  failed  alive/sus/dead  drift       p-est\n");
    for round in 1..=rounds {
        let r = node.step().expect("gossip enabled");
        let v = node.global_view().expect("gossip enabled");
        let mem = r.membership.unwrap_or_default();
        out.push_str(&format!(
            "  {round:<5}  {:<3}  {:<9}  {:<6}  {}/{}/{:<10}  {:<10.3e}  {}\n",
            r.generation,
            r.exchanges,
            r.failed,
            mem.alive,
            mem.suspect,
            mem.dead,
            r.drift,
            v.estimated_peers(),
        ));
        if r.converged {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    let v = node.global_view().expect("gossip enabled");
    out.push_str(&format!(
        "  final: generation={}, p-est={}, N-est={}, converged={}\n",
        v.generation(),
        v.estimated_peers(),
        v.estimated_total(),
        v.converged(),
    ));
    drop(w);
    node.shutdown();
    Ok(out)
}

/// `sim-fleet`: resolve a scenario (built-in or file), apply flag
/// overrides, run the simulated fleet, and fail the command unless the
/// union estimate converged within the oracle bound by the final round.
fn cmd_sim_fleet(args: &Args) -> Result<String> {
    use crate::sim::{Scenario, SimFleet};

    let name = args.flag("scenario").unwrap_or("baseline");
    let path = std::path::Path::new(name);
    let mut scenario = if path.is_file() {
        Scenario::from_file(path)?
    } else {
        Scenario::builtin(name)?
    };
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    if let Some(v) = args.flag("members") {
        scenario.members = v.parse().context("--members")?;
    }
    if let Some(v) = args.flag("rounds") {
        scenario.rounds = v.parse().context("--rounds")?;
    }
    if let Some(v) = args.flag("items") {
        scenario.items_per_member = v.parse().context("--items")?;
    }
    if let Some(v) = args.flag("alpha") {
        scenario.alpha = v.parse().context("--alpha")?;
    }
    if let Some(v) = args.flag("m") {
        scenario.max_buckets = v.parse().context("--m")?;
    }
    if let Some(v) = args.flag("fan-out") {
        scenario.fan_out = v.parse().context("--fan-out")?;
    }
    if let Some(v) = args.flag("graph") {
        scenario.graph = v.parse().map_err(anyhow::Error::msg).context("--graph")?;
    }
    if let Some(v) = args.flag("dataset") {
        scenario.dataset = v.parse().map_err(anyhow::Error::msg).context("--dataset")?;
    }
    if let Some(v) = args.flag("churn") {
        scenario.churn = v.parse().map_err(anyhow::Error::msg).context("--churn")?;
    }
    if let Some(v) = args.flag("drop-prob") {
        scenario.faults.drop_prob = v.parse().context("--drop-prob")?;
    }
    if let Some(v) = args.flag("restart-free") {
        scenario.restart_free = v.parse().context("--restart-free")?;
    }
    scenario.validate()?;

    let sw = crate::util::Stopwatch::start();
    let mut fleet = SimFleet::new(scenario.clone(), seed)?;
    if args.flag("events").is_some() {
        fleet = fleet.with_event_export();
    }
    let report = fleet.run()?;
    let wall = sw.secs();

    let mut out = format!(
        "sim-fleet: scenario={} seed={seed} members={} rounds={} graph={} \
         dataset={} churn={:?} alpha={} drop={}/{}\n",
        report.scenario,
        report.members_initial,
        scenario.rounds,
        scenario.graph.name(),
        scenario.dataset.name(),
        scenario.churn,
        scenario.alpha,
        scenario.faults.drop_prob,
        scenario.faults.reply_drop_prob,
    );
    if !args.has("quiet") {
        out.push_str(
            "  round  alive  down  exch   failed  KiB       mem-KiB  gen  rel-err     ok  events\n",
        );
        for r in &report.rounds {
            out.push_str(&format!(
                "  {:<5}  {:<5}  {:<4}  {:<5}  {:<6}  {:<8.1}  {:<7.1}  {:<3}  {:<9.3e}  {}  {}\n",
                r.round,
                r.alive,
                r.downed,
                r.exchanges,
                r.failed,
                r.bytes as f64 / 1024.0,
                r.membership_bytes as f64 / 1024.0,
                r.generation,
                r.max_rel_err,
                if r.within_tol { "y " } else { ". " },
                r.events.join(", "),
            ));
        }
    }
    out.push_str(&format!(
        "  net: delivered={} push_lost={} reply_lost={} refused={} wire={:.1} MiB\n",
        report.net.delivered,
        report.net.push_lost,
        report.net.reply_lost,
        report.net.refused,
        report.net.bytes as f64 / (1024.0 * 1024.0),
    ));
    out.push_str(&format!(
        "  trace: {} events ({} members peak), wall {wall:.2}s\n",
        report.trace.len(),
        report.members_peak,
    ));
    if let Some(p) = args.flag("json-log") {
        std::fs::write(p, report.to_json()).with_context(|| format!("writing {p}"))?;
        out.push_str(&format!("  json log: {p}\n"));
    }
    if let Some(p) = args.flag("trace") {
        std::fs::write(p, report.trace_text()).with_context(|| format!("writing {p}"))?;
        out.push_str(&format!("  trace file: {p}\n"));
    }
    if let Some(p) = args.flag("events") {
        std::fs::write(p, report.events_text()).with_context(|| format!("writing {p}"))?;
        out.push_str(&format!(
            "  event log: {p} ({} lines)\n",
            report.events_jsonl.len()
        ));
    }
    match report.converged_round {
        Some(r) => out.push_str(&format!(
            "  OK: converged from round {r} (err {:.3e} <= tol {:.3e}); \
             O(log n) reference: {} rounds for n={}\n",
            report.final_max_rel_err,
            report.tol,
            report.reference_rounds,
            report.members_peak,
        )),
        None => bail!(
            "sim-fleet did not converge: final err {:.3e} > tol {:.3e} \
             after {} rounds\n{out}",
            report.final_max_rel_err,
            report.tol,
            scenario.rounds,
        ),
    }
    Ok(out)
}

fn cmd_observe(args: &Args) -> Result<String> {
    use crate::obs::observe::{observe_fleet, self_test, FleetReport};
    use std::time::Duration;

    if args.has("self-test") {
        self_test().map_err(anyhow::Error::msg)?;
        return Ok("observe self-test: OK\n".to_string());
    }
    let scrape = args
        .flag("scrape")
        .context("observe needs --scrape HOST:PORT[,HOST:PORT...] (or --self-test)")?;
    let targets: Vec<String> = scrape
        .split(',')
        .map(|t| t.trim().to_string())
        .filter(|t| !t.is_empty())
        .collect();
    if targets.is_empty() {
        bail!("--scrape lists no targets");
    }
    let timeout_ms: u64 = args
        .flag("timeout-ms")
        .unwrap_or("2000")
        .parse()
        .context("--timeout-ms wants a positive integer")?;
    if timeout_ms == 0 {
        bail!("--timeout-ms must be positive");
    }
    let timeout = Duration::from_millis(timeout_ms);
    let as_json = args.has("json");
    let render = |report: &FleetReport| {
        if as_json {
            let mut line = report.render_json();
            line.push('\n');
            line
        } else {
            report.render_table()
        }
    };
    let Some(watch) = args.flag("watch") else {
        return Ok(render(&observe_fleet(&targets, timeout)));
    };
    // `--watch` alone re-scrapes every 2 s; `--watch SECS` picks the
    // cadence. `--iterations N` bounds the loop (0 = until killed);
    // each report is printed as it lands, not buffered to the end.
    let every_s: u64 = if watch == "true" {
        2
    } else {
        watch.parse().context("--watch wants whole seconds")?
    };
    if every_s == 0 {
        bail!("--watch interval must be positive");
    }
    let iterations: u64 = args
        .flag("iterations")
        .unwrap_or("0")
        .parse()
        .context("--iterations wants an integer")?;
    let mut printed = 0u64;
    loop {
        print!("{}", render(&observe_fleet(&targets, timeout)));
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        printed += 1;
        if iterations != 0 && printed >= iterations {
            return Ok(String::new());
        }
        std::thread::sleep(Duration::from_secs(every_s));
    }
}

fn cmd_info() -> Result<String> {
    let mut out = String::new();
    out.push_str(&format!(
        "duddsketch {} — {}\n",
        env!("CARGO_PKG_VERSION"),
        env!("CARGO_PKG_DESCRIPTION")
    ));
    out.push_str(&format!("artifacts dir: {}\n", artifacts_dir().display()));
    let avg = list_shaped_artifacts("avg_pairs");
    let bkt = list_shaped_artifacts("bucketize");
    out.push_str(&format!(
        "avg_pairs artifacts: {:?}\n",
        avg.iter().map(|(p, w, _)| (*p, *w)).collect::<Vec<_>>()
    ));
    out.push_str(&format!(
        "bucketize artifacts: {:?}\n",
        bkt.iter().map(|(p, w, _)| (*p, *w)).collect::<Vec<_>>()
    ));
    match crate::runtime::Runtime::cpu() {
        Ok(rt) => out.push_str(&format!("pjrt platform: {}\n", rt.platform())),
        Err(e) => out.push_str(&format!("pjrt unavailable: {e}\n")),
    }
    out.push_str(&format!(
        "defaults: {}\n",
        ExperimentConfig::default().summary()
    ));
    Ok(out)
}

/// Dispatch a parsed command; returns the text to print.
pub fn dispatch(args: &Args) -> Result<String> {
    match args.command.as_str() {
        "run" => cmd_run(args),
        "sweep" => cmd_sweep(args),
        "figure" | "figures" => cmd_figure(args),
        "quantiles" => cmd_quantiles(args),
        "serve-bench" => cmd_serve_bench(args),
        "serve-gossip" => cmd_serve_gossip(args),
        "serve-remote" => cmd_serve_remote(args),
        "sim-fleet" => cmd_sim_fleet(args),
        "observe" | "dudd-observe" => cmd_observe(args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => Ok(USAGE.to_string()),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parse_flags_and_overrides() {
        let a = args(&["run", "--paper-scale", "peers=500", "--out", "dir"]);
        assert_eq!(a.command, "run");
        assert!(a.has("paper-scale"));
        assert_eq!(a.flag("out"), Some("dir"));
        assert_eq!(a.overrides, vec![("peers".into(), "500".into())]);
    }

    #[test]
    fn no_command_means_help() {
        let a = args(&[]);
        assert_eq!(a.command, "help");
        assert!(dispatch(&a).unwrap().contains("USAGE"));
    }

    #[test]
    fn unknown_command_errors() {
        let a = args(&["frobnicate"]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn run_small_experiment_via_cli() {
        let a = args(&[
            "run",
            "peers=40",
            "items=100",
            "rounds=8",
            "dataset=exponential",
            "quantiles=0.5,0.9",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("ARE"), "{out}");
        assert!(out.contains("q=0.5") || out.contains("0.5"), "{out}");
    }

    #[test]
    fn quantiles_on_generated_dataset() {
        let a = args(&[
            "quantiles",
            "--dataset",
            "power",
            "--items",
            "5000",
            "--q",
            "0.5,0.99",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("q=0.5"), "{out}");
    }

    #[test]
    fn quantiles_from_file() {
        let dir = std::env::temp_dir().join("duddsketch_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("vals.txt");
        std::fs::write(&p, "1.0\n2.0\n3.0\n4.0\n5.0\n").unwrap();
        let a = args(&["quantiles", "--input", p.to_str().unwrap(), "--q", "0.5"]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("n=5"), "{out}");
    }

    #[test]
    fn sweep_over_fanout() {
        let a = args(&[
            "sweep",
            "--key",
            "fan_out",
            "--values",
            "1,2",
            "peers=40",
            "items=100",
            "rounds=6",
            "dataset=uniform",
            "quantiles=0.5",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("sweep over fan_out"), "{out}");
        // one row per value + header lines
        assert!(out.lines().count() >= 4, "{out}");
    }

    #[test]
    fn serve_bench_verifies_against_sequential() {
        let a = args(&[
            "serve-bench",
            "--dataset",
            "uniform",
            "--items",
            "20000",
            "--shards",
            "1,2",
            "--q",
            "0.5,0.99",
            "batch=256",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("sequential baseline"), "{out}");
        assert!(out.contains("worst-rel-diff"), "{out}");
        // One row per shard count + headers/footer.
        assert!(out.lines().count() >= 6, "{out}");
    }

    #[test]
    fn serve_gossip_converges_and_verifies() {
        let a = args(&[
            "serve-gossip",
            "--dataset",
            "uniform",
            "--items",
            "2000",
            "--nodes",
            "3",
            "--rounds",
            "12",
            "--q",
            "0.5,0.99",
            "batch=256",
            "shards=2",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("serve-gossip"), "{out}");
        assert!(out.contains("global-view"), "{out}");
        assert!(out.contains("OK: worst rel-diff"), "{out}");
        // Live ingest reseeds the fleet at least once mid-run.
        assert!(out.contains("yes"), "no reseed observed:\n{out}");
    }

    #[test]
    fn serve_remote_converges_over_loopback_tcp() {
        let a = args(&[
            "serve-remote",
            "--dataset",
            "uniform",
            "--items",
            "1500",
            "--nodes",
            "3",
            "--rounds",
            "20",
            "--q",
            "0.5,0.99",
            "batch=256",
            "shards=2",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("serve-remote"), "{out}");
        assert!(out.contains("listening on 127.0.0.1:"), "{out}");
        assert!(out.contains("worst-node-view"), "{out}");
        assert!(out.contains("OK: worst rel-diff"), "{out}");
    }

    #[test]
    fn serve_remote_metrics_bind_prints_a_scrape_address_per_node() {
        // Port 0 gives each node its own ephemeral /metrics listener;
        // the run must report one scrape address per node and still
        // converge as usual.
        let a = args(&[
            "serve-remote",
            "--dataset",
            "uniform",
            "--items",
            "1000",
            "--nodes",
            "2",
            "--rounds",
            "20",
            "--metrics-bind",
            "127.0.0.1:0",
            "batch=256",
            "shards=1",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("OK: worst rel-diff"), "{out}");
        assert_eq!(
            out.matches("metrics on http://127.0.0.1:").count(),
            2,
            "{out}"
        );
    }

    #[test]
    fn serve_remote_full_frames_and_fresh_connects_still_converge() {
        // --no-delta/--no-pool A/B the hot-path machinery off; the
        // protocol result must be identical (full frames, fresh
        // connects).
        let a = args(&[
            "serve-remote",
            "--dataset",
            "uniform",
            "--items",
            "800",
            "--nodes",
            "2",
            "--rounds",
            "10",
            "--q",
            "0.5",
            "--no-delta",
            "--no-pool",
            "batch=256",
            "shards=1",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("pool=0"), "{out}");
        assert!(out.contains("delta=false"), "{out}");
        assert!(out.contains("OK: worst rel-diff"), "{out}");
    }

    /// The live-churn demo end to end: bootstrap + joins, one node
    /// joining mid-run, one crashing mid-run, survivors re-converging
    /// to the surviving union with byte-identical member tables.
    #[test]
    fn serve_remote_membership_churn_demo() {
        let a = args(&[
            "serve-remote",
            "--membership",
            "--items",
            "800",
            "--nodes",
            "3",
            "--rounds",
            "6",
            "--join-after",
            "2",
            "--kill-after",
            "4",
            "--q",
            "0.5,0.99",
            "batch=256",
            "shards=1",
            "gossip_suspect_after_ms=150",
        ]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("member id 0"), "{out}");
        assert!(out.contains("node joins"), "{out}");
        assert!(out.contains("node killed"), "{out}");
        assert!(out.contains("member tables byte-identical: true"), "{out}");
        assert!(out.contains("tombstones held: 1"), "{out}");
        assert!(out.contains("OK: worst rel-diff"), "{out}");
    }

    #[test]
    fn serve_remote_membership_rejects_bad_inputs() {
        let a = args(&["serve-remote", "--membership", "--nodes", "1"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-remote", "--membership", "--kill-after", "0"]);
        assert!(dispatch(&a).is_err());
        let a = args(&[
            "serve-remote",
            "--membership",
            "--rounds",
            "5",
            "--join-after",
            "9",
        ]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-remote", "--join", "not-an-addr"]);
        assert!(dispatch(&a).is_err());
        let a = args(&[
            "serve-remote",
            "--membership",
            "--items",
            "100",
            "gossip_suspect_after_ms=0",
        ]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn serve_remote_rejects_bad_inputs() {
        let a = args(&["serve-remote", "--nodes", "1"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-remote", "--items", "0"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-remote", "--items", "100", "window=2"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-remote", "--items", "100", "gossip_deadline_ms=0"]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn serve_gossip_rejects_bad_inputs() {
        let a = args(&["serve-gossip", "--nodes", "1"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-gossip", "--items", "100", "window=2"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["serve-gossip", "--items", "100", "bogus=1"]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn serve_bench_rejects_bad_overrides() {
        let a = args(&["serve-bench", "--items", "100", "bogus_key=1"]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn sim_fleet_converges_and_logs_are_deterministic() {
        let dir = std::env::temp_dir().join("duddsketch_sim_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = dir.join("rounds.json");
        let trace_a = dir.join("trace_a.txt");
        let trace_b = dir.join("trace_b.txt");
        let events_a = dir.join("events_a.jsonl");
        let events_b = dir.join("events_b.jsonl");
        let run = |trace: &std::path::Path, events: &std::path::Path| {
            let a = args(&[
                "sim-fleet",
                "--members",
                "10",
                "--rounds",
                "14",
                "--items",
                "80",
                "--alpha",
                "0.01",
                "--m",
                "256",
                "--seed",
                "9",
                "--json-log",
                json.to_str().unwrap(),
                "--trace",
                trace.to_str().unwrap(),
                "--events",
                events.to_str().unwrap(),
            ]);
            dispatch(&a).unwrap()
        };
        let out = run(&trace_a, &events_a);
        assert!(out.contains("OK: converged from round"), "{out}");
        assert!(out.contains("O(log n) reference"), "{out}");
        assert!(out.contains("event log:"), "{out}");
        let log = std::fs::read_to_string(&json).unwrap();
        assert!(log.contains("\"summary\""), "{log}");
        run(&trace_b, &events_b);
        let a = std::fs::read(&trace_a).unwrap();
        let b = std::fs::read(&trace_b).unwrap();
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must produce a byte-identical trace");
        let ea = std::fs::read_to_string(&events_a).unwrap();
        let eb = std::fs::read_to_string(&events_b).unwrap();
        assert!(ea.lines().count() > 0, "event log must not be empty");
        assert!(
            ea.lines().all(|l| l.starts_with("{\"event\":")),
            "every event line is a flat JSON object"
        );
        assert_eq!(ea, eb, "same seed must produce a byte-identical event log");
    }

    #[test]
    fn sim_fleet_rejects_bad_inputs() {
        let a = args(&["sim-fleet", "--scenario", "no-such-scenario"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["sim-fleet", "--members", "1"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["sim-fleet", "--rounds", "0"]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn figure_list() {
        let a = args(&["figure", "--list"]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("fig12"));
    }

    #[test]
    fn observe_self_test_passes() {
        let a = args(&["observe", "--self-test"]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("OK"), "{out}");
        // The binary-style alias dispatches to the same command.
        let a = args(&["dudd-observe", "--self-test"]);
        assert!(dispatch(&a).unwrap().contains("OK"));
    }

    #[test]
    fn observe_rejects_bad_inputs() {
        let a = args(&["observe"]);
        assert!(dispatch(&a).is_err(), "missing --scrape must fail");
        let a = args(&["observe", "--scrape", ","]);
        assert!(dispatch(&a).is_err(), "empty target list must fail");
        let a = args(&["observe", "--scrape", "x:1", "--timeout-ms", "0"]);
        assert!(dispatch(&a).is_err());
        let a = args(&["observe", "--scrape", "x:1", "--watch", "0"]);
        assert!(dispatch(&a).is_err());
    }

    /// End to end over a real socket: bind a metrics endpoint, point
    /// `observe --json` at it, and check the machine-readable report
    /// carries the verdict and per-node fields the CI smoke asserts on.
    #[test]
    fn observe_scrapes_a_live_endpoint_and_emits_the_json_verdict() {
        use crate::obs::{MetricsRegistry, MetricsServer};
        use std::sync::Arc;

        let registry = Arc::new(MetricsRegistry::new());
        registry
            .counter("dudd_rounds_total", "gossip rounds driven")
            .unwrap()
            .add(7);
        registry
            .gauge("dudd_converged", "node convergence flag")
            .unwrap()
            .set(1.0);
        registry
            .gauge("dudd_drift", "round-over-round drift")
            .unwrap()
            .set(1e-4);
        let server = MetricsServer::bind("127.0.0.1:0", Arc::clone(&registry)).unwrap();
        let target = server.local_addr().to_string();

        let a = args(&["observe", "--scrape", &target, "--json"]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("\"verdict\":"), "{out}");
        assert!(out.contains("\"rounds\":7"), "{out}");
        assert!(out.contains("\"converged\":true"), "{out}");

        // Table mode reports the same fleet, plus an unreachable row
        // for a dead target.
        let a = args(&["observe", "--scrape", &format!("{target},127.0.0.1:1")]);
        let out = dispatch(&a).unwrap();
        assert!(out.contains("verdict"), "{out}");
        assert!(out.contains(&target), "{out}");
        assert!(out.contains("UNREACHABLE"), "{out}");
    }
}
