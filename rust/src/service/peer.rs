//! Fronting a gossip peer with a live service.
//!
//! The paper assumes every peer "already maintains a local UDDSketch over
//! its own stream" (Algorithm 3). In production that local summary is
//! exactly what [`QuantileService`](super::QuantileService) maintains:
//! [`ServicePeer`] adapts the service's latest snapshot into a
//! [`PeerState`] the gossip engine can exchange, and re-seeds it whenever
//! a newer epoch is published. Distributed averaging re-converges from
//! any initial states (Prop. 4), so refresh-then-gossip is sound.
//!
//! `ServicePeer` is the one-shot bridge; the *continuous* refresh →
//! exchange → serve cycle over a whole fleet lives in
//! [`GossipLoop`](super::GossipLoop).

#![forbid(unsafe_code)]

use super::coordinator::QuantileService;
use crate::gossip::PeerState;

/// A gossip peer whose local sketch tracks a service's snapshots.
#[derive(Debug)]
pub struct ServicePeer {
    epoch: u64,
    state: PeerState,
}

impl ServicePeer {
    /// Front `svc` as gossip peer `id`, seeded from the current snapshot.
    pub fn new(id: usize, svc: &QuantileService) -> Self {
        let snap = svc.snapshot();
        Self {
            epoch: snap.epoch(),
            state: PeerState::from_sketch(id, snap.sketch()),
        }
    }

    /// Snapshot epoch the peer state was last seeded from.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Re-seed from the latest snapshot if a newer epoch was published;
    /// returns `true` when the local state was rebuilt. Averaged scalar
    /// state restarts alongside the sketch — the protocol re-converges.
    pub fn refresh(&mut self, svc: &QuantileService) -> bool {
        let snap = svc.snapshot();
        if snap.epoch() == self.epoch {
            return false;
        }
        self.epoch = snap.epoch();
        self.state = PeerState::from_sketch(self.state.id, snap.sketch());
        true
    }

    /// The gossip-facing peer state.
    pub fn state(&self) -> &PeerState {
        &self.state
    }

    /// Mutable access for exchanges ([`PeerState::exchange`]).
    pub fn state_mut(&mut self) -> &mut PeerState {
        &mut self.state
    }

    /// Unwrap into the underlying peer state.
    pub fn into_state(self) -> PeerState {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;

    fn service_with(values: &[f64], shards: usize) -> QuantileService {
        let mut cfg = ServiceConfig::default();
        cfg.shards = shards;
        let svc = QuantileService::start(cfg).unwrap();
        let mut w = svc.writer();
        w.insert_batch(values);
        w.flush();
        svc.flush();
        svc
    }

    #[test]
    fn refresh_tracks_new_epochs() {
        let svc = service_with(&[1.0, 2.0, 3.0], 2);
        let mut peer = ServicePeer::new(5, &svc);
        assert_eq!(peer.epoch(), 1);
        assert_eq!(peer.state().id, 5);
        assert_eq!(peer.state().n_tilde, 3.0);
        assert!(!peer.refresh(&svc), "no new epoch yet");

        let mut w = svc.writer();
        w.insert(4.0);
        w.flush();
        svc.flush();
        assert!(peer.refresh(&svc));
        assert_eq!(peer.epoch(), 2);
        assert_eq!(peer.state().n_tilde, 4.0);
        svc.shutdown();
    }

    #[test]
    fn two_service_peers_converge_via_exchange() {
        // Two services front two gossip peers; one atomic push–pull
        // exchange fully averages a 2-peer network, after which both
        // reconstruct the *global* quantiles exactly (Algorithm 6 at the
        // fixed point).
        let xs: Vec<f64> = (1..=600).map(|i| i as f64).collect();
        let ys: Vec<f64> = (601..=1000).map(|i| i as f64).collect();
        let svc_a = service_with(&xs, 2);
        let svc_b = service_with(&ys, 3);

        let mut seq = crate::sketch::UddSketch::<crate::sketch::DenseStore>::new(
            0.001, 1024,
        )
        .unwrap();
        seq.extend(&xs);
        seq.extend(&ys);

        let mut a = ServicePeer::new(0, &svc_a);
        let mut b = ServicePeer::new(1, &svc_b);
        PeerState::exchange(a.state_mut(), b.state_mut()).unwrap();

        for q in [0.01, 0.5, 0.99] {
            let truth = seq.quantile(q).unwrap();
            assert_eq!(a.state().query(q).unwrap(), truth, "peer a q={q}");
            assert_eq!(b.state().query(q).unwrap(), truth, "peer b q={q}");
        }
        svc_a.shutdown();
        svc_b.shutdown();
    }
}
