//! The exchange transport layer: how a gossip member reaches a partner.
//!
//! PR 2's [`GossipLoop`](super::GossipLoop) called partner state
//! directly — every member lived in the same process. This module puts
//! the paper's **atomic push–pull exchange** (Algorithm 4) behind a
//! [`Transport`] trait so the same loop drives in-process fleets and
//! fleets of real nodes on different machines:
//!
//! ```text
//!   initiator                              partner
//!   ─────────                              ───────
//!   push  ──[len u32][UDDX push frame]──▶  decode, try-lock state
//!                                          average (Algorithm 4 UPDATE)
//!   pull  ◀─[len u32][UDDX reply frame]──  commit iff the reply is on
//!   adopt reply                            the wire; roll back otherwise
//! ```
//!
//! **Failure semantics (§7.2).** Any failure — connect refusal, a missed
//! deadline, a malformed frame, a busy or stale partner — cancels the
//! exchange: the initiator returns an error *without touching its state*,
//! and the serving side commits its averaged state only after the reply
//! write succeeds (rolling back when it does not). Both sides therefore
//! keep their pre-round state, the cancelled-exchange model the paper's
//! churn analysis assumes; the loop counts these in
//! [`GossipRoundReport::failed`](super::GossipRoundReport::failed).
//!
//! One caveat is fundamental (Two Generals): "the reply write succeeded"
//! means the bytes entered the kernel's send buffer, not that the
//! initiator read them. A reply lost *after* that point half-commits the
//! exchange — the server adopted the average, the initiator kept its
//! state — skewing the generation's `q̃` mass by the difference. The
//! window is one in-flight reply against a deadline-long read budget, so
//! it is rare; and the skew is bounded in time, because the next protocol
//! restart (epoch advance anywhere → new generation, every node reseeds)
//! restores the mass to exactly 1.
//!
//! **Concurrency model.** Rounds and inbound serves share one worker
//! lock: a node mid-round rejects inbound pushes as `Busy` (a §7.2
//! cancellation the initiator retries next round) rather than queueing —
//! that is what makes cross-node deadlock impossible with blocking
//! sockets. The cost is that a round stalled on a dead peer (up to
//! fan-out × deadline) also serves nothing; background fleets should
//! stagger `round_interval_ms` (or keep intervals ≫ deadline) so rounds
//! rarely collide. Finer-grained locking is a ROADMAP item.
//!
//! Two implementations ship:
//!
//! * [`InProcessTransport`] — PR 2's behavior behind the trait: direct
//!   in-memory exchanges with the codec's byte accounting. Results are
//!   bit-identical to the pre-trait loop (`rust/tests/integration_remote.rs`
//!   proves it against the simulation engine).
//! * [`TcpTransport`] — length-prefixed [`codec`](crate::sketch::codec)
//!   frames over `std::net`: one accept loop per node serving inbound
//!   pushes, per-exchange deadlines on connect/read/write, and generation
//!   tags so nodes that restarted their protocol (new epoch ⇒ reseed)
//!   never average with states from an older restart.
//!
//! Construction normally goes through
//! [`Node::builder()`](super::Node::builder); see the `serve-remote` CLI
//! subcommand for a full loopback fleet.

use super::gossip_loop::{NodeHandle, ServeReject};
use crate::gossip::PeerState;
use crate::sketch::codec::{
    decode_exchange, encode_exchange_push, encode_exchange_reject, encode_exchange_reply,
    peer_state_wire_size, ExchangeFrame, RejectReason,
};
use anyhow::Context;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Why an exchange was cancelled (initiator side; §7.2 — the local state
/// is untouched whenever one of these is returned).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// Socket-level failure: connect, read, or write failed or missed
    /// the per-exchange deadline.
    Io(String),
    /// The partner's bytes failed to decode.
    Codec(String),
    /// The partner is mid-exchange or mid-round; retry next round.
    Busy,
    /// Our restart generation is behind the partner's (the payload): the
    /// loop reseeds and catches up at its next refresh.
    StaleGeneration(u64),
    /// A frame decoded but violated the exchange protocol.
    Protocol(String),
    /// Sketch α₀ lineages differ; these members can never merge.
    Lineage(String),
    /// This transport cannot reach remote members at all.
    Unreachable(SocketAddr),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "exchange i/o failed: {e}"),
            TransportError::Codec(e) => write!(f, "exchange frame invalid: {e}"),
            TransportError::Busy => write!(f, "partner busy (exchange cancelled)"),
            TransportError::StaleGeneration(g) => {
                write!(f, "partner is at restart generation {g}, ours is older")
            }
            TransportError::Protocol(e) => write!(f, "exchange protocol violation: {e}"),
            TransportError::Lineage(e) => write!(f, "alpha0 lineage mismatch: {e}"),
            TransportError::Unreachable(addr) => {
                write!(f, "transport cannot reach remote peer {addr}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// How a [`GossipLoop`](super::GossipLoop) executes the atomic push–pull
/// exchange with a partner — in process or across the network.
///
/// Implementations must uphold §7.2's cancelled-exchange contract: when
/// any method returns `Err`, every `&mut PeerState` it received is
/// exactly its pre-call value.
pub trait Transport: Send + Sync + std::fmt::Debug + 'static {
    /// Short human name for telemetry and error messages.
    fn name(&self) -> &'static str;

    /// True when [`Transport::exchange_remote`] can actually reach a
    /// socket address. The loop refuses to start a fleet containing
    /// [`GossipMember::Remote`](super::GossipMember::Remote) members on a
    /// transport that cannot.
    fn supports_remote(&self) -> bool {
        false
    }

    /// Atomic push–pull between two co-located members: both end up with
    /// the averaged state, or neither changes. Returns the wire bytes the
    /// exchange *would* move (push + pull frames, codec byte-exact) for
    /// traffic accounting.
    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError>;

    /// Atomic push–pull with a remote node: push `local`'s framed state
    /// at restart generation `generation`, pull the averaged reply, and
    /// adopt it. Returns the bytes moved on the wire. On `Err`, `local`
    /// is exactly its pre-call value (cancelled exchange, §7.2).
    fn exchange_remote(
        &self,
        local: &mut PeerState,
        generation: u64,
        peer: SocketAddr,
    ) -> Result<usize, TransportError> {
        let _ = (local, generation);
        Err(TransportError::Unreachable(peer))
    }

    /// The address this transport's accept loop serves, if it has one.
    fn listen_addr(&self) -> Option<SocketAddr> {
        None
    }

    /// Spawn the serve side (accept loop), if this transport has one.
    /// Called once by [`GossipLoop`](super::GossipLoop) at start; the
    /// returned thread must watch [`NodeHandle::stopping`] and exit
    /// promptly when it turns true.
    fn spawn_server(&self, node: NodeHandle) -> crate::Result<Option<JoinHandle<()>>> {
        let _ = node;
        Ok(None)
    }
}

/// The shared in-memory exchange: [`PeerState::exchange`] plus PR 2's
/// exact byte accounting (push frame sized before the exchange, pull
/// frame after). Both shipped transports use it for co-located pairs, so
/// local exchanges are bit-identical across transports.
pub fn in_process_exchange(
    a: &mut PeerState,
    b: &mut PeerState,
) -> Result<usize, TransportError> {
    let push = peer_state_wire_size(a);
    // `exchange` validates the lineage before mutating anything, so an
    // error here leaves both states untouched (§7.2).
    PeerState::exchange(a, b).map_err(|e| TransportError::Lineage(e.to_string()))?;
    Ok(push + peer_state_wire_size(b))
}

/// PR 2's in-process behavior behind the [`Transport`] trait: members
/// exchange directly in memory, remote members are unreachable.
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcessTransport;

impl Transport for InProcessTransport {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError> {
        in_process_exchange(a, b)
    }
}

/// Hard cap on a length-prefixed frame. A peer state is ~16 bytes per
/// live bucket plus a fixed header (~16 KiB at the default m = 1024);
/// 4 MiB admits bucket budgets up to ~260k while bounding what a
/// connection flood can pin to `MAX_INFLIGHT_SERVES × 4 MiB` — and the
/// incremental read below means even that much is allocated only for
/// bytes a peer actually sends.
pub const MAX_FRAME_BYTES: usize = 4 << 20;

/// Write one `[len u32 LE][frame]` record.
fn write_frame(mut w: impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    w.flush()
}

/// Read one `[len u32 LE][frame]` record, rejecting absurd lengths.
///
/// The buffer grows with the bytes that actually arrive (via
/// [`Read::take`]), so a hostile prefix claiming a huge length pins no
/// memory beyond what the peer really sends within the socket deadline.
fn read_frame(mut r: impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"),
        ));
    }
    let mut buf = Vec::with_capacity(len.min(64 << 10));
    (&mut r).take(len as u64).read_to_end(&mut buf)?;
    if buf.len() != len {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("frame truncated: got {} of {len} bytes", buf.len()),
        ));
    }
    Ok(buf)
}

/// Length-prefixed exchange frames over `std::net` TCP.
///
/// Bind one per serving node ([`TcpTransport::bind`], address book
/// built *before* any loop starts so nodes can list each other as
/// [`GossipMember::Remote`](super::GossipMember::Remote)); pure clients
/// use [`TcpTransport::connect_only`]. Every socket operation carries the
/// per-exchange deadline
/// ([`GossipLoopConfig::exchange_deadline_ms`](crate::config::GossipLoopConfig::exchange_deadline_ms));
/// a missed deadline cancels the exchange with both sides keeping their
/// pre-round state (§7.2).
#[derive(Debug)]
pub struct TcpTransport {
    /// Taken (once) by `spawn_server` when the loop starts.
    listener: Mutex<Option<TcpListener>>,
    local_addr: Option<SocketAddr>,
    deadline: Duration,
}

impl TcpTransport {
    /// Bind the accept side on `addr` (use port 0 for an OS-assigned
    /// loopback port) with the given per-exchange deadline.
    pub fn bind(addr: impl ToSocketAddrs, deadline: Duration) -> crate::Result<Self> {
        anyhow::ensure!(
            !deadline.is_zero(),
            "gossip_exchange_deadline_ms must be >= 1 (a zero deadline \
             cancels every remote exchange)"
        );
        let listener = TcpListener::bind(addr).context("binding gossip transport listener")?;
        let local_addr = listener
            .local_addr()
            .context("resolving transport listen address")?;
        Ok(Self {
            listener: Mutex::new(Some(listener)),
            local_addr: Some(local_addr),
            deadline,
        })
    }

    /// A client-only transport: can initiate exchanges with remote nodes
    /// but serves no inbound ones (no accept loop).
    pub fn connect_only(deadline: Duration) -> crate::Result<Self> {
        anyhow::ensure!(
            !deadline.is_zero(),
            "gossip_exchange_deadline_ms must be >= 1 (a zero deadline \
             cancels every remote exchange)"
        );
        Ok(Self {
            listener: Mutex::new(None),
            local_addr: None,
            deadline,
        })
    }

    /// The per-exchange deadline.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError> {
        // Co-located members short-circuit the socket: byte-identical to
        // the in-process transport.
        in_process_exchange(a, b)
    }

    fn exchange_remote(
        &self,
        local: &mut PeerState,
        generation: u64,
        peer: SocketAddr,
    ) -> Result<usize, TransportError> {
        let io = |e: std::io::Error| TransportError::Io(e.to_string());
        let stream = TcpStream::connect_timeout(&peer, self.deadline).map_err(io)?;
        stream.set_read_timeout(Some(self.deadline)).map_err(io)?;
        stream.set_write_timeout(Some(self.deadline)).map_err(io)?;
        let _ = stream.set_nodelay(true);

        let push = encode_exchange_push(generation, local);
        write_frame(&stream, &push).map_err(io)?;
        let reply = read_frame(&stream).map_err(io)?;
        match decode_exchange(&reply).map_err(|e| TransportError::Codec(e.to_string()))? {
            ExchangeFrame::Reply {
                generation: gen,
                state,
            } => {
                if gen != generation {
                    return Err(TransportError::Protocol(format!(
                        "reply at generation {gen}, push was {generation}"
                    )));
                }
                if state.id != local.id {
                    return Err(TransportError::Protocol(format!(
                        "reply carries peer id {}, expected {}",
                        state.id, local.id
                    )));
                }
                if !state.sketch.mapping().same_lineage(local.sketch.mapping()) {
                    return Err(TransportError::Lineage(format!(
                        "reply alpha0 {} vs local {}",
                        state.sketch.mapping().alpha0(),
                        local.sketch.mapping().alpha0()
                    )));
                }
                // Commit point: the partner already committed when its
                // reply write succeeded; adopting completes the exchange.
                *local = state;
                Ok(8 + push.len() + reply.len())
            }
            ExchangeFrame::Reject {
                generation: gen,
                reason,
            } => Err(match reason {
                RejectReason::Busy => TransportError::Busy,
                RejectReason::StaleGeneration => TransportError::StaleGeneration(gen),
                RejectReason::Lineage => {
                    TransportError::Lineage("partner rejected: alpha0 lineage mismatch".into())
                }
                RejectReason::Malformed => {
                    TransportError::Protocol("partner rejected the push frame as malformed".into())
                }
            }),
            ExchangeFrame::Push { .. } => {
                Err(TransportError::Protocol("partner replied with a push frame".into()))
            }
        }
    }

    fn listen_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    fn spawn_server(&self, node: NodeHandle) -> crate::Result<Option<JoinHandle<()>>> {
        let listener = self
            .listener
            .lock()
            .expect("transport listener mutex poisoned")
            .take();
        let Some(listener) = listener else {
            return Ok(None);
        };
        listener
            .set_nonblocking(true)
            .context("switching the accept loop to non-blocking")?;
        let deadline = self.deadline;
        let handle = std::thread::Builder::new()
            .name("dudd-accept".into())
            .spawn(move || accept_loop(&listener, &node, deadline))
            .context("spawning transport accept loop")?;
        Ok(Some(handle))
    }
}

/// Most inbound exchanges served concurrently; connections beyond this
/// are dropped (the initiator counts a cancelled exchange and retries
/// next round, §7.2), bounding thread count and memory under a
/// connection flood.
const MAX_INFLIGHT_SERVES: usize = 32;

/// Accept loop: non-blocking accept polled against the stop flag (≤5 ms
/// latency to shut down), one short-lived handler thread per inbound
/// exchange, capped at [`MAX_INFLIGHT_SERVES`]. Handlers are bounded by
/// the socket deadlines, so a stuck client can never wedge the node.
fn accept_loop(listener: &TcpListener, node: &NodeHandle, deadline: Duration) {
    let inflight = Arc::new(AtomicUsize::new(0));
    while !node.stopping() {
        match listener.accept() {
            Ok((stream, _)) => {
                if inflight.load(Ordering::SeqCst) >= MAX_INFLIGHT_SERVES {
                    drop(stream); // overload: cancelled exchange (§7.2)
                    continue;
                }
                inflight.fetch_add(1, Ordering::SeqCst);
                let node = node.clone();
                let inflight = inflight.clone();
                let spawned = std::thread::Builder::new()
                    .name("dudd-exchange".into())
                    .spawn(move || {
                        serve_connection(&stream, &node, deadline);
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    inflight.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Serve one inbound exchange on an accepted connection.
fn serve_connection(stream: &TcpStream, node: &NodeHandle, deadline: Duration) {
    // The listener is non-blocking; the exchange itself must not be.
    if stream.set_nonblocking(false).is_err()
        || stream.set_read_timeout(Some(deadline)).is_err()
        || stream.set_write_timeout(Some(deadline)).is_err()
    {
        return;
    }
    let _ = stream.set_nodelay(true);
    let frame = match read_frame(stream) {
        Ok(f) => f,
        Err(_) => return,
    };
    let (generation, state) = match decode_exchange(&frame) {
        Ok(ExchangeFrame::Push { generation, state }) => (generation, state),
        // Malformed or non-push frames never touch local state (§7.2).
        _ => {
            let _ = write_frame(stream, &encode_exchange_reject(0, RejectReason::Malformed));
            return;
        }
    };
    // The reply write runs inside the commit window: the serve-side state
    // change lands only once the averaged reply is on the wire and rolls
    // back when the write fails — a cancelled exchange leaves both sides
    // at their pre-round state.
    let served = node.serve_exchange(state, generation, |reply, gen| {
        write_frame(stream, &encode_exchange_reply(gen, reply))
    });
    if let Err(reject) = served {
        let (gen, reason) = match reject {
            ServeReject::Busy => (0, RejectReason::Busy),
            ServeReject::StaleGeneration(g) => (g, RejectReason::StaleGeneration),
            ServeReject::Lineage => (0, RejectReason::Lineage),
            // The reply write itself failed; the socket is gone.
            ServeReject::Cancelled(_) => return,
        };
        let _ = write_frame(stream, &encode_exchange_reject(gen, reason));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(id: usize, values: &[f64]) -> PeerState {
        PeerState::init(id, values, 0.01, 64).unwrap()
    }

    #[test]
    fn in_process_exchange_matches_peer_state_exchange() {
        let mut a1 = state(0, &[1.0, 2.0, 3.0]);
        let mut b1 = state(1, &[10.0, 20.0]);
        let mut a2 = a1.clone();
        let mut b2 = b1.clone();

        let expect_push = peer_state_wire_size(&a1);
        PeerState::exchange(&mut a1, &mut b1).unwrap();
        let expect = expect_push + peer_state_wire_size(&b1);

        let bytes = in_process_exchange(&mut a2, &mut b2).unwrap();
        assert_eq!(bytes, expect);
        assert_eq!(a2.n_tilde.to_bits(), a1.n_tilde.to_bits());
        assert_eq!(b2.q_tilde.to_bits(), b1.q_tilde.to_bits());
        assert_eq!(
            a2.sketch.positive_store().entries(),
            a1.sketch.positive_store().entries()
        );
    }

    #[test]
    fn lineage_error_cancels_in_process_exchange() {
        let mut a = state(0, &[1.0, 2.0]);
        let mut b = PeerState::init(1, &[3.0], 0.05, 64).unwrap();
        let a_before = a.clone();
        let b_before = b.clone();
        assert!(matches!(
            in_process_exchange(&mut a, &mut b),
            Err(TransportError::Lineage(_))
        ));
        assert_eq!(a.n_tilde.to_bits(), a_before.n_tilde.to_bits());
        assert_eq!(
            a.sketch.positive_store().entries(),
            a_before.sketch.positive_store().entries()
        );
        assert_eq!(
            b.sketch.positive_store().entries(),
            b_before.sketch.positive_store().entries()
        );
    }

    #[test]
    fn in_process_transport_refuses_remote_peers() {
        let t = InProcessTransport;
        assert!(!t.supports_remote());
        let mut s = state(0, &[1.0]);
        let before = s.clone();
        let addr: SocketAddr = "127.0.0.1:9".parse().unwrap();
        assert!(matches!(
            t.exchange_remote(&mut s, 1, addr),
            Err(TransportError::Unreachable(_))
        ));
        assert_eq!(s.n_tilde.to_bits(), before.n_tilde.to_bits());
    }

    #[test]
    fn frame_io_roundtrips_and_caps_length() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        assert_eq!(read_frame(&buf[..]).unwrap(), b"hello");

        let mut hostile = Vec::new();
        hostile.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&hostile[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn tcp_transport_requires_nonzero_deadline() {
        assert!(TcpTransport::bind("127.0.0.1:0", Duration::ZERO).is_err());
        assert!(TcpTransport::connect_only(Duration::ZERO).is_err());
        let t = TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        assert!(t.supports_remote());
        assert_eq!(t.listen_addr(), None);
        assert_eq!(t.deadline(), Duration::from_millis(50));
    }

    #[test]
    fn remote_exchange_failure_leaves_initiator_untouched() {
        // Nothing listens on this freshly bound-then-dropped port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let t = TcpTransport::connect_only(Duration::from_millis(100)).unwrap();
        let mut s = state(0, &[1.0, 2.0, 3.0]);
        let before = s.clone();
        let err = t.exchange_remote(&mut s, 1, addr).unwrap_err();
        assert!(matches!(err, TransportError::Io(_)), "{err:?}");
        assert_eq!(s.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(s.q_tilde.to_bits(), before.q_tilde.to_bits());
        assert_eq!(
            s.sketch.positive_store().entries(),
            before.sketch.positive_store().entries()
        );
    }

    #[test]
    fn local_exchange_is_transport_independent() {
        let tcp = TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        let inp = InProcessTransport;
        let (mut a1, mut b1) = (state(0, &[1.0, 5.0]), state(1, &[9.0]));
        let (mut a2, mut b2) = (a1.clone(), b1.clone());
        let x = inp.exchange_local(&mut a1, &mut b1).unwrap();
        let y = tcp.exchange_local(&mut a2, &mut b2).unwrap();
        assert_eq!(x, y);
        assert_eq!(a1.n_tilde.to_bits(), a2.n_tilde.to_bits());
        assert_eq!(
            a1.sketch.positive_store().entries(),
            a2.sketch.positive_store().entries()
        );
    }
}
