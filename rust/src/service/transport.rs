//! The exchange transport layer: how a gossip member reaches a partner.
//!
//! PR 2's [`GossipLoop`](super::GossipLoop) called partner state
//! directly — every member lived in the same process. This module puts
//! the paper's **atomic push–pull exchange** (Algorithm 4) behind a
//! [`Transport`] trait so the same loop drives in-process fleets and
//! fleets of real nodes on different machines:
//!
//! ```text
//!   initiator                              partner
//!   ─────────                              ───────
//!   push  ──[len u32][UDDX push frame]──▶  decode, try-lock state
//!                                          average (Algorithm 4 UPDATE)
//!   pull  ◀─[len u32][UDDX reply frame]──  commit iff the reply is on
//!   adopt reply                            the wire; roll back otherwise
//! ```
//!
//! The normative wire specification — header layout, frame kinds,
//! restart-generation rules, and the cancelled-exchange state machine —
//! lives in `docs/PROTOCOL.md`; this module is its reference
//! implementation.
//!
//! **Failure semantics (§7.2).** Any failure — connect refusal, a missed
//! deadline, a malformed frame, a busy or stale partner — cancels the
//! exchange: the initiator returns an error *without touching its state*,
//! and the serving side commits its averaged state only after the reply
//! write succeeds (rolling back when it does not). Both sides therefore
//! keep their pre-round state, the cancelled-exchange model the paper's
//! churn analysis assumes; the loop counts these in
//! [`GossipRoundReport::failed`](super::GossipRoundReport::failed).
//!
//! One caveat is fundamental (Two Generals): "the reply write succeeded"
//! means the bytes entered the kernel's send buffer, not that the
//! initiator read them. A reply lost *after* that point half-commits the
//! exchange — the server adopted the average, the initiator kept its
//! state — skewing the generation's `q̃` mass by the difference. The
//! window is one in-flight reply against a deadline-long read budget, so
//! it is rare; and the skew is bounded in time, because the next protocol
//! restart (a death re-anchor, or an epoch-carry fallback → new
//! generation, every node reseeds) restores the mass to exactly 1.
//!
//! # Hot-path machinery (PR 4)
//!
//! Three coordinated optimizations take the per-exchange cost from
//! ~1 RTT of connect + an accept poll + a full ~16 KiB frame pair down
//! to a frame pair on a warm socket — and a few dozen bytes of it once
//! the fleet is near convergence:
//!
//! * **Connection reuse** — [`TcpTransport`] keeps a small per-peer pool
//!   of idle connections ([`TcpTransportOptions::pool_connections`],
//!   [`TcpTransportOptions::pool_idle`]). Checkout health-checks the
//!   socket (non-blocking 1-byte peek) and falls back to a fresh connect
//!   on a stale one; a connection that dies mid-exchange *before any
//!   reply byte arrived* is classified [`TransportError::StaleChannel`]
//!   so the caller can retry once on a fresh connect without
//!   double-counting a failure (safe up to the protocol's existing Two
//!   Generals window — see the variant's docs). Read timeouts are
//!   **never** classified stale — a merely slow partner may still serve
//!   the first push, and retrying would double-average (see
//!   `docs/PROTOCOL.md`).
//! * **Poll-driven serving** — one `dudd-serve` thread per node runs all
//!   inbound connections non-blocking (accept + incremental frame
//!   assembly + per-frame deadline + idle eviction), replacing the
//!   thread-per-push accept path. Connections stay open across
//!   exchanges, which is what makes client-side pooling pay off.
//! * **Delta exchanges** — a completed push–pull leaves both partners
//!   with the identical averaged state; both cache it (keyed by
//!   partner) as the *baseline* of their next exchange and ship only
//!   changed buckets
//!   ([`DeltaPayload`](crate::sketch::codec::DeltaPayload)). Baselines
//!   are fingerprinted; any mismatch (eviction, a lost reply) draws a
//!   `BaselineMismatch` reject and an automatic full-frame retry on
//!   the same connection. Under **baseline carry**
//!   ([`TcpTransportOptions::baseline_carry`], the restart-free
//!   default) a baseline survives restart generations: the fingerprint
//!   alone authenticates it, so even a post-reseed state ships as a
//!   delta against the pre-reseed baseline — a required reseed (death
//!   re-anchor, epoch-carry fallback) costs O(changed buckets), not a
//!   full frame per peer. With carry off, the generation is part of
//!   the baseline key and every bump invalidates the cache (PR 5
//!   behavior).
//!
//! **Concurrency model.** Since the per-member locking redesign the
//! serve path contends only on the *member state slots*, not on the
//! round bookkeeping: an initiator stalled in a dead peer's connect
//! deadline ([`Transport::open_remote`] runs **without** any member
//! lock) no longer blocks inbound serves. A node actually mid-push–pull
//! on its own slot still rejects inbound pushes as `Busy` (a §7.2
//! cancellation the initiator retries next round) — that, plus servers
//! only ever *try*-locking, is what keeps cross-node deadlock
//! impossible with blocking sockets. See [`GossipLoop`](super::GossipLoop)'s
//! module for the lock order.
//!
//! Two implementations ship:
//!
//! * [`InProcessTransport`] — PR 2's behavior behind the trait: direct
//!   in-memory exchanges with the codec's byte accounting. Results are
//!   bit-identical to the pre-trait loop (`rust/tests/integration_remote.rs`
//!   proves it against the simulation engine).
//! * [`TcpTransport`] — length-prefixed [`codec`](crate::sketch::codec)
//!   frames over `std::net` with the pool/serve-loop/delta machinery
//!   above, per-exchange deadlines, and generation tags so nodes that
//!   restarted their protocol (new epoch ⇒ reseed) never average with
//!   states from an older restart.
//!
//! Construction normally goes through
//! [`Node::builder()`](super::Node::builder); see the `serve-remote` CLI
//! subcommand for a full loopback fleet.

#![forbid(unsafe_code)]

use super::gossip_loop::{NodeHandle, ServeReject};
use super::membership::MemberTable;
use crate::config::GossipLoopConfig;
use crate::gossip::PeerState;
use crate::obs::{ExchangeSpan, ObsSlot, TransportMetrics};
use crate::sketch::codec::{
    apply_delta, decode_exchange, decode_exchange_traced, delta_payload, delta_wire_size,
    encode_exchange_delta_push_traced, encode_exchange_delta_reply_traced,
    encode_exchange_push_traced, encode_exchange_reject, encode_exchange_reject_traced,
    encode_exchange_reply_traced, encode_join_request, encode_membership_push,
    encode_membership_reply, exchange_frame_fingerprint, peer_state_fingerprint,
    peer_state_wire_size, DeltaPayload, ExchangeFrame, RejectReason,
};
use anyhow::Context;
use std::any::Any;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why an exchange was cancelled (initiator side; §7.2 — the local state
/// is untouched whenever one of these is returned).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// Socket-level failure: connect, read, or write failed or missed
    /// the per-exchange deadline.
    Io(String),
    /// A **reused** (pooled) connection died before a single reply byte
    /// arrived: in every ordinary failure ordering the push was never
    /// served (the peer closed the idle socket, so the push drew a
    /// reset), and the caller may retry once on a fresh connection. The
    /// one ordering where the partner *did* commit — its reply was
    /// written and then destroyed in flight by a host failure or
    /// middlebox reset — is the protocol's existing Two Generals
    /// window, and a retry there produces exactly the same bounded
    /// `q̃`-mass skew as the half-commit it replaces while leaving both
    /// sides *consistent* (see `docs/PROTOCOL.md` §3). Timeouts are
    /// never classified here. The transport has already discarded every
    /// pooled connection to that peer.
    StaleChannel(String),
    /// The partner's bytes failed to decode.
    Codec(String),
    /// The partner is mid-exchange or mid-round; retry next round.
    Busy,
    /// Our restart generation is behind the partner's (the payload): the
    /// loop reseeds and catches up at its next refresh.
    StaleGeneration(u64),
    /// A frame decoded but violated the exchange protocol.
    Protocol(String),
    /// Sketch α₀ lineages differ; these members can never merge.
    Lineage(String),
    /// This transport cannot reach remote members at all.
    Unreachable(SocketAddr),
    /// The partner's membership plane is not enabled (static
    /// address-book fleet) — do not retry membership traffic there.
    NoMembership,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "exchange i/o failed: {e}"),
            TransportError::StaleChannel(e) => {
                write!(f, "pooled connection was stale (retry on fresh): {e}")
            }
            TransportError::Codec(e) => write!(f, "exchange frame invalid: {e}"),
            TransportError::Busy => write!(f, "partner busy (exchange cancelled)"),
            TransportError::StaleGeneration(g) => {
                write!(f, "partner is at restart generation {g}, ours is older")
            }
            TransportError::Protocol(e) => write!(f, "exchange protocol violation: {e}"),
            TransportError::Lineage(e) => write!(f, "alpha0 lineage mismatch: {e}"),
            TransportError::Unreachable(addr) => {
                write!(f, "transport cannot reach remote peer {addr}")
            }
            TransportError::NoMembership => {
                write!(f, "partner has no membership plane enabled")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// An established (but not yet used) connection to a remote peer:
/// created by [`Transport::open_remote`], consumed by
/// [`Transport::exchange_on`]. Opaque so the gossip loop can hold the
/// two phases apart (connect outside the member lock, push–pull inside
/// it) without knowing the transport's socket type.
pub struct RemoteChannel {
    peer: SocketAddr,
    reused: bool,
    inner: Box<dyn Any + Send>,
}

impl RemoteChannel {
    /// Wrap a transport-specific connection object.
    pub fn new(peer: SocketAddr, reused: bool, inner: Box<dyn Any + Send>) -> Self {
        Self {
            peer,
            reused,
            inner,
        }
    }

    /// The peer this channel reaches.
    pub fn peer(&self) -> SocketAddr {
        self.peer
    }

    /// True when the connection came out of a pool rather than a fresh
    /// connect (governs [`TransportError::StaleChannel`] retry rules).
    pub fn reused(&self) -> bool {
        self.reused
    }
}

impl std::fmt::Debug for RemoteChannel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RemoteChannel(peer={}, reused={})",
            self.peer, self.reused
        )
    }
}

/// What a traced remote exchange reports back: the wire bytes it moved
/// plus, on transports that time their phases ([`TcpTransport`] does),
/// the initiator-side [`ExchangeSpan`]. Returned by
/// [`Transport::exchange_traced`].
#[derive(Debug)]
pub struct ExchangeOutcome {
    /// Wire bytes moved (push + reply records, length prefixes
    /// included) — identical to [`Transport::exchange_on`]'s return.
    pub bytes: usize,
    /// The phase-timed span of the exchange, when the transport records
    /// one; `None` on transports without per-exchange instrumentation.
    pub span: Option<ExchangeSpan>,
}

/// How a [`GossipLoop`](super::GossipLoop) executes the atomic push–pull
/// exchange with a partner — in process or across the network.
///
/// Implementations must uphold §7.2's cancelled-exchange contract: when
/// any method returns `Err`, every `&mut PeerState` it received is
/// exactly its pre-call value.
///
/// Remote exchanges run in two phases so the loop can scope its member
/// locks tightly (see [`GossipLoop`](super::GossipLoop)):
/// [`Transport::open_remote`] establishes the connection and is called
/// **without** any member lock held — a dead peer's connect deadline
/// burns here without blocking inbound serves — then
/// [`Transport::exchange_on`] runs the framed push–pull while the caller
/// holds only the initiator's own slot.
pub trait Transport: Send + Sync + std::fmt::Debug + 'static {
    /// Short human name for telemetry and error messages.
    fn name(&self) -> &'static str;

    /// True when this transport can actually reach a socket address. The
    /// loop refuses to start a fleet containing
    /// [`GossipMember::Remote`](super::GossipMember::Remote) members on a
    /// transport that cannot.
    fn supports_remote(&self) -> bool {
        false
    }

    /// Atomic push–pull between two co-located members: both end up with
    /// the averaged state, or neither changes. Returns the wire bytes the
    /// exchange *would* move (push + pull frames, codec byte-exact) for
    /// traffic accounting.
    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError>;

    /// Phase 1 of a remote exchange: produce a connected channel to
    /// `peer` (fresh connect or pool checkout). Called by the loop with
    /// **no member lock held**, so a dead peer's connect deadline never
    /// blocks inbound serves.
    fn open_remote(&self, peer: SocketAddr) -> Result<RemoteChannel, TransportError> {
        Err(TransportError::Unreachable(peer))
    }

    /// Phase 2 of a remote exchange: push `local`'s framed state at
    /// restart generation `generation` over `chan`, pull the averaged
    /// reply, and adopt it. Returns the bytes moved on the wire. On
    /// `Err`, `local` is exactly its pre-call value (cancelled exchange,
    /// §7.2). Called with only the initiator's member slot locked.
    fn exchange_on(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
    ) -> Result<usize, TransportError> {
        let _ = (local, generation);
        Err(TransportError::Unreachable(chan.peer()))
    }

    /// [`Transport::exchange_on`], additionally stamping `trace_id` into
    /// the push frame's header so the serving side echoes it and both
    /// ends log the same correlator (`docs/PROTOCOL.md` §2), and
    /// reporting an [`ExchangeOutcome`] carrying the transport's phase
    /// timings when it records them. The default ignores the id and
    /// wraps [`Transport::exchange_on`], so transports without wire
    /// tracing need not implement anything.
    fn exchange_traced(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
        trace_id: u64,
    ) -> Result<ExchangeOutcome, TransportError> {
        let _ = trace_id;
        let bytes = self.exchange_on(chan, local, generation)?;
        Ok(ExchangeOutcome { bytes, span: None })
    }

    /// Both phases in one call, with a single
    /// [`StaleChannel`](TransportError::StaleChannel) retry. Convenience
    /// for benches and direct API use; the loop calls the phases itself
    /// to scope its locks.
    fn exchange_remote(
        &self,
        local: &mut PeerState,
        generation: u64,
        peer: SocketAddr,
    ) -> Result<usize, TransportError> {
        let chan = self.open_remote(peer)?;
        match self.exchange_on(chan, local, generation) {
            Err(TransportError::StaleChannel(_)) => {
                // The pool was invalidated with the error, so this
                // checkout is a fresh connect.
                let chan = self.open_remote(peer)?;
                self.exchange_on(chan, local, generation)
            }
            r => r,
        }
    }

    /// The address this transport's serve loop listens on, if it has one.
    fn listen_addr(&self) -> Option<SocketAddr> {
        None
    }

    /// One membership anti-entropy conversation with `peer`: push
    /// `local` (tagged with our restart `generation`), pull the
    /// partner's merged table. Returns `(partner table, partner
    /// generation, wire bytes)`. Membership exchanges are idempotent
    /// (table merge), so transports may retry freely on dead pooled
    /// connections. Default: membership is unsupported.
    fn exchange_membership(
        &self,
        peer: SocketAddr,
        generation: u64,
        local: &MemberTable,
    ) -> Result<(MemberTable, u64, usize), TransportError> {
        let _ = (generation, local);
        Err(TransportError::Unreachable(peer))
    }

    /// The `dudd-join` handshake: ask `seed` to assign this node's
    /// listen address a stable member id, returning `(the seed's full
    /// table, the seed's restart generation)` — the joiner starts at
    /// that generation so its first exchanges are not rejected
    /// `StaleGeneration`. Requires a serving transport (the joiner must
    /// itself be reachable). Default: unsupported.
    fn join_remote(&self, seed: SocketAddr) -> Result<(MemberTable, u64), TransportError> {
        Err(TransportError::Unreachable(seed))
    }

    /// Cumulative connection-pool / frame-mix counters, when this
    /// transport keeps any ([`TcpTransport`] does). The gossip loop
    /// diffs consecutive snapshots into the per-round
    /// [`GossipRoundReport::pool`](super::GossipRoundReport::pool)
    /// telemetry so dashboards stop pulling from the transport directly.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }

    /// Install the transport-layer metric handles
    /// ([`TransportMetrics`](crate::obs::TransportMetrics)) so the
    /// transport mirrors its pool, frame-mix, wire-byte, RTT, and reject
    /// counters into the owning node's shared registry. Called once by
    /// [`GossipLoop`](super::GossipLoop) at start, *before* the serve
    /// loop spawns. The default ignores the handles (a transport with
    /// nothing to count); installing twice keeps the first handles.
    fn install_metrics(&self, metrics: Arc<TransportMetrics>) {
        let _ = metrics;
    }

    /// Spawn the serve side (accept + frame-pump loop), if this
    /// transport has one. Called once by
    /// [`GossipLoop`](super::GossipLoop) at start; the returned thread
    /// must watch [`NodeHandle::stopping`] and exit promptly when it
    /// turns true.
    fn spawn_server(&self, node: NodeHandle) -> crate::Result<Option<JoinHandle<()>>> {
        let _ = node;
        Ok(None)
    }
}

/// The shared in-memory exchange: [`PeerState::exchange`] plus PR 2's
/// exact byte accounting (push frame sized before the exchange, pull
/// frame after). Both shipped transports use it for co-located pairs, so
/// local exchanges are bit-identical across transports.
pub fn in_process_exchange(
    a: &mut PeerState,
    b: &mut PeerState,
) -> Result<usize, TransportError> {
    let push = peer_state_wire_size(a);
    // `exchange` validates the lineage before mutating anything, so an
    // error here leaves both states untouched (§7.2).
    PeerState::exchange(a, b).map_err(|e| TransportError::Lineage(e.to_string()))?;
    Ok(push + peer_state_wire_size(b))
}

/// PR 2's in-process behavior behind the [`Transport`] trait: members
/// exchange directly in memory, remote members are unreachable.
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcessTransport;

impl Transport for InProcessTransport {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError> {
        in_process_exchange(a, b)
    }
}

/// Hard cap on a length-prefixed frame. A peer state is ~16 bytes per
/// live bucket plus a fixed header (~16 KiB at the default m = 1024);
/// 4 MiB admits bucket budgets up to ~260k while bounding what a
/// connection flood can pin to `MAX_INFLIGHT_SERVES × 4 MiB` — and the
/// incremental reads below mean even that much is allocated only for
/// bytes a peer actually sends.
pub const MAX_FRAME_BYTES: usize = 4 << 20;

/// Write one `[len u32 LE][frame]` record.
fn write_frame(mut w: impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    w.flush()
}

/// Read one `[len u32 LE][frame]` record, rejecting absurd lengths.
///
/// The buffer grows with the bytes that actually arrive (via
/// [`Read::take`]), so a hostile prefix claiming a huge length pins no
/// memory beyond what the peer really sends within the socket deadline.
fn read_frame(r: impl Read) -> std::io::Result<Vec<u8>> {
    read_frame_tracked(r).map_err(|(_, e)| e)
}

/// [`read_frame`], but reporting whether *any* byte of the record had
/// arrived when an error struck — the discriminator between "stale
/// pooled connection, retry-eligible" (zero bytes plus a
/// connection-death error kind; see
/// [`TransportError::StaleChannel`] for why the residual ambiguity is
/// acceptable) and everything else.
fn read_frame_tracked(mut r: impl Read) -> Result<Vec<u8>, (bool, std::io::Error)> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) => {
                return Err((
                    got > 0,
                    std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed before the reply",
                    ),
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err((got > 0, e)),
        }
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_BYTES {
        return Err((
            true,
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"),
            ),
        ));
    }
    let mut buf = Vec::with_capacity(len.min(64 << 10));
    if let Err(e) = (&mut r).take(len as u64).read_to_end(&mut buf) {
        return Err((true, e));
    }
    if buf.len() != len {
        return Err((
            true,
            std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("frame truncated: got {} of {len} bytes", buf.len()),
            ),
        ));
    }
    Ok(buf)
}

/// Error kinds that mean "the connection itself is dead" — the only
/// failures eligible for the stale-pooled-connection retry. Timeouts are
/// deliberately excluded: a slow partner may still serve the first push,
/// and a retry would average twice.
fn connection_died(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::NotConnected
            | std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::WriteZero
    )
}

/// Tuning knobs of a [`TcpTransport`]: the per-exchange deadline plus
/// the PR 4 hot-path machinery (connection pool, delta exchanges).
///
/// ```
/// use duddsketch::service::TcpTransportOptions;
/// use std::time::Duration;
///
/// let opts = TcpTransportOptions::default();
/// assert_eq!(opts.deadline, Duration::from_millis(1_000));
/// assert_eq!(opts.pool_connections, 2);
/// assert!(opts.delta_exchanges);
/// assert!(opts.baseline_carry);
/// ```
#[derive(Debug, Clone)]
pub struct TcpTransportOptions {
    /// Per-exchange socket deadline (connect, read, and write
    /// individually); an exchange that misses it is cancelled (§7.2).
    pub deadline: Duration,
    /// Idle connections kept per peer; 0 disables reuse (every exchange
    /// pays a fresh connect).
    pub pool_connections: usize,
    /// Pooled connections idle longer than this are discarded at
    /// checkout; the serve loop evicts its side on the same clock, so
    /// keep the two transports of a fleet on one setting.
    pub pool_idle: Duration,
    /// Ship delta frames against the per-peer baseline cache when one
    /// exists (always with automatic full-frame fallback on a baseline
    /// mismatch).
    pub delta_exchanges: bool,
    /// Keep delta baselines valid **across restart generations**. The
    /// fingerprint in every delta frame authenticates the baseline
    /// bit-for-bit, so a baseline cached before a reseed still composes
    /// exactly — required reseeds (a death re-anchor, an epoch-carry
    /// fallback) then ship as deltas against the pre-reseed baseline
    /// instead of paying a full frame per peer (`docs/PROTOCOL.md`
    /// §10). Off, a baseline is only used at the exact generation it
    /// was cached at (the PR 5 rule). Follows
    /// [`GossipLoopConfig::restart_free`](crate::config::GossipLoopConfig::restart_free)
    /// in [`TcpTransportOptions::from_gossip`].
    pub baseline_carry: bool,
}

impl Default for TcpTransportOptions {
    fn default() -> Self {
        Self {
            deadline: Duration::from_millis(1_000),
            pool_connections: 2,
            pool_idle: Duration::from_millis(30_000),
            delta_exchanges: true,
            baseline_carry: true,
        }
    }
}

impl TcpTransportOptions {
    /// Derive the options from the loop configuration's validated keys
    /// (`gossip_exchange_deadline_ms`, `gossip_pool_connections`,
    /// `gossip_pool_idle_ms`, `gossip_delta_exchanges`,
    /// `gossip_restart_free`).
    pub fn from_gossip(cfg: &GossipLoopConfig) -> Self {
        Self {
            deadline: Duration::from_millis(cfg.exchange_deadline_ms),
            pool_connections: cfg.pool_connections,
            pool_idle: Duration::from_millis(cfg.pool_idle_ms),
            delta_exchanges: cfg.delta_exchanges,
            baseline_carry: cfg.restart_free,
        }
    }

    fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            !self.deadline.is_zero(),
            "gossip_exchange_deadline_ms must be >= 1 (a zero deadline \
             cancels every remote exchange)"
        );
        anyhow::ensure!(
            !self.pool_idle.is_zero(),
            "gossip_pool_idle_ms must be >= 1 (a zero idle timeout \
             discards every pooled connection)"
        );
        Ok(())
    }
}

/// Counters of the connection pool's behavior (monotonic since
/// construction). `failed` in the round report only counts *unrecovered*
/// exchanges; these counters are where the recovery work shows up.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Fresh TCP connects performed.
    pub fresh_connects: usize,
    /// Exchanges that ran on a pooled connection.
    pub reused: usize,
    /// Pooled connections found dead (at checkout health-check or
    /// mid-exchange) and discarded.
    pub stale_discarded: usize,
    /// Pooled connections discarded for exceeding the idle timeout.
    pub expired: usize,
    /// Push frames shipped as deltas against a shared baseline (the
    /// delta-hit half of the hit rate).
    pub delta_pushes: usize,
    /// Push frames shipped full — no usable baseline, a delta that
    /// would not save bytes, or the fallback after a
    /// `BaselineMismatch`.
    pub full_pushes: usize,
}

impl PoolStats {
    /// The counter movement since `prev` (saturating, so a transport
    /// swap mid-run degrades to zeros instead of wrapping) — how the
    /// gossip loop turns the cumulative counters into per-round
    /// telemetry.
    pub fn delta_since(&self, prev: PoolStats) -> PoolStats {
        PoolStats {
            fresh_connects: self.fresh_connects.saturating_sub(prev.fresh_connects),
            reused: self.reused.saturating_sub(prev.reused),
            stale_discarded: self.stale_discarded.saturating_sub(prev.stale_discarded),
            expired: self.expired.saturating_sub(prev.expired),
            delta_pushes: self.delta_pushes.saturating_sub(prev.delta_pushes),
            full_pushes: self.full_pushes.saturating_sub(prev.full_pushes),
        }
    }
}

#[derive(Debug, Default)]
struct TransportStats {
    fresh: AtomicUsize,
    reused: AtomicUsize,
    stale: AtomicUsize,
    expired: AtomicUsize,
    delta_pushes: AtomicUsize,
    full_pushes: AtomicUsize,
}

/// One idle pooled connection.
#[derive(Debug)]
struct PooledConn {
    stream: TcpStream,
    idle_since: Instant,
}

/// Non-blocking 1-byte peek: `WouldBlock` means alive-and-quiet, data or
/// EOF or any other error means the connection cannot carry a fresh
/// exchange (closed, reset, or protocol residue).
fn probe_alive(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut b = [0u8; 1];
    let alive = matches!(stream.peek(&mut b),
        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock);
    alive && stream.set_nonblocking(false).is_ok()
}

/// Bounded per-peer pool of idle connections.
#[derive(Debug, Default)]
struct Pool {
    conns: Mutex<HashMap<SocketAddr, Vec<PooledConn>>>,
}

impl Pool {
    fn lock_conns(&self) -> MutexGuard<'_, HashMap<SocketAddr, Vec<PooledConn>>> {
        self.conns.lock().expect("transport pool poisoned")
    }

    /// Take a healthy pooled connection, discarding expired/dead ones.
    ///
    /// `probe_alive` is a socket operation, so the candidate list is
    /// drained under the lock and probed after releasing it — a peer
    /// with an unresponsive socket must not stall every other caller
    /// of the pool.
    fn checkout(
        &self,
        peer: SocketAddr,
        idle: Duration,
        stats: &TransportStats,
        metrics: Option<&Arc<TransportMetrics>>,
    ) -> Option<TcpStream> {
        let mut candidates = self.lock_conns().remove(&peer)?;
        let mut found = None;
        while let Some(c) = candidates.pop() {
            if c.idle_since.elapsed() > idle {
                stats.expired.fetch_add(1, Ordering::Relaxed);
                if let Some(m) = metrics {
                    m.pool_expired.inc();
                }
                continue;
            }
            if probe_alive(&c.stream) {
                stats.reused.fetch_add(1, Ordering::Relaxed);
                if let Some(m) = metrics {
                    m.pool_reused.inc();
                }
                found = Some(c.stream);
                break;
            }
            stats.stale.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = metrics {
                m.pool_stale_discarded.inc();
            }
        }
        // Unprobed candidates go back at the front of the LIFO list;
        // anything checked in while the lock was released stays newer
        // and is reused first.
        if !candidates.is_empty() {
            let mut map = self.lock_conns();
            let list = map.entry(peer).or_default();
            candidates.append(list);
            *list = candidates;
        }
        found
    }

    /// Return a connection after a successful exchange (dropped when the
    /// per-peer cap is reached or pooling is disabled).
    fn checkin(&self, peer: SocketAddr, stream: TcpStream, cap: usize) {
        if cap == 0 {
            return;
        }
        let mut map = self.lock_conns();
        let list = map.entry(peer).or_default();
        if list.len() < cap {
            list.push(PooledConn {
                stream,
                idle_since: Instant::now(),
            });
        }
    }

    /// Drop every pooled connection to `peer` (called when one proved
    /// stale mid-exchange: the peer likely restarted, so its siblings
    /// are dead too).
    fn invalidate(
        &self,
        peer: SocketAddr,
        stats: &TransportStats,
        metrics: Option<&Arc<TransportMetrics>>,
    ) {
        let mut map = self.lock_conns();
        if let Some(list) = map.remove(&peer) {
            stats.stale.fetch_add(list.len(), Ordering::Relaxed);
            if let Some(m) = metrics {
                m.pool_stale_discarded.add(list.len() as u64);
            }
        }
    }
}

/// The last mutually-known state of an exchange pair: what both sides
/// hold after a completed push–pull, cached so the next exchange can
/// ship a delta. `fingerprint` (supplied by the caller, hashed off the
/// full reply frame's bytes when one exists, so the steady state pays
/// no ~16 KiB re-encode) is what authenticates the baseline bit-for-
/// bit; under baseline carry it is the *only* validity check, so
/// baselines compose across restart generations. With carry off,
/// `generation` is additionally part of the identity and a protocol
/// restart invalidates every baseline without any bookkeeping.
/// `stored_at` drives LRU eviction on the serve side.
#[derive(Debug, Clone)]
struct Baseline {
    generation: u64,
    fingerprint: u64,
    state: PeerState,
    stored_at: Instant,
}

impl Baseline {
    fn of(state: &PeerState, generation: u64, fingerprint: u64) -> Self {
        Self {
            generation,
            fingerprint,
            state: state.clone(),
            stored_at: Instant::now(),
        }
    }
}

/// Serve-side baseline cache, keyed by initiator peer id. Shared between
/// the transport (initiator half lives in its own map, keyed by address)
/// and the serve loop thread.
type ServeBaselines = Arc<Mutex<HashMap<u64, Baseline>>>;

fn lock_serve_baselines(cache: &ServeBaselines) -> MutexGuard<'_, HashMap<u64, Baseline>> {
    cache.lock().expect("serve baseline cache poisoned")
}

/// Cap on serve-side cached baselines (hostile peers can mint ids; each
/// baseline holds a full peer state).
const MAX_SERVE_BASELINES: usize = 256;

/// Length-prefixed exchange frames over `std::net` TCP.
///
/// Bind one per serving node ([`TcpTransport::bind_with`], address book
/// built *before* any loop starts so nodes can list each other as
/// [`GossipMember::Remote`](super::GossipMember::Remote)); pure clients
/// use [`TcpTransport::connect_only_with`]. Every socket operation
/// carries the per-exchange deadline; a missed deadline cancels the
/// exchange with both sides keeping their pre-round state (§7.2).
///
/// # Invariants (pool / baselines)
///
/// * A connection enters the pool only after a fully completed exchange,
///   so a pooled socket never carries half a conversation.
/// * A pooled connection that dies before any reply byte surfaces as
///   [`TransportError::StaleChannel`] **and** empties that peer's pool —
///   the immediate retry is guaranteed a fresh connect.
/// * A baseline is cached only from a committed exchange and read back
///   at any generation under baseline carry (at the same restart
///   generation otherwise); the fingerprint in every delta frame
///   catches any disagreement (e.g. a reply lost after the server
///   committed) and downgrades that exchange to full frames.
#[derive(Debug)]
pub struct TcpTransport {
    /// Taken (once) by `spawn_server` when the loop starts.
    listener: Mutex<Option<TcpListener>>,
    local_addr: Option<SocketAddr>,
    opts: TcpTransportOptions,
    pool: Pool,
    stats: TransportStats,
    /// Initiator-side baselines, one per partner address.
    baselines: Mutex<HashMap<SocketAddr, Baseline>>,
    /// Serve-side baselines, one per initiator id (shared with the serve
    /// loop thread).
    serve_baselines: ServeBaselines,
    /// Registry-backed mirrors of [`TransportStats`], installed (once)
    /// by the owning node via [`Transport::install_metrics`]. Empty on a
    /// transport used outside a node; every hot-path site checks the
    /// slot with a lock-free read.
    metrics: ObsSlot<TransportMetrics>,
}

impl TcpTransport {
    fn lock_baselines(&self) -> MutexGuard<'_, HashMap<SocketAddr, Baseline>> {
        self.baselines.lock().expect("transport baseline cache poisoned")
    }

    fn lock_listener(&self) -> MutexGuard<'_, Option<TcpListener>> {
        self.listener.lock().expect("transport listener mutex poisoned")
    }

    /// Bind the serve side on `addr` (use port 0 for an OS-assigned
    /// loopback port) with full options.
    pub fn bind_with(addr: impl ToSocketAddrs, opts: TcpTransportOptions) -> crate::Result<Self> {
        opts.validate()?;
        let listener = TcpListener::bind(addr).context("binding gossip transport listener")?;
        let local_addr = listener
            .local_addr()
            .context("resolving transport listen address")?;
        Ok(Self {
            listener: Mutex::new(Some(listener)),
            local_addr: Some(local_addr),
            opts,
            pool: Pool::default(),
            stats: TransportStats::default(),
            baselines: Mutex::new(HashMap::new()),
            serve_baselines: Arc::new(Mutex::new(HashMap::new())),
            metrics: ObsSlot::new(),
        })
    }

    /// [`TcpTransport::bind_with`] keeping every option at its default
    /// except the deadline.
    pub fn bind(addr: impl ToSocketAddrs, deadline: Duration) -> crate::Result<Self> {
        Self::bind_with(
            addr,
            TcpTransportOptions {
                deadline,
                ..TcpTransportOptions::default()
            },
        )
    }

    /// A client-only transport with full options: can initiate exchanges
    /// with remote nodes but serves no inbound ones (no serve loop).
    pub fn connect_only_with(opts: TcpTransportOptions) -> crate::Result<Self> {
        opts.validate()?;
        Ok(Self {
            listener: Mutex::new(None),
            local_addr: None,
            opts,
            pool: Pool::default(),
            stats: TransportStats::default(),
            baselines: Mutex::new(HashMap::new()),
            serve_baselines: Arc::new(Mutex::new(HashMap::new())),
            metrics: ObsSlot::new(),
        })
    }

    /// [`TcpTransport::connect_only_with`] keeping every option at its
    /// default except the deadline.
    pub fn connect_only(deadline: Duration) -> crate::Result<Self> {
        Self::connect_only_with(TcpTransportOptions {
            deadline,
            ..TcpTransportOptions::default()
        })
    }

    /// The per-exchange deadline.
    pub fn deadline(&self) -> Duration {
        self.opts.deadline
    }

    /// The transport's full option set.
    pub fn options(&self) -> &TcpTransportOptions {
        &self.opts
    }

    /// Snapshot of the connection-pool and frame-mix counters.
    pub fn pool_stats(&self) -> PoolStats {
        PoolStats {
            fresh_connects: self.stats.fresh.load(Ordering::Relaxed),
            reused: self.stats.reused.load(Ordering::Relaxed),
            stale_discarded: self.stats.stale.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            delta_pushes: self.stats.delta_pushes.load(Ordering::Relaxed),
            full_pushes: self.stats.full_pushes.load(Ordering::Relaxed),
        }
    }

    /// Idle connections currently pooled for `peer` (observability).
    pub fn pooled_connections(&self, peer: SocketAddr) -> usize {
        self.pool.lock_conns().get(&peer).map_or(0, Vec::len)
    }

    /// Classify a mid-exchange i/o failure, invalidating the pool when
    /// the connection qualifies for a stale retry.
    fn channel_failure(
        &self,
        peer: SocketAddr,
        reused: bool,
        phase: &str,
        reply_started: bool,
        e: std::io::Error,
    ) -> TransportError {
        if reused && !reply_started && connection_died(&e) {
            self.pool.invalidate(peer, &self.stats, self.metrics.get());
            self.stats.stale.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = self.metrics.get() {
                m.pool_stale_discarded.inc();
            }
            TransportError::StaleChannel(format!("{phase}: {e}"))
        } else {
            TransportError::Io(format!("{phase}: {e}"))
        }
    }

    /// Validate and adopt a reply, updating the pair baseline.
    /// `fingerprint` is the adopted state's peer-state fingerprint —
    /// hashed off the full reply frame when one exists, computed from
    /// the reconstructed state for delta replies.
    fn adopt_reply(
        &self,
        peer: SocketAddr,
        local: &mut PeerState,
        generation: u64,
        gen: u64,
        state: PeerState,
        fingerprint: u64,
    ) -> Result<(), TransportError> {
        if gen != generation {
            return Err(TransportError::Protocol(format!(
                "reply at generation {gen}, push was {generation}"
            )));
        }
        if state.id != local.id {
            return Err(TransportError::Protocol(format!(
                "reply carries peer id {}, expected {}",
                state.id, local.id
            )));
        }
        if !state.sketch.mapping().same_lineage(local.sketch.mapping()) {
            return Err(TransportError::Lineage(format!(
                "reply alpha0 {} vs local {}",
                state.sketch.mapping().alpha0(),
                local.sketch.mapping().alpha0()
            )));
        }
        if self.opts.delta_exchanges {
            self.lock_baselines()
                .insert(peer, Baseline::of(&state, generation, fingerprint));
        }
        // Commit point: the partner already committed when its reply
        // write succeeded; adopting completes the exchange.
        *local = state;
        Ok(())
    }

    /// Unwrap a [`RemoteChannel`] back into its TCP stream with the
    /// per-exchange deadlines armed.
    fn channel_stream(
        chan: RemoteChannel,
        deadline: Duration,
    ) -> Result<TcpStream, TransportError> {
        let stream = *chan.inner.downcast::<TcpStream>().map_err(|_| {
            TransportError::Protocol("channel was opened by a different transport".into())
        })?;
        let io = |e: std::io::Error| TransportError::Io(e.to_string());
        stream.set_read_timeout(Some(deadline)).map_err(io)?;
        stream.set_write_timeout(Some(deadline)).map_err(io)?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// One membership push–pull (the body of
    /// [`Transport::exchange_membership`]); classifies a dead pooled
    /// connection as [`TransportError::StaleChannel`] so the caller can
    /// retry.
    fn membership_conversation(
        &self,
        peer: SocketAddr,
        generation: u64,
        local: &MemberTable,
    ) -> Result<(MemberTable, u64, usize), TransportError> {
        let chan = self.open_remote(peer)?;
        let reused = chan.reused();
        let stream = Self::channel_stream(chan, self.opts.deadline)?;
        let push = encode_membership_push(generation, local);
        if let Err(e) = write_frame(&stream, &push) {
            return Err(self.channel_failure(peer, reused, "membership push", false, e));
        }
        let reply = match read_frame_tracked(&stream) {
            Ok(r) => r,
            Err((started, e)) => {
                return Err(self.channel_failure(
                    peer,
                    reused,
                    "membership reply",
                    started,
                    e,
                ))
            }
        };
        let wire = 8 + push.len() + reply.len();
        match decode_exchange(&reply).map_err(|e| TransportError::Codec(e.to_string()))? {
            ExchangeFrame::MembershipReply { generation, table } => {
                self.pool.checkin(peer, stream, self.opts.pool_connections);
                Ok((table, generation, wire))
            }
            ExchangeFrame::Reject {
                reason: RejectReason::NoMembership,
                ..
            } => {
                self.count_reject(RejectReason::NoMembership);
                // The framing is intact; keep the connection warm.
                self.pool.checkin(peer, stream, self.opts.pool_connections);
                Err(TransportError::NoMembership)
            }
            other => Err(TransportError::Protocol(format!(
                "partner answered a membership push with {other:?}"
            ))),
        }
    }

    /// The pair baseline for `peer`, if cached and usable: any cached
    /// baseline under baseline carry (the frame fingerprint
    /// authenticates it regardless of the generation it was cached
    /// at), or one cached at exactly `generation` otherwise.
    fn baseline_for(&self, peer: SocketAddr, generation: u64) -> Option<Baseline> {
        if !self.opts.delta_exchanges {
            return None;
        }
        self.lock_baselines()
            .get(&peer)
            .filter(|b| self.opts.baseline_carry || b.generation == generation)
            .cloned()
    }

    /// Book a completed initiated exchange on the installed metrics:
    /// the socket bytes it moved and its round-trip time (`start` is
    /// taken before the push write, so the RTT spans push write through
    /// reply adoption, a full-frame retry included).
    fn finish_exchange(&self, start: Instant, wire: usize) -> Result<usize, TransportError> {
        if let Some(m) = self.metrics.get() {
            m.wire_bytes.add(wire as u64);
            m.exchange_rtt.observe(start.elapsed().as_secs_f64());
        }
        Ok(wire)
    }

    /// Count a reject frame received as an initiator.
    fn count_reject(&self, reason: RejectReason) {
        if let Some(m) = self.metrics.get() {
            m.rejects.reason(reason).inc();
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError> {
        // Co-located members short-circuit the socket: byte-identical to
        // the in-process transport.
        in_process_exchange(a, b)
    }

    fn open_remote(&self, peer: SocketAddr) -> Result<RemoteChannel, TransportError> {
        if self.opts.pool_connections > 0 {
            if let Some(stream) =
                self.pool
                    .checkout(peer, self.opts.pool_idle, &self.stats, self.metrics.get())
            {
                return Ok(RemoteChannel::new(peer, true, Box::new(stream)));
            }
        }
        let io = |e: std::io::Error| TransportError::Io(e.to_string());
        let stream = TcpStream::connect_timeout(&peer, self.opts.deadline).map_err(io)?;
        self.stats.fresh.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.metrics.get() {
            m.pool_fresh_connects.inc();
        }
        Ok(RemoteChannel::new(peer, false, Box::new(stream)))
    }

    fn exchange_on(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
    ) -> Result<usize, TransportError> {
        // Untraced entry point: trace id 0 ("no trace", PROTOCOL.md §2)
        // on the wire, span discarded.
        self.exchange_traced(chan, local, generation, 0)
            .map(|o| o.bytes)
    }

    fn exchange_traced(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
        trace_id: u64,
    ) -> Result<ExchangeOutcome, TransportError> {
        let peer = chan.peer();
        let reused = chan.reused();
        let start = Instant::now();
        let stream = Self::channel_stream(chan, self.opts.deadline)?;
        let io = |e: std::io::Error| TransportError::Io(e.to_string());
        // Span constructor for the success paths; failures return `Err`
        // and the caller synthesizes the failure span. `connect` is left
        // zero — the channel was opened before this call, and the loop
        // fills in the time it measured around `open_remote`.
        let make_span = |kind: &'static str,
                         bytes: usize,
                         push: Duration,
                         reply: Duration,
                         commit: Duration| ExchangeSpan {
            trace_id,
            initiator: true,
            peer: peer.to_string(),
            generation,
            kind,
            bytes,
            outcome: "ok",
            connect: Duration::ZERO,
            push,
            reply,
            commit,
        };

        // Prefer a delta push when the pair baseline exists at this
        // generation and the delta actually saves bytes.
        let baseline = self.baseline_for(peer, generation);
        let push_delta: Option<DeltaPayload> = baseline.as_ref().and_then(|b| {
            delta_payload(&b.state, b.fingerprint, local)
                .filter(|d| delta_wire_size(d) < 22 + peer_state_wire_size(local))
        });
        let push = match &push_delta {
            Some(d) => {
                self.stats.delta_pushes.fetch_add(1, Ordering::Relaxed);
                if let Some(m) = self.metrics.get() {
                    m.frames_delta.inc();
                }
                encode_exchange_delta_push_traced(generation, trace_id, d)
            }
            None => {
                self.stats.full_pushes.fetch_add(1, Ordering::Relaxed);
                if let Some(m) = self.metrics.get() {
                    m.frames_full.inc();
                }
                encode_exchange_push_traced(generation, trace_id, local)
            }
        };
        let mut kind: &'static str = if push_delta.is_some() { "delta" } else { "full" };
        let push_started = Instant::now();
        if let Err(e) = write_frame(&stream, &push) {
            return Err(self.channel_failure(peer, reused, "push write", false, e));
        }
        let mut phase_push = push_started.elapsed();
        let read_started = Instant::now();
        let reply = match read_frame_tracked(&stream) {
            Ok(r) => r,
            Err((started, e)) => {
                return Err(self.channel_failure(peer, reused, "reply read", started, e))
            }
        };
        let mut phase_reply = read_started.elapsed();
        let mut wire = 8 + push.len() + reply.len();
        // The echoed id is diagnostic only (§2): a reply is never
        // rejected over it.
        let (decoded, _echoed) =
            decode_exchange_traced(&reply).map_err(|e| TransportError::Codec(e.to_string()))?;
        match decoded {
            ExchangeFrame::Reply {
                generation: gen,
                state,
            } => {
                let commit_started = Instant::now();
                let fp = exchange_frame_fingerprint(&reply)
                    .expect("a decoded reply frame is longer than its header");
                self.adopt_reply(peer, local, generation, gen, state, fp)?;
                self.pool.checkin(peer, stream, self.opts.pool_connections);
                let bytes = self.finish_exchange(start, wire)?;
                let span = make_span(kind, bytes, phase_push, phase_reply, commit_started.elapsed());
                Ok(ExchangeOutcome {
                    bytes,
                    span: Some(span),
                })
            }
            ExchangeFrame::DeltaReply {
                generation: gen,
                delta,
            } => {
                let commit_started = Instant::now();
                let Some(b) = baseline else {
                    return Err(TransportError::Protocol(
                        "delta reply to a full push (no shared baseline)".into(),
                    ));
                };
                if delta.baseline_fingerprint != b.fingerprint {
                    return Err(TransportError::Protocol(
                        "delta reply names a baseline we do not hold".into(),
                    ));
                }
                let state =
                    apply_delta(&b.state, &delta).map_err(|e| TransportError::Codec(e.to_string()))?;
                let fp = peer_state_fingerprint(&state);
                self.adopt_reply(peer, local, generation, gen, state, fp)?;
                self.pool.checkin(peer, stream, self.opts.pool_connections);
                let bytes = self.finish_exchange(start, wire)?;
                let span = make_span(kind, bytes, phase_push, phase_reply, commit_started.elapsed());
                Ok(ExchangeOutcome {
                    bytes,
                    span: Some(span),
                })
            }
            ExchangeFrame::Reject {
                reason: RejectReason::BaselineMismatch,
                ..
            } if push_delta.is_some() => {
                // The partner lost (or never had) our baseline: drop ours
                // and retry with a full frame on this same connection.
                self.count_reject(RejectReason::BaselineMismatch);
                self.lock_baselines().remove(&peer);
                self.stats.full_pushes.fetch_add(1, Ordering::Relaxed);
                if let Some(m) = self.metrics.get() {
                    m.frames_full.inc();
                }
                kind = "full";
                let push = encode_exchange_push_traced(generation, trace_id, local);
                let retry_write = Instant::now();
                write_frame(&stream, &push).map_err(io)?;
                phase_push += retry_write.elapsed();
                let retry_read = Instant::now();
                let reply = read_frame(&stream).map_err(io)?;
                phase_reply += retry_read.elapsed();
                wire += 8 + push.len() + reply.len();
                match decode_exchange(&reply)
                    .map_err(|e| TransportError::Codec(e.to_string()))?
                {
                    ExchangeFrame::Reply {
                        generation: gen,
                        state,
                    } => {
                        let commit_started = Instant::now();
                        let fp = exchange_frame_fingerprint(&reply)
                            .expect("a decoded reply frame is longer than its header");
                        self.adopt_reply(peer, local, generation, gen, state, fp)?;
                        self.pool.checkin(peer, stream, self.opts.pool_connections);
                        let bytes = self.finish_exchange(start, wire)?;
                        let span = make_span(
                            kind,
                            bytes,
                            phase_push,
                            phase_reply,
                            commit_started.elapsed(),
                        );
                        Ok(ExchangeOutcome {
                            bytes,
                            span: Some(span),
                        })
                    }
                    ExchangeFrame::Reject {
                        generation: gen,
                        reason,
                    } => {
                        // Framing is intact after a reject: keep the
                        // connection warm for the next round.
                        self.count_reject(reason);
                        if matches!(
                            reason,
                            RejectReason::Busy | RejectReason::StaleGeneration
                        ) {
                            self.pool.checkin(peer, stream, self.opts.pool_connections);
                        }
                        Err(reject_error(gen, reason))
                    }
                    _ => Err(TransportError::Protocol(
                        "partner answered the full retry with a non-reply frame".into(),
                    )),
                }
            }
            ExchangeFrame::Reject {
                generation: gen,
                reason,
            } => {
                // Busy and stale-generation rejects are routine round
                // collisions on an intact connection (the server keeps
                // its side open, PROTOCOL.md §3) — pool it so the retry
                // next round skips the reconnect.
                self.count_reject(reason);
                if matches!(reason, RejectReason::Busy | RejectReason::StaleGeneration) {
                    self.pool.checkin(peer, stream, self.opts.pool_connections);
                }
                Err(reject_error(gen, reason))
            }
            ExchangeFrame::Push { .. } | ExchangeFrame::DeltaPush { .. } => Err(
                TransportError::Protocol("partner replied with a push frame".into()),
            ),
            ExchangeFrame::MembershipPush { .. }
            | ExchangeFrame::MembershipReply { .. }
            | ExchangeFrame::JoinRequest { .. } => Err(TransportError::Protocol(
                "partner answered a data push with a membership frame".into(),
            )),
        }
    }

    fn listen_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    fn exchange_membership(
        &self,
        peer: SocketAddr,
        generation: u64,
        local: &MemberTable,
    ) -> Result<(MemberTable, u64, usize), TransportError> {
        // A table merge is idempotent, so (unlike the data exchange) a
        // dead pooled connection is always safe to retry on a fresh one.
        match self.membership_conversation(peer, generation, local) {
            Err(TransportError::StaleChannel(_)) => {
                self.membership_conversation(peer, generation, local)
            }
            r => r,
        }
    }

    fn join_remote(&self, seed: SocketAddr) -> Result<(MemberTable, u64), TransportError> {
        let addr = self.local_addr.ok_or_else(|| {
            TransportError::Protocol(
                "join requires a serving transport (the joiner must be \
                 reachable) — bind the transport before joining"
                    .into(),
            )
        })?;
        let chan = self.open_remote(seed)?;
        let stream = Self::channel_stream(chan, self.opts.deadline)?;
        let io = |e: std::io::Error| TransportError::Io(e.to_string());
        write_frame(&stream, &encode_join_request(0, addr)).map_err(io)?;
        let reply = read_frame(&stream).map_err(io)?;
        match decode_exchange(&reply).map_err(|e| TransportError::Codec(e.to_string()))? {
            ExchangeFrame::MembershipReply { table, generation } => {
                self.pool.checkin(seed, stream, self.opts.pool_connections);
                Ok((table, generation))
            }
            ExchangeFrame::Reject {
                reason: RejectReason::NoMembership,
                ..
            } => {
                self.count_reject(RejectReason::NoMembership);
                Err(TransportError::NoMembership)
            }
            other => Err(TransportError::Protocol(format!(
                "seed answered the join with a non-membership frame: {other:?}"
            ))),
        }
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(TcpTransport::pool_stats(self))
    }

    fn install_metrics(&self, metrics: Arc<TransportMetrics>) {
        self.metrics.install(metrics);
    }

    fn spawn_server(&self, node: NodeHandle) -> crate::Result<Option<JoinHandle<()>>> {
        let listener = self.lock_listener().take();
        let Some(listener) = listener else {
            return Ok(None);
        };
        listener
            .set_nonblocking(true)
            .context("switching the serve loop to non-blocking")?;
        let params = ServeParams {
            deadline: self.opts.deadline,
            idle: self.opts.pool_idle,
            delta: self.opts.delta_exchanges,
            carry: self.opts.baseline_carry,
            baselines: self.serve_baselines.clone(),
            // The loop installs metrics before spawning the server, so
            // an instrumented node's serve side always sees them.
            metrics: self.metrics.get().cloned(),
        };
        let handle = std::thread::Builder::new()
            .name("dudd-serve".into())
            .spawn(move || serve_loop(&listener, &node, &params))
            .context("spawning transport serve loop")?;
        Ok(Some(handle))
    }
}

/// Map a reject frame to the initiator-side error.
fn reject_error(gen: u64, reason: RejectReason) -> TransportError {
    match reason {
        RejectReason::Busy => TransportError::Busy,
        RejectReason::StaleGeneration => TransportError::StaleGeneration(gen),
        RejectReason::Lineage => {
            TransportError::Lineage("partner rejected: alpha0 lineage mismatch".into())
        }
        RejectReason::Malformed => {
            TransportError::Protocol("partner rejected the push frame as malformed".into())
        }
        RejectReason::BaselineMismatch => TransportError::Protocol(
            "partner rejected a full frame with a baseline mismatch".into(),
        ),
        RejectReason::NoMembership => TransportError::NoMembership,
    }
}

/// Cap on concurrently held inbound connections. Since connections now
/// persist across exchanges, hitting the cap evicts the longest-idle
/// connection (its owner recovers through the stale-pool retry) rather
/// than refusing the newcomer, so the cap bounds memory
/// (`MAX_INFLIGHT_SERVES × MAX_FRAME_BYTES` worst case against a flood
/// of senders that actually ship bytes) without hard-limiting fleet
/// size. Only when every held connection is mid-frame — genuine
/// overload — is the new connection dropped (the initiator counts a
/// cancelled exchange and retries next round, §7.2).
const MAX_INFLIGHT_SERVES: usize = 64;

/// Serve-loop configuration captured at spawn.
struct ServeParams {
    deadline: Duration,
    idle: Duration,
    delta: bool,
    /// Serve-side mirror of [`TcpTransportOptions::baseline_carry`]:
    /// accept delta pushes against a baseline cached at any generation
    /// (the fingerprint authenticates it), not just the current one.
    carry: bool,
    baselines: ServeBaselines,
    /// Installed metric handles, if the owning node registered any
    /// before the serve loop spawned.
    metrics: Option<Arc<TransportMetrics>>,
}

/// Count a reject frame written while serving, if metrics are installed.
fn count_serve_reject(params: &ServeParams, reason: RejectReason) {
    if let Some(m) = &params.metrics {
        m.serve_rejects.reason(reason).inc();
    }
}

/// One inbound connection's frame-assembly state.
struct ServeConn {
    stream: TcpStream,
    /// Raw received bytes of the record being assembled
    /// (`[len u32][frame]`).
    buf: Vec<u8>,
    /// When the current partial record started arriving.
    started: Instant,
    /// When the last full frame was served (idle eviction clock).
    last_frame: Instant,
}

enum ConnState {
    /// Keep polling; the flag reports whether this pump made progress.
    Keep(bool),
    Drop,
}

/// The poll-driven serve side: one thread accepts and pumps every
/// inbound connection non-blocking (≤2 ms latency to shut down or to
/// notice new bytes), assembling length-prefixed records incrementally
/// and serving each completed frame. Connections persist across
/// exchanges — the client side pools them — and are evicted on a
/// per-frame deadline (partial record) or the idle timeout (no record).
/// No handler threads: thread churn is zero regardless of fleet size.
fn serve_loop(listener: &TcpListener, node: &NodeHandle, params: &ServeParams) {
    let mut conns: Vec<ServeConn> = Vec::new();
    while !node.stopping() {
        let mut progress = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if conns.len() >= MAX_INFLIGHT_SERVES && !evict_idlest(&mut conns) {
                        drop(stream); // genuine overload: cancelled exchange (§7.2)
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_write_timeout(Some(params.deadline));
                    let now = Instant::now();
                    conns.push(ServeConn {
                        stream,
                        buf: Vec::new(),
                        started: now,
                        last_frame: now,
                    });
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        let mut i = 0;
        while i < conns.len() {
            match pump_conn(&mut conns[i], node, params) {
                ConnState::Keep(made) => {
                    progress |= made;
                    i += 1;
                }
                ConnState::Drop => {
                    conns.swap_remove(i);
                    progress = true;
                }
            }
        }
        if !progress {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Make room for a new inbound connection by evicting the one idle the
/// longest (empty buffer — not mid-frame). Returns false when every
/// held connection is mid-frame, i.e. the node is genuinely overloaded.
fn evict_idlest(conns: &mut Vec<ServeConn>) -> bool {
    let victim = conns
        .iter()
        .enumerate()
        .filter(|(_, c)| c.buf.is_empty())
        .max_by_key(|(_, c)| c.last_frame.elapsed())
        .map(|(i, _)| i);
    match victim {
        Some(i) => {
            conns.swap_remove(i);
            true
        }
        None => false,
    }
}

/// Record-assembly state of a connection's buffer: `Err` for a hostile
/// length, `Ok(Some(total_record_len))` once a full record is buffered.
fn buffered_record(buf: &[u8]) -> Result<Option<usize>, ()> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().expect("4-byte slice")) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(());
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(4 + len))
}

/// Advance one connection: drain available bytes, enforce deadlines,
/// serve at most one completed frame.
fn pump_conn(c: &mut ServeConn, node: &NodeHandle, params: &ServeParams) -> ConnState {
    let was_empty = c.buf.is_empty();
    let mut chunk = [0u8; 4096];
    let mut read_any = false;
    loop {
        match buffered_record(&c.buf) {
            Err(()) => return ConnState::Drop,
            Ok(Some(_)) => break, // serve before reading further
            Ok(None) => {}
        }
        match c.stream.read(&mut chunk) {
            Ok(0) => return ConnState::Drop, // peer closed
            Ok(n) => {
                c.buf.extend_from_slice(&chunk[..n]);
                read_any = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ConnState::Drop,
        }
    }
    if was_empty && read_any {
        c.started = Instant::now();
    }
    let frame = match buffered_record(&c.buf) {
        Err(()) => return ConnState::Drop,
        Ok(None) => {
            return if c.buf.is_empty() {
                if c.last_frame.elapsed() > params.idle {
                    ConnState::Drop
                } else {
                    ConnState::Keep(read_any)
                }
            } else if c.started.elapsed() > params.deadline {
                ConnState::Drop // partial record outlived the deadline
            } else {
                ConnState::Keep(read_any)
            };
        }
        Ok(Some(total)) => {
            let frame = c.buf[4..total].to_vec();
            c.buf.drain(..total);
            frame
        }
    };
    c.last_frame = Instant::now();
    c.started = c.last_frame;
    match serve_frame(&c.stream, &frame, node, params) {
        Ok(()) => ConnState::Keep(true),
        Err(()) => ConnState::Drop,
    }
}

/// Serve one completed frame. The reply write runs in blocking mode with
/// the exchange deadline — "the reply is on the wire" (accepted by the
/// kernel) is the §7.2 commit point, exactly as in the thread-per-push
/// design. `Err(())` drops the connection.
///
/// A reply write can therefore stall the (single-threaded) serve loop
/// for up to one deadline — the commit-on-reply contract forbids
/// abandoning a half-written reply. The stall is bounded per offender:
/// a peer that stops draining replies times the write out, which
/// cancels the exchange (rollback) and **drops its connection**, so a
/// non-reading client costs at most one deadline before it must
/// reconnect (and reconnects are capped by [`MAX_INFLIGHT_SERVES`]).
/// In practice loopback/LAN kernels buffer dozens of ~16 KiB replies,
/// so honest traffic never blocks here; a worker-pool or epoll serve
/// side that removes the residual stall is a ROADMAP item.
fn serve_frame(
    stream: &TcpStream,
    frame: &[u8],
    node: &NodeHandle,
    params: &ServeParams,
) -> Result<(), ()> {
    if stream.set_nonblocking(false).is_err() {
        return Err(());
    }
    let result = serve_frame_blocking(stream, frame, node, params);
    if stream.set_nonblocking(true).is_err() {
        return Err(());
    }
    result
}

fn serve_frame_blocking(
    stream: &TcpStream,
    frame: &[u8],
    node: &NodeHandle,
    params: &ServeParams,
) -> Result<(), ()> {
    let serve_started = Instant::now();
    // Decode; delta pushes are reconstructed against the cached pair
    // baseline first — a miss or mismatch answers BaselineMismatch and
    // keeps the connection (the initiator retries full on it). The
    // push's trace id is echoed in every data-plane answer (§2).
    let (generation, incoming, reply_baseline, trace_id, kind) = match decode_exchange_traced(frame)
    {
        Ok((ExchangeFrame::Push { generation, state }, tid)) => {
            (generation, state, None, tid, "full")
        }
        Ok((ExchangeFrame::DeltaPush { generation, delta }, tid)) => {
            let cached = lock_serve_baselines(&params.baselines)
                .get(&(delta.id as u64))
                .filter(|b| {
                    (params.carry || b.generation == generation)
                        && b.fingerprint == delta.baseline_fingerprint
                })
                .cloned();
            let Some(b) = cached else {
                return reject_baseline_mismatch(
                    stream,
                    node,
                    params,
                    tid,
                    generation,
                    frame.len(),
                    serve_started,
                );
            };
            match apply_delta(&b.state, &delta) {
                Ok(state) => (generation, state, Some(b), tid, "delta"),
                Err(_) => {
                    return reject_baseline_mismatch(
                        stream,
                        node,
                        params,
                        tid,
                        generation,
                        frame.len(),
                        serve_started,
                    )
                }
            }
        }
        // Membership plane (docs/PROTOCOL.md §9): merge-and-reply, or a
        // NoMembership reject on a static address-book node. Either way
        // the framing stays intact, so the connection survives.
        // Membership frames are untraced (§2), so the answers carry
        // trace id 0.
        Ok((ExchangeFrame::MembershipPush { generation, table }, _)) => {
            return match node.serve_membership(&table, generation) {
                Ok((merged, gen)) => {
                    write_frame(stream, &encode_membership_reply(gen, &merged)).map_err(|_| ())
                }
                Err(_) => {
                    count_serve_reject(params, RejectReason::NoMembership);
                    write_frame(
                        stream,
                        &encode_exchange_reject(0, RejectReason::NoMembership),
                    )
                    .map_err(|_| ())
                }
            };
        }
        Ok((ExchangeFrame::JoinRequest { addr, .. }, _)) => {
            return match node.serve_join(addr) {
                Ok((table, gen)) => {
                    write_frame(stream, &encode_membership_reply(gen, &table)).map_err(|_| ())
                }
                Err(_) => {
                    count_serve_reject(params, RejectReason::NoMembership);
                    write_frame(
                        stream,
                        &encode_exchange_reject(0, RejectReason::NoMembership),
                    )
                    .map_err(|_| ())
                }
            };
        }
        // Malformed or non-push frames never touch local state (§7.2);
        // the framing can no longer be trusted, so the connection goes.
        _ => {
            count_serve_reject(params, RejectReason::Malformed);
            let _ = write_frame(stream, &encode_exchange_reject(0, RejectReason::Malformed));
            return Err(());
        }
    };
    // The reply mirrors the push: full push → full reply, delta push →
    // delta reply (the initiator provably holds the baseline) unless the
    // delta would not save bytes.
    let mut committed: Option<(PeerState, u64, u64)> = None;
    let mut phase_push = Duration::ZERO;
    let mut phase_reply = Duration::ZERO;
    let mut reply_len = 0usize;
    let served = node.serve_exchange(incoming, generation, |reply, gen| {
        // Everything up to here — decode, delta reconstruction, and the
        // Algorithm 4 averaging inside `serve_exchange` — is the serve
        // side's "push" phase.
        phase_push = serve_started.elapsed();
        // The full frame is always built (it is the delta's size
        // benchmark), so the baseline fingerprint comes free from its
        // bytes — no separate ~16 KiB encode.
        let full = encode_exchange_reply_traced(gen, trace_id, reply);
        let fingerprint = exchange_frame_fingerprint(&full)
            .expect("an encoded reply frame is longer than its header");
        let frame = match &reply_baseline {
            Some(b) if params.delta => match delta_payload(&b.state, b.fingerprint, reply) {
                Some(d) if delta_wire_size(&d) < full.len() => {
                    encode_exchange_delta_reply_traced(gen, trace_id, &d)
                }
                _ => full,
            },
            _ => full,
        };
        write_frame(stream, &frame)?;
        phase_reply = serve_started.elapsed() - phase_push;
        reply_len = frame.len();
        committed = Some((reply.clone(), gen, fingerprint));
        Ok(())
    });
    match served {
        Ok(()) => {
            let commit_started = Instant::now();
            if params.delta {
                if let Some((state, gen, fingerprint)) = committed {
                    store_serve_baseline(&params.baselines, state, gen, fingerprint);
                }
            }
            emit_serve_span(
                node,
                stream,
                ExchangeSpan {
                    trace_id,
                    initiator: false,
                    peer: String::new(),
                    generation,
                    kind,
                    bytes: 8 + frame.len() + reply_len,
                    outcome: "ok",
                    connect: Duration::ZERO,
                    push: phase_push,
                    reply: phase_reply,
                    commit: commit_started.elapsed(),
                },
            );
            Ok(())
        }
        Err(reject) => {
            let (gen, reason) = match reject {
                ServeReject::Busy => (0, RejectReason::Busy),
                ServeReject::StaleGeneration(g) => (g, RejectReason::StaleGeneration),
                ServeReject::Lineage => (0, RejectReason::Lineage),
                // The reply write itself failed; the socket is gone.
                ServeReject::Cancelled(_) => return Err(()),
                // serve_exchange never returns this; the membership
                // frames have their own dispatch above.
                ServeReject::NoMembership => (0, RejectReason::NoMembership),
            };
            count_serve_reject(params, reason);
            let answer = encode_exchange_reject_traced(gen, trace_id, reason);
            let wrote = write_frame(stream, &answer);
            emit_serve_span(
                node,
                stream,
                ExchangeSpan {
                    trace_id,
                    initiator: false,
                    peer: String::new(),
                    generation,
                    kind,
                    bytes: 8 + frame.len() + answer.len(),
                    outcome: reject_outcome(reason),
                    connect: Duration::ZERO,
                    push: serve_started.elapsed(),
                    reply: Duration::ZERO,
                    commit: Duration::ZERO,
                },
            );
            wrote.map_err(|_| ())
        }
    }
}

/// Answer a delta push whose baseline this node does not hold (or could
/// not apply): a `BaselineMismatch` reject echoing the push's trace id,
/// plus the serve-side span so the initiator's automatic full-frame
/// retry shows up as a causal pair in the event logs.
fn reject_baseline_mismatch(
    stream: &TcpStream,
    node: &NodeHandle,
    params: &ServeParams,
    trace_id: u64,
    generation: u64,
    frame_len: usize,
    started: Instant,
) -> Result<(), ()> {
    count_serve_reject(params, RejectReason::BaselineMismatch);
    let push = started.elapsed();
    let answer = encode_exchange_reject_traced(0, trace_id, RejectReason::BaselineMismatch);
    let wrote = write_frame(stream, &answer);
    emit_serve_span(
        node,
        stream,
        ExchangeSpan {
            trace_id,
            initiator: false,
            peer: String::new(),
            generation,
            kind: "delta",
            bytes: 8 + frame_len + answer.len(),
            outcome: reject_outcome(RejectReason::BaselineMismatch),
            connect: Duration::ZERO,
            push,
            reply: started.elapsed() - push,
            commit: Duration::ZERO,
        },
    );
    wrote.map_err(|_| ())
}

/// The span `outcome` label of a reject answer (`"reject:<reason>"`;
/// the reason names match the `dudd_serve_rejects_total` label values).
fn reject_outcome(reason: RejectReason) -> &'static str {
    match reason {
        RejectReason::Busy => "reject:busy",
        RejectReason::StaleGeneration => "reject:stale_generation",
        RejectReason::Lineage => "reject:lineage",
        RejectReason::Malformed => "reject:malformed",
        RejectReason::BaselineMismatch => "reject:baseline_mismatch",
        RejectReason::NoMembership => "reject:no_membership",
    }
}

/// Ship a serve-side [`ExchangeSpan`] to the owning node's event log,
/// filling in the remote peer address. A node without an installed
/// event sink skips the peer-address lookup entirely, keeping the
/// serve hot path unchanged.
fn emit_serve_span(node: &NodeHandle, stream: &TcpStream, mut span: ExchangeSpan) {
    if !node.serve_tracing() {
        return;
    }
    span.peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "unknown".into());
    node.record_serve_span(span);
}

/// Cache the committed averaged state as the pair baseline (serve side,
/// keyed by initiator id). At capacity, older-generation entries go
/// first, then the least-recently-stored same-generation entry — never
/// the incoming one, so active partners keep their delta path even past
/// [`MAX_SERVE_BASELINES`] total partners (a starved pair would
/// otherwise pay delta-push → mismatch → full-push every exchange,
/// worse than delta-off).
fn store_serve_baseline(
    cache: &ServeBaselines,
    state: PeerState,
    generation: u64,
    fingerprint: u64,
) {
    let mut map = lock_serve_baselines(cache);
    let key = state.id as u64;
    if map.len() >= MAX_SERVE_BASELINES && !map.contains_key(&key) {
        map.retain(|_, b| b.generation >= generation);
        if map.len() >= MAX_SERVE_BASELINES {
            if let Some(oldest) = map
                .iter()
                .min_by_key(|(_, b)| b.stored_at)
                .map(|(&k, _)| k)
            {
                map.remove(&oldest);
            }
        }
    }
    map.insert(key, Baseline::of(&state, generation, fingerprint));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(id: usize, values: &[f64]) -> PeerState {
        PeerState::init(id, values, 0.01, 64).unwrap()
    }

    #[test]
    fn in_process_exchange_matches_peer_state_exchange() {
        let mut a1 = state(0, &[1.0, 2.0, 3.0]);
        let mut b1 = state(1, &[10.0, 20.0]);
        let mut a2 = a1.clone();
        let mut b2 = b1.clone();

        let expect_push = peer_state_wire_size(&a1);
        PeerState::exchange(&mut a1, &mut b1).unwrap();
        let expect = expect_push + peer_state_wire_size(&b1);

        let bytes = in_process_exchange(&mut a2, &mut b2).unwrap();
        assert_eq!(bytes, expect);
        assert_eq!(a2.n_tilde.to_bits(), a1.n_tilde.to_bits());
        assert_eq!(b2.q_tilde.to_bits(), b1.q_tilde.to_bits());
        assert_eq!(
            a2.sketch.positive_store().entries(),
            a1.sketch.positive_store().entries()
        );
    }

    #[test]
    fn lineage_error_cancels_in_process_exchange() {
        let mut a = state(0, &[1.0, 2.0]);
        let mut b = PeerState::init(1, &[3.0], 0.05, 64).unwrap();
        let a_before = a.clone();
        let b_before = b.clone();
        assert!(matches!(
            in_process_exchange(&mut a, &mut b),
            Err(TransportError::Lineage(_))
        ));
        assert_eq!(a.n_tilde.to_bits(), a_before.n_tilde.to_bits());
        assert_eq!(
            a.sketch.positive_store().entries(),
            a_before.sketch.positive_store().entries()
        );
        assert_eq!(
            b.sketch.positive_store().entries(),
            b_before.sketch.positive_store().entries()
        );
    }

    #[test]
    fn in_process_transport_refuses_remote_peers() {
        let t = InProcessTransport;
        assert!(!t.supports_remote());
        let mut s = state(0, &[1.0]);
        let before = s.clone();
        let addr: SocketAddr = "127.0.0.1:9".parse().unwrap();
        assert!(matches!(
            t.exchange_remote(&mut s, 1, addr),
            Err(TransportError::Unreachable(_))
        ));
        assert_eq!(s.n_tilde.to_bits(), before.n_tilde.to_bits());
    }

    #[test]
    fn frame_io_roundtrips_and_caps_length() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        assert_eq!(read_frame(&buf[..]).unwrap(), b"hello");
        assert_eq!(read_frame_tracked(&buf[..]).unwrap(), b"hello");

        let mut hostile = Vec::new();
        hostile.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&hostile[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let (started, err) = read_frame_tracked(&hostile[..]).unwrap_err();
        assert!(started, "the whole prefix arrived");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // Nothing at all: zero bytes seen.
        let (started, _) = read_frame_tracked(&[][..]).unwrap_err();
        assert!(!started);
        // A partial prefix still counts as "the reply started".
        let (started, _) = read_frame_tracked(&[7u8][..]).unwrap_err();
        assert!(started);
    }

    #[test]
    fn tcp_transport_requires_nonzero_deadline() {
        assert!(TcpTransport::bind("127.0.0.1:0", Duration::ZERO).is_err());
        assert!(TcpTransport::connect_only(Duration::ZERO).is_err());
        let t = TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        assert!(t.supports_remote());
        assert_eq!(t.listen_addr(), None);
        assert_eq!(t.deadline(), Duration::from_millis(50));

        let mut opts = TcpTransportOptions::default();
        opts.pool_idle = Duration::ZERO;
        assert!(TcpTransport::connect_only_with(opts).is_err());
    }

    #[test]
    fn remote_exchange_failure_leaves_initiator_untouched() {
        // Nothing listens on this freshly bound-then-dropped port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let t = TcpTransport::connect_only(Duration::from_millis(100)).unwrap();
        let mut s = state(0, &[1.0, 2.0, 3.0]);
        let before = s.clone();
        let err = t.exchange_remote(&mut s, 1, addr).unwrap_err();
        assert!(matches!(err, TransportError::Io(_)), "{err:?}");
        assert_eq!(s.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(s.q_tilde.to_bits(), before.q_tilde.to_bits());
        assert_eq!(
            s.sketch.positive_store().entries(),
            before.sketch.positive_store().entries()
        );
    }

    #[test]
    fn local_exchange_is_transport_independent() {
        let tcp = TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        let inp = InProcessTransport;
        let (mut a1, mut b1) = (state(0, &[1.0, 5.0]), state(1, &[9.0]));
        let (mut a2, mut b2) = (a1.clone(), b1.clone());
        let x = inp.exchange_local(&mut a1, &mut b1).unwrap();
        let y = tcp.exchange_local(&mut a2, &mut b2).unwrap();
        assert_eq!(x, y);
        assert_eq!(a1.n_tilde.to_bits(), a2.n_tilde.to_bits());
        assert_eq!(
            a1.sketch.positive_store().entries(),
            a2.sketch.positive_store().entries()
        );
    }

    /// A pooled connection whose peer hung up is classified
    /// [`TransportError::StaleChannel`] (retry-eligible), leaves the
    /// initiator untouched, and empties the pool for that peer.
    #[test]
    fn dead_pooled_channel_classified_stale_and_state_untouched() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only(Duration::from_millis(300)).unwrap();

        // Connect, then have the "server" close its end immediately.
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(server_side);
        // Give the FIN a moment to land so the failure is deterministic.
        std::thread::sleep(Duration::from_millis(50));

        let chan = RemoteChannel::new(addr, true, Box::new(client));
        assert!(chan.reused());
        let mut s = state(0, &[1.0, 2.0]);
        let before = s.clone();
        let err = t.exchange_on(chan, &mut s, 1).unwrap_err();
        assert!(matches!(err, TransportError::StaleChannel(_)), "{err:?}");
        assert_eq!(s.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(
            s.sketch.positive_store().entries(),
            before.sketch.positive_store().entries()
        );
        assert_eq!(t.pooled_connections(addr), 0);
    }

    /// A *fresh* connection dying the same way is a plain Io failure —
    /// no retry invitation, exactly one failed exchange.
    #[test]
    fn dead_fresh_channel_is_not_retryable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only(Duration::from_millis(300)).unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(server_side);
        std::thread::sleep(Duration::from_millis(50));

        let chan = RemoteChannel::new(addr, false, Box::new(client));
        let mut s = state(0, &[1.0]);
        let err = t.exchange_on(chan, &mut s, 1).unwrap_err();
        assert!(matches!(err, TransportError::Io(_)), "{err:?}");
    }

    #[test]
    fn pool_checkout_discards_closed_connections() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only(Duration::from_millis(300)).unwrap();

        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        t.pool.checkin(addr, client, t.opts.pool_connections);
        assert_eq!(t.pooled_connections(addr), 1);

        drop(server_side);
        std::thread::sleep(Duration::from_millis(50));

        // Checkout health-check notices the close and reports no conn.
        assert!(t
            .pool
            .checkout(addr, t.opts.pool_idle, &t.stats, None)
            .is_none());
        assert_eq!(t.pool_stats().stale_discarded, 1);
        assert_eq!(t.pooled_connections(addr), 0);
    }

    #[test]
    fn pool_checkout_returns_healthy_connection_and_counts_reuse() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only(Duration::from_millis(300)).unwrap();

        let client = TcpStream::connect(addr).unwrap();
        let (_server_side, _) = listener.accept().unwrap();
        t.pool.checkin(addr, client, 2);
        let got = t.pool.checkout(addr, t.opts.pool_idle, &t.stats, None);
        assert!(got.is_some());
        assert_eq!(t.pool_stats().reused, 1);
        assert_eq!(t.pool_stats().stale_discarded, 0);
    }

    #[test]
    fn pool_respects_cap_and_idle_expiry() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only_with(TcpTransportOptions {
            deadline: Duration::from_millis(300),
            pool_connections: 1,
            pool_idle: Duration::from_millis(1),
            ..TcpTransportOptions::default()
        })
        .unwrap();

        let mut held = Vec::new();
        for _ in 0..2 {
            let c = TcpStream::connect(addr).unwrap();
            held.push(listener.accept().unwrap().0);
            t.pool.checkin(addr, c, t.opts.pool_connections);
        }
        assert_eq!(t.pooled_connections(addr), 1, "cap of 1 enforced");

        std::thread::sleep(Duration::from_millis(30));
        assert!(
            t.pool
                .checkout(addr, t.opts.pool_idle, &t.stats, None)
                .is_none(),
            "idle-expired connection must not be reused"
        );
        assert_eq!(t.pool_stats().expired, 1);
    }

    #[test]
    fn transport_options_from_gossip_config() {
        let mut cfg = GossipLoopConfig::default();
        cfg.exchange_deadline_ms = 250;
        cfg.pool_connections = 0;
        cfg.pool_idle_ms = 5;
        cfg.delta_exchanges = false;
        let opts = TcpTransportOptions::from_gossip(&cfg);
        assert_eq!(opts.deadline, Duration::from_millis(250));
        assert_eq!(opts.pool_connections, 0);
        assert_eq!(opts.pool_idle, Duration::from_millis(5));
        assert!(!opts.delta_exchanges);
    }

    #[test]
    fn evict_idlest_prefers_longest_idle_and_spares_mid_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut make = |busy: bool, idle_ms: u64| -> ServeConn {
            let _client = TcpStream::connect(addr).unwrap();
            let (stream, _) = listener.accept().unwrap();
            let now = Instant::now();
            ServeConn {
                stream,
                buf: if busy { vec![1] } else { Vec::new() },
                started: now,
                last_frame: now - Duration::from_millis(idle_ms),
            }
        };
        let mut conns = vec![make(false, 50), make(true, 500), make(false, 200)];
        assert!(evict_idlest(&mut conns), "an idle connection exists");
        assert_eq!(conns.len(), 2);
        assert!(
            conns.iter().any(|c| !c.buf.is_empty()),
            "the mid-frame connection must survive"
        );
        assert!(evict_idlest(&mut conns), "one idle connection left");
        assert!(
            !evict_idlest(&mut conns),
            "all remaining connections are mid-frame: genuine overload"
        );
        assert_eq!(conns.len(), 1);
    }

    #[test]
    fn serve_baseline_cache_bounded_with_lru_eviction() {
        let cache: ServeBaselines = Arc::new(Mutex::new(HashMap::new()));
        for id in 0..MAX_SERVE_BASELINES + 10 {
            let st = state(id, &[1.0]);
            let fp = peer_state_fingerprint(&st);
            store_serve_baseline(&cache, st, 1, fp);
        }
        {
            let map = cache.lock().unwrap();
            assert!(map.len() <= MAX_SERVE_BASELINES);
            // The most recent partner is cached (LRU evicted an older
            // one) — an active pair past the cap must keep its delta
            // path rather than degrade to mismatch-then-full forever.
            let newest = (MAX_SERVE_BASELINES + 9) as u64;
            assert!(map.contains_key(&newest), "newest partner not cached");
            // The 10 evictions all hit the earliest-stored cohort.
            assert!(
                (0..10u64).any(|id| !map.contains_key(&id)),
                "LRU eviction should have removed early partners"
            );
        }
        // A newer generation evicts the old entries instead of starving.
        let st = state(3, &[2.0]);
        let fp = peer_state_fingerprint(&st);
        store_serve_baseline(&cache, st, 2, fp);
        let map = cache.lock().unwrap();
        assert_eq!(map.get(&3).unwrap().generation, 2);
    }

    /// ISSUE 9: under baseline carry (the default) a cached pair
    /// baseline survives a generation bump — a required reseed ships
    /// as a delta against the pre-reseed baseline — while carry-off
    /// restores the PR 5 generation-keyed invalidation.
    #[test]
    fn initiator_baseline_survives_generation_bump_only_with_carry() {
        let peer: SocketAddr = "127.0.0.1:9009".parse().unwrap();
        let st = state(1, &[1.0, 2.0]);
        let fp = peer_state_fingerprint(&st);

        let t = TcpTransport::connect_only(Duration::from_millis(100)).unwrap();
        assert!(t.options().baseline_carry, "carry is the default");
        t.lock_baselines().insert(peer, Baseline::of(&st, 3, fp));
        assert!(t.baseline_for(peer, 3).is_some());
        assert!(
            t.baseline_for(peer, 4).is_some(),
            "carry: a baseline cached at generation 3 must serve generation 4"
        );

        let t = TcpTransport::connect_only_with(TcpTransportOptions {
            deadline: Duration::from_millis(100),
            baseline_carry: false,
            ..TcpTransportOptions::default()
        })
        .unwrap();
        t.lock_baselines().insert(peer, Baseline::of(&st, 3, fp));
        assert!(t.baseline_for(peer, 3).is_some());
        assert!(
            t.baseline_for(peer, 4).is_none(),
            "carry off: the generation is part of the baseline key"
        );
    }

    /// A `Busy` reject is a routine round collision on an intact
    /// connection: the socket must go back to the pool, not pay a
    /// reconnect next round.
    #[test]
    fn busy_reject_keeps_the_connection_pooled() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let _push = read_frame(&s).unwrap();
            write_frame(&s, &encode_exchange_reject(0, RejectReason::Busy)).unwrap();
            // Hold the socket open long enough for the checkin.
            std::thread::sleep(Duration::from_millis(200));
            drop(s);
        });
        let t = TcpTransport::connect_only(Duration::from_millis(1_000)).unwrap();
        let mut st = state(0, &[1.0, 2.0]);
        let before = st.clone();
        let err = t.exchange_remote(&mut st, 1, addr).unwrap_err();
        assert!(matches!(err, TransportError::Busy), "{err:?}");
        assert_eq!(st.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(
            t.pooled_connections(addr),
            1,
            "busy reject must return the connection to the pool"
        );
        server.join().unwrap();
    }

    /// Regression: `delta_since` against a *larger* previous snapshot
    /// (transport swapped mid-run, so the counters restarted) must clamp
    /// to zero instead of wrapping to huge per-round values.
    #[test]
    fn pool_stats_delta_since_saturates_on_counter_reset() {
        let newer = PoolStats {
            fresh_connects: 3,
            reused: 10,
            delta_pushes: 2,
            ..PoolStats::default()
        };
        let older = PoolStats {
            fresh_connects: 5,
            reused: 4,
            stale_discarded: 7,
            expired: 1,
            delta_pushes: 2,
            full_pushes: 9,
        };
        let d = newer.delta_since(older);
        assert_eq!(d.fresh_connects, 0, "reset counter must clamp, not wrap");
        assert_eq!(d.reused, 6, "a genuinely advancing counter still diffs");
        assert_eq!(d.stale_discarded, 0);
        assert_eq!(d.expired, 0);
        assert_eq!(d.delta_pushes, 0, "an unchanged counter diffs to zero");
        assert_eq!(d.full_pushes, 0);
        assert_eq!(
            newer.delta_since(PoolStats::default()),
            newer,
            "diff against a zero snapshot is the snapshot itself"
        );
    }

    /// Installed [`TransportMetrics`] mirror the legacy pool counters
    /// without replacing them.
    #[test]
    fn installed_metrics_mirror_the_pool_counters() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = TcpTransport::connect_only(Duration::from_millis(300)).unwrap();
        let obs = crate::obs::NodeMetrics::standalone();
        t.install_metrics(obs.transport.clone());

        // A fresh dial books `pool_fresh_connects`.
        let chan = t.open_remote(addr).unwrap();
        assert!(!chan.reused());
        assert_eq!(obs.transport.pool_fresh_connects.get(), 1);

        // A pooled checkout books `pool_reused` and keeps the legacy
        // counter advancing alongside.
        let client = TcpStream::connect(addr).unwrap();
        let (_server_side, _) = listener.accept().unwrap();
        t.pool.checkin(addr, client, 2);
        let got = t
            .pool
            .checkout(addr, t.opts.pool_idle, &t.stats, t.metrics.get());
        assert!(got.is_some());
        assert_eq!(obs.transport.pool_reused.get(), 1);
        assert_eq!(t.pool_stats().reused, 1, "legacy counters still advance");

        // A second install is ignored (first wins), so the handles stay
        // attached to the original registry.
        t.install_metrics(crate::obs::NodeMetrics::standalone().transport.clone());
        let chan2 = t.open_remote(addr).unwrap();
        assert!(!chan2.reused());
        assert_eq!(obs.transport.pool_fresh_connects.get(), 2);
    }
}
