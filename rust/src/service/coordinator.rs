//! The service coordinator: epoch drains, snapshot publication, writers.
//!
//! [`QuantileService::start`] spawns N ingest shards; producers obtain
//! batching [`ServiceWriter`]s and push values with no shared state.
//! Periodically (background ticker) or on demand ([`QuantileService::flush`])
//! the coordinator runs an **epoch**: it drains every shard's delta
//! sketch, folds the deltas into the accumulator (`merge_weighted`
//! aligns collapse lineages, so shards that collapsed at different
//! depths still fold exactly), and publishes a fresh epoch-stamped
//! [`Snapshot`] through an [`ArcSwapCell`] — queries never block ingest
//! and never take a lock.

#![forbid(unsafe_code)]

use super::shard::{spawn_shard, ShardDelta, ShardHandle, ShardMsg};
use super::snapshot::Snapshot;
use super::swap::ArcSwapCell;
use super::window::WindowRing;
use crate::config::ServiceConfig;
use crate::gossip::PeerState;
use crate::obs::ServiceMetrics;
use crate::sketch::{DenseStore, UddSketch};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator state shared with the background ticker.
struct Inner {
    /// The published snapshot (lock-free read path).
    current: ArcSwapCell<Snapshot>,
    /// Epoch accumulator; the lock serializes concurrent epochs
    /// (ticker vs. `flush`), never readers.
    accum: Mutex<Accum>,
    /// Installed ingest metrics (`None` on an uninstrumented service —
    /// the bench baseline and every direct [`QuantileService::start`]).
    metrics: Option<ServiceMetrics>,
}

struct Accum {
    alpha: f64,
    max_buckets: usize,
    /// Cumulative global sketch (cumulative mode only).
    global: UddSketch<DenseStore>,
    /// Sliding-window ring (windowed mode only).
    ring: Option<WindowRing>,
    /// Epochs completed.
    epoch: u64,
    /// Lifetime operations folded in.
    ops: u64,
}

/// A multi-threaded quantile-tracking service over sharded UDDSketches.
///
/// ```
/// use duddsketch::config::ServiceConfig;
/// use duddsketch::service::QuantileService;
///
/// let mut cfg = ServiceConfig::default();
/// cfg.shards = 2;
/// let svc = QuantileService::start(cfg).unwrap();
/// let mut w = svc.writer();
/// for i in 1..=1000 {
///     w.insert(i as f64);
/// }
/// w.flush();
/// let snap = svc.flush();
/// assert_eq!(snap.count(), 1000.0);
/// let p50 = snap.quantile(0.5).unwrap();
/// assert!((p50 - 500.0).abs() / 500.0 < 0.01);
/// svc.shutdown();
/// ```
pub struct QuantileService {
    cfg: ServiceConfig,
    shards: Vec<ShardHandle>,
    inner: Arc<Inner>,
    stop: Arc<AtomicBool>,
    ticker: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for QuantileService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "QuantileService(shards={}, epoch={})",
            self.shards.len(),
            self.snapshot().epoch()
        )
    }
}

impl QuantileService {
    /// Validate the configuration, spawn the ingest shards, and (when an
    /// epoch interval is configured) the background epoch ticker.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        Self::start_instrumented(cfg, None)
    }

    /// [`QuantileService::start`] with ingest metrics installed —
    /// [`Node::builder`](super::Node::builder) wires the node's shared
    /// registry through here. `None` keeps the service entirely
    /// uninstrumented (the ingest bench's baseline).
    pub(crate) fn start_instrumented(
        cfg: ServiceConfig,
        metrics: Option<ServiceMetrics>,
    ) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let n = cfg.shards;
        let mut shards = Vec::with_capacity(n);
        for id in 0..n {
            shards.push(spawn_shard(
                id,
                cfg.alpha,
                cfg.max_buckets,
                cfg.queue_depth,
                metrics.clone(),
            )?);
        }
        let ring = if cfg.window_slots > 0 {
            Some(
                WindowRing::new(cfg.window_slots, cfg.alpha, cfg.max_buckets)
                    .context("building window ring")?,
            )
        } else {
            None
        };
        let inner = Arc::new(Inner {
            current: ArcSwapCell::new(Arc::new(
                Snapshot::empty(cfg.alpha, cfg.max_buckets).context("initial snapshot")?,
            )),
            accum: Mutex::new(Accum {
                alpha: cfg.alpha,
                max_buckets: cfg.max_buckets,
                global: UddSketch::new(cfg.alpha, cfg.max_buckets)
                    .context("global accumulator")?,
                ring,
                epoch: 0,
                ops: 0,
            }),
            metrics,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let ticker = if cfg.epoch_interval_ms > 0 {
            let senders: Vec<SyncSender<ShardMsg>> =
                shards.iter().map(|s| s.tx.clone()).collect();
            let inner = inner.clone();
            let stop = stop.clone();
            let interval = Duration::from_millis(cfg.epoch_interval_ms);
            Some(
                std::thread::Builder::new()
                    .name("dudd-epoch".into())
                    .spawn(move || ticker_loop(&senders, &inner, &stop, interval))
                    .context("spawning epoch ticker")?,
            )
        } else {
            None
        };
        Ok(Self {
            cfg,
            shards,
            inner,
            stop,
            ticker,
        })
    }

    /// [`QuantileService::start`], wrapped in an [`Arc`] — the form a
    /// [`GossipLoop`](super::GossipLoop) member and concurrent query
    /// threads share.
    pub fn start_shared(cfg: ServiceConfig) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::start(cfg)?))
    }

    /// The service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Number of ingest shards running.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// A new batching ingest handle. Writers are independent — create one
    /// per producer thread; each buffers locally and ships full batches
    /// round-robin across the shards (bounded queues give backpressure).
    pub fn writer(&self) -> ServiceWriter {
        ServiceWriter {
            senders: self.shards.iter().map(|s| s.tx.clone()).collect(),
            batch: self.cfg.batch_size.max(1),
            inserts: Vec::with_capacity(self.cfg.batch_size.max(1)),
            updates: Vec::new(),
            next: 0,
        }
    }

    /// The latest published snapshot. Lock-free; never blocks ingest or
    /// epochs, and the returned handle stays consistent forever.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.inner.current.load()
    }

    /// Run one epoch synchronously: drain every shard, fold the deltas,
    /// publish, and return the fresh snapshot. Batches already enqueued
    /// to the shards are included (FIFO queues); values still buffered in
    /// un-flushed [`ServiceWriter`]s are not — flush writers first.
    pub fn flush(&self) -> Arc<Snapshot> {
        let senders: Vec<SyncSender<ShardMsg>> =
            self.shards.iter().map(|s| s.tx.clone()).collect();
        run_epoch(&senders, &self.inner)
    }

    /// A gossip peer state fronted by the latest snapshot: the local
    /// sketch of Algorithm 3 is the service's live summary instead of a
    /// replayed raw stream (see also [`super::ServicePeer`]).
    pub fn peer_state(&self, id: usize) -> PeerState {
        PeerState::from_sketch(id, self.snapshot().sketch())
    }

    /// Stop the ticker, run a final epoch, retire the shards, and return
    /// the final snapshot. Outstanding [`ServiceWriter`]s may still be
    /// alive — shards retire via an explicit stop message, so shutdown
    /// never blocks on writer lifetimes; later writer batches are
    /// dropped against the disconnected queues.
    pub fn shutdown(mut self) -> Arc<Snapshot> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.ticker.take() {
            let _ = t.join();
        }
        let snap = self.flush();
        retire_shards(&mut self.shards);
        snap
    }
}

impl Drop for QuantileService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.ticker.take() {
            let _ = t.join();
        }
        retire_shards(&mut self.shards);
    }
}

/// Send every shard a stop message and join it. The explicit message —
/// rather than waiting for all sender clones to drop — means teardown
/// cannot deadlock on a `ServiceWriter` that outlives the service.
fn retire_shards(shards: &mut Vec<ShardHandle>) {
    for s in shards.iter() {
        let _ = s.tx.send(ShardMsg::Stop);
    }
    for s in shards.drain(..) {
        drop(s.tx);
        let _ = s.join.join();
    }
}

/// Background ticker: one epoch per interval, stop-aware in ≤10 ms steps
/// so shutdown never waits out a long interval.
fn ticker_loop(
    senders: &[SyncSender<ShardMsg>],
    inner: &Inner,
    stop: &AtomicBool,
    interval: Duration,
) {
    let step = Duration::from_millis(10).min(interval);
    'outer: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'outer;
            }
            let d = step.min(interval - slept);
            std::thread::sleep(d);
            slept += d;
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        run_epoch(senders, inner);
    }
}

fn lock_accum(inner: &Inner) -> std::sync::MutexGuard<'_, Accum> {
    inner.accum.lock().expect("accumulator poisoned")
}

/// Drain every shard into the accumulator and publish a fresh snapshot.
fn run_epoch(senders: &[SyncSender<ShardMsg>], inner: &Inner) -> Arc<Snapshot> {
    let fold_start = Instant::now();
    // The accumulator lock serializes concurrent epochs end to end.
    let mut guard = lock_accum(inner);
    let accum: &mut Accum = &mut guard;
    let (tx, rx) = mpsc::channel::<ShardDelta>();
    let mut expected = 0usize;
    for s in senders {
        if s.send(ShardMsg::Drain(tx.clone())).is_ok() {
            expected += 1;
        }
    }
    drop(tx);

    let mut epoch_delta: UddSketch<DenseStore> =
        UddSketch::new(accum.alpha, accum.max_buckets).expect("validated parameters");
    let mut ops = 0u64;
    for _ in 0..expected {
        match rx.recv() {
            Ok(delta) => {
                ops += delta.ops;
                epoch_delta
                    .merge(&delta.sketch)
                    .expect("shards share one alpha0 lineage");
            }
            Err(_) => break, // a shard died mid-drain; fold what arrived
        }
    }

    // Idle tick in cumulative mode: nothing arrived, so the published
    // snapshot is already exact — skip the global clone + republish a
    // frequent ticker would otherwise burn every interval. Windowed mode
    // must always push (empty epochs still age out old intervals).
    if ops == 0 && accum.ring.is_none() && accum.epoch > 0 {
        return inner.current.load();
    }

    accum.ops += ops;
    accum.epoch += 1;
    let (sketch, window) = match &mut accum.ring {
        Some(ring) => {
            ring.push_epoch(epoch_delta);
            (
                ring.merged().expect("ring shares one alpha0 lineage"),
                ring.coverage(),
            )
        }
        None => {
            accum
                .global
                .merge(&epoch_delta)
                .expect("global shares one alpha0 lineage");
            (accum.global.clone(), None)
        }
    };
    let snap = Arc::new(Snapshot::new(accum.epoch, sketch, accum.ops, window));
    inner.current.store(snap.clone());
    // Booked after the idle short-circuit above, so `dudd_epochs_total`
    // counts published folds, not no-op ticks.
    if let Some(m) = &inner.metrics {
        m.epochs.inc();
        m.epoch_fold.observe(fold_start.elapsed().as_secs_f64());
    }
    snap
}

/// Batching ingest handle bound to one producer.
///
/// Values accumulate in a local buffer and ship to the shards
/// round-robin as full batches; [`ServiceWriter::flush`] (also run on
/// `Drop`) pushes partial batches. Turnstile updates
/// ([`ServiceWriter::delete`] / [`ServiceWriter::update`]) batch
/// separately; weights add commutatively, so the relative order of the
/// two buffers never changes the folded result. Non-finite values are
/// dropped at the shard (a live stream must not panic a worker).
pub struct ServiceWriter {
    senders: Vec<SyncSender<ShardMsg>>,
    batch: usize,
    inserts: Vec<f64>,
    updates: Vec<(f64, f64)>,
    next: usize,
}

impl ServiceWriter {
    /// Insert one value.
    #[inline]
    pub fn insert(&mut self, x: f64) {
        self.inserts.push(x);
        if self.inserts.len() >= self.batch {
            self.ship_inserts();
        }
    }

    /// Insert a slice of values.
    pub fn insert_batch(&mut self, xs: &[f64]) {
        for &x in xs {
            self.insert(x);
        }
    }

    /// Delete one previously inserted value (turnstile model).
    #[inline]
    pub fn delete(&mut self, x: f64) {
        self.update(x, -1.0);
    }

    /// Add weight `w` (possibly negative or fractional) for value `x`.
    #[inline]
    pub fn update(&mut self, x: f64, w: f64) {
        self.updates.push((x, w));
        if self.updates.len() >= self.batch {
            self.ship_updates();
        }
    }

    /// Ship all locally buffered values to the shards. Blocks while shard
    /// queues are full (backpressure).
    pub fn flush(&mut self) {
        self.ship_inserts();
        self.ship_updates();
    }

    fn ship_inserts(&mut self) {
        if self.inserts.is_empty() {
            return;
        }
        let batch = std::mem::replace(&mut self.inserts, Vec::with_capacity(self.batch));
        self.ship(ShardMsg::Ingest(batch));
    }

    fn ship_updates(&mut self) {
        if self.updates.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.updates);
        self.ship(ShardMsg::Update(batch));
    }

    fn ship(&mut self, msg: ShardMsg) {
        let n = self.senders.len();
        let mut msg = msg;
        // Round-robin; skip retired shards (disconnected channels). If
        // every shard is gone the service shut down and the batch drops.
        for _ in 0..n {
            let k = self.next % n;
            self.next = self.next.wrapping_add(1);
            msg = match self.senders[k].send(msg) {
                Ok(()) => return,
                Err(e) => e.0,
            };
        }
    }
}

impl Drop for ServiceWriter {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(shards: usize) -> ServiceConfig {
        let mut c = ServiceConfig::default();
        c.shards = shards;
        c.batch_size = 64;
        c
    }

    #[test]
    fn epochs_accumulate_and_stamp_snapshots() {
        let svc = QuantileService::start(cfg(3)).unwrap();
        assert_eq!(svc.shard_count(), 3);
        assert_eq!(svc.snapshot().epoch(), 0);

        let mut w = svc.writer();
        w.insert_batch(&[1.0, 2.0, 3.0, 4.0]);
        w.flush();
        let s1 = svc.flush();
        assert_eq!(s1.epoch(), 1);
        assert_eq!(s1.count(), 4.0);
        assert_eq!(s1.ops(), 4);

        w.insert_batch(&[5.0, 6.0]);
        w.flush();
        let s2 = svc.flush();
        assert_eq!(s2.epoch(), 2);
        assert_eq!(s2.count(), 6.0);
        // The earlier handle is immutable.
        assert_eq!(s1.count(), 4.0);
        drop(w);
        let fin = svc.shutdown();
        assert_eq!(fin.count(), 6.0);
    }

    #[test]
    fn turnstile_updates_fold_across_shards() {
        let svc = QuantileService::start(cfg(4)).unwrap();
        let mut w = svc.writer();
        for i in 1..=100 {
            w.insert(i as f64);
        }
        for i in 51..=100 {
            w.delete(i as f64);
        }
        w.flush();
        let snap = svc.flush();
        assert_eq!(snap.count(), 50.0);
        let hi = snap.quantile(1.0).unwrap();
        assert!((hi - 50.0).abs() <= 0.001 * 50.0 + 1e-9, "max {hi}");
        drop(w);
        svc.shutdown();
    }

    #[test]
    fn writer_drop_flushes_partial_batches() {
        let svc = QuantileService::start(cfg(2)).unwrap();
        {
            let mut w = svc.writer();
            w.insert(42.0); // far below batch_size
        }
        let snap = svc.flush();
        assert_eq!(snap.count(), 1.0);
        svc.shutdown();
    }

    #[test]
    fn windowed_mode_serves_last_k_epochs() {
        let mut c = cfg(2);
        c.window_slots = 2;
        let svc = QuantileService::start(c).unwrap();
        let mut w = svc.writer();
        for chunk in [&[1.0f64; 8][..], &[2.0; 8], &[3.0; 8]] {
            w.insert_batch(chunk);
            w.flush();
            svc.flush();
        }
        let snap = svc.snapshot();
        assert_eq!(snap.epoch(), 3);
        assert_eq!(snap.window(), Some((2, 3)));
        // Epoch 1 (all 1.0) evicted: 16 items left, min ≈ 2.
        assert_eq!(snap.count(), 16.0);
        let lo = snap.quantile(0.0).unwrap();
        assert!((lo - 2.0).abs() <= 0.001 * 2.0 + 1e-9, "evicted epoch leaked: {lo}");
        // Lifetime ops still counts evicted epochs.
        assert_eq!(snap.ops(), 24);
        drop(w);
        svc.shutdown();
    }

    #[test]
    fn background_ticker_publishes_without_flush() {
        let mut c = cfg(2);
        c.epoch_interval_ms = 5;
        let svc = QuantileService::start(c).unwrap();
        let mut w = svc.writer();
        w.insert_batch(&[1.0, 2.0, 3.0]);
        w.flush();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let snap = svc.snapshot();
            if snap.count() == 3.0 {
                assert!(snap.epoch() >= 1);
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "ticker never published (epoch {})",
                snap.epoch()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        drop(w);
        svc.shutdown();
    }

    /// An instrumented service books published epoch folds (and their
    /// latency) but not idle ticks, which short-circuit.
    #[test]
    fn instrumented_service_books_epoch_folds_not_idle_ticks() {
        let obs = crate::obs::NodeMetrics::standalone();
        let svc =
            QuantileService::start_instrumented(cfg(2), Some(obs.service.clone())).unwrap();
        let mut w = svc.writer();
        w.insert_batch(&[1.0, 2.0]);
        w.flush();
        svc.flush();
        assert_eq!(obs.service.epochs.get(), 1);
        assert_eq!(obs.service.epoch_fold.count(), 1);
        assert_eq!(obs.service.values.get(), 2);
        svc.flush(); // idle: nothing arrived, no republish
        assert_eq!(obs.service.epochs.get(), 1, "idle tick must not count");
        drop(w);
        svc.shutdown();
    }

    #[test]
    fn peer_state_fronts_snapshot() {
        let svc = QuantileService::start(cfg(2)).unwrap();
        let mut w = svc.writer();
        for i in 1..=1000 {
            w.insert(i as f64);
        }
        w.flush();
        svc.flush();
        let peer = svc.peer_state(0);
        assert_eq!(peer.id, 0);
        assert_eq!(peer.q_tilde, 1.0);
        assert_eq!(peer.n_tilde, 1000.0);
        let est = peer.query(0.5).unwrap();
        assert!((est - 500.0).abs() / 500.0 <= 0.001 + 1e-9);
        svc.shutdown();
    }
}
