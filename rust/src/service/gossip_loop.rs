//! The continuous service-driven gossip loop: refresh → exchange → serve.
//!
//! PR 1 connected a [`QuantileService`] to the protocol one shot at a
//! time ([`ServicePeer`](super::ServicePeer)); this module closes the
//! paper's full production loop. A [`GossipLoop`] owns the node's view of
//! a fleet of **members** — live services, simulated peers, and (since
//! the transport redesign) **remote nodes** — and runs the cycle
//! continuously while ingest keeps flowing:
//!
//! ```text
//!        ┌────────────────────────── every round ─────────────────────────┐
//!        │ refresh: any service published a newer epoch? a partner        │
//!        │          reported a newer restart generation? the member       │
//!        │          view re-anchored?                                     │
//!        │   ├─ epoch only → fold the snapshot's additive delta into     │
//!        │   │   the averaged slot in place (restart-free carry, §10)    │
//!        │   └─ else → reseed every local PeerState (protocol restart,    │
//!        │            Prop. 4: averaging re-converges from any states)    │
//!        │ exchange: one fan-out push–pull round over the overlay,        │
//!        │           every partner interaction through the Transport      │
//!        │           trait (in-process or framed TCP; failures cancel     │
//!        │           the exchange, §7.2)                                  │
//!        │ serve: publish one GlobalView per local member through an      │
//!        │        ArcSwapCell — reads never block, never see a torn state │
//!        └────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Queries can therefore read **two** estimates: the service's own
//! [`Snapshot`](super::Snapshot) (local stream only, exact fold) and the
//! loop's [`GlobalView`] (network-converged estimate of the *union*
//! stream, Algorithm 6). Convergence is observable: each round the loop
//! probes a configured quantile set and reports the largest relative
//! drift since the previous round; once the drift falls below
//! [`GossipLoopConfig::convergence_rel`] the view is flagged converged.
//!
//! **Restarts and restart-free churn.** `q̃` mass must stay exactly 1
//! across the fleet for the network-size estimate `p̃ = 1/q̃` to be
//! unbiased. PR 5 guarded that invariant with a blunt rule — *any*
//! churn or epoch advance restarted every member — which turned a
//! large fleet's steady trickle of joins and ingest into a generation
//! storm that never let averaging converge. The rules are now sharper
//! (normative statement: `docs/PROTOCOL.md` §10), on by default via
//! [`GossipLoopConfig::restart_free`](crate::config::GossipLoopConfig::restart_free):
//!
//! * **Joins are free.** A joiner enters the *current* generation with
//!   `q̃ = 0`: zero mass in, zero mass moved, Σ`q̃` is untouched — the
//!   invariant holds by construction, no coordination round needed.
//! * **Epoch advances carry.** A local epoch advance folds the
//!   snapshot's additive delta (new summary − seed summary) into the
//!   averaged slot in place; the fleet sums move exactly as if the new
//!   items had been present at the last restart. Only when the delta
//!   is undefined — the summary is not an insert-only extension of the
//!   seed (window eviction, lineage reset) — does the node fall back
//!   to a restart ([`RestartCause::EpochFallback`]).
//! * **Only deaths re-anchor.** A dead ↔ non-dead flip of the member
//!   view is the one churn event that still restarts the protocol: a
//!   dead node's in-memory mass share is unrecoverable, so survivors
//!   bump the **generation counter** carried in every exchange frame
//!   and reseed from their own latest summaries. A node that *hears* a
//!   newer generation (in an inbound push, or in a partner's
//!   stale-rejection) reseeds and adopts it before any averaging —
//!   states from different generations never average together, so
//!   within each generation the `q̃` mass is exactly 1 and the fixed
//!   point is the union of the freshest local summaries.
//!
//! # Locking model (per-member since PR 4)
//!
//! PR 3 serialized everything — rounds *and* inbound serves — on one
//! worker mutex, so a round stalled on a dead peer's connect deadline
//! served nothing for up to fan-out × deadline. The lock is now split:
//!
//! * **One state lock per member slot** (`slots[i]`). An initiator holds
//!   *only its own slot* across the push–pull socket op; co-located
//!   pairs lock both slots in ascending index order; inbound serves
//!   **try**-lock (never block) and answer `Busy` on contention — the
//!   §7.2 cancellation the initiator retries next round.
//! * **One control lock** (`ctl`) for round bookkeeping: rng, round and
//!   generation counters, epochs, drift. It is held only for short
//!   critical sections, **never across a socket operation**.
//! * **One round gate** serializing whole rounds (manual
//!   [`GossipLoop::step`] vs the background thread); serves ignore it.
//!
//! *Lock order:* slots in ascending member index, then `ctl`; the gate
//! is outermost and only on round paths. No path acquires a slot while
//! holding `ctl`, and serves acquire slots exclusively with `try_lock`,
//! so the order is acyclic and cross-node deadlock stays impossible.
//!
//! The payoff: [`Transport::open_remote`] (where a dead peer's connect
//! deadline burns) runs with **no lock at all**, so inbound exchanges
//! keep being served while a round waits out a dead partner — the
//! serve-availability guarantee PR 3's ROADMAP called for. A node
//! actually mid-exchange on its own slot still answers `Busy`, which is
//! the protocol's intended behavior (the slot's state is in flight).
//!
//! The serve side of the transport ([`NodeHandle`]) applies inbound
//! exchanges with §7.2 atomicity: the averaged state commits only once
//! the reply reaches the wire and rolls back otherwise.
//!
//! The locking model above is machine-checked: the `lock-order` rule of
//! `dudd-analyze` (see `docs/ANALYSIS.md`) rejects inverted slot/ctl
//! acquisitions, slot pairs taken without ascending-order evidence, and
//! socket I/O reachable under control-plane locks.

#![forbid(unsafe_code)]

use super::coordinator::QuantileService;
use super::membership::{MemberStatus, MemberTable, Membership};
use super::swap::ArcSwapCell;
use super::transport::{InProcessTransport, PoolStats, Transport, TransportError};
use crate::config::GossipLoopConfig;
use crate::gossip::{select_exchange_partners, GossipSketch, PeerState};
use crate::graph::Graph;
use crate::metrics::relative_error;
use crate::obs::{ExchangeSpan, NodeMetrics, RoundPhase, RoundTrace};
use crate::rng::{default_rng, Rng as _, Xoshiro256pp};
use crate::sketch::{theorem2_bound, QuantileReader, SketchError, Store, UddSketch};
use anyhow::{bail, Context, Result};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One participant in a [`GossipLoop`].
#[derive(Debug)]
pub enum GossipMember {
    /// A live ingest service: reseeded from its latest published
    /// snapshot whenever a newer epoch appears.
    Service(Arc<QuantileService>),
    /// A simulated remote peer with a fixed local summary.
    Static(GossipSketch),
    /// A real remote node reached through the loop's
    /// [`Transport`](super::Transport) (its state lives on that node; the
    /// member's own loop drives its exchanges). Requires a
    /// remote-capable transport such as
    /// [`TcpTransport`](super::TcpTransport).
    Remote(SocketAddr),
}

impl GossipMember {
    /// A member fronting a live service.
    pub fn service(svc: Arc<QuantileService>) -> Self {
        GossipMember::Service(svc)
    }

    /// A simulated peer summarizing `data` with the given sketch
    /// parameters.
    pub fn from_dataset(data: &[f64], alpha: f64, max_buckets: usize) -> Result<Self> {
        let mut s: UddSketch = UddSketch::new(alpha, max_buckets)
            .map_err(anyhow::Error::msg)
            .context("static member sketch")?;
        s.extend(data);
        Ok(GossipMember::Static(s.convert_store()))
    }

    /// A simulated peer fronting an already-built local summary.
    pub fn from_sketch<S: Store>(sketch: &UddSketch<S>) -> Self {
        GossipMember::Static(sketch.convert_store())
    }

    /// A remote node at `addr` (see [`GossipMember::Remote`]).
    pub fn remote(addr: SocketAddr) -> Self {
        GossipMember::Remote(addr)
    }

    /// True for members whose state lives in this loop (service/static).
    pub fn is_local(&self) -> bool {
        !matches!(self, GossipMember::Remote(_))
    }
}

/// The network-converged estimate one member serves after a round.
///
/// Immutable, like [`Snapshot`](super::Snapshot): a handle keeps
/// answering consistently no matter how far the loop advances. Also
/// queryable through [`QuantileReader`].
#[derive(Debug, Clone)]
pub struct GlobalView {
    round: u64,
    generation: u64,
    epoch: u64,
    drift: f64,
    converged: bool,
    state: PeerState,
}

impl GlobalView {
    /// Gossip rounds executed when this view was published.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Restart generations so far (bumped whenever a service published a
    /// newer epoch, or a partner node reported a newer generation, and
    /// the protocol restarted).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Service epoch this member's local state was seeded from (0 for
    /// static/remote members and before the first epoch).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Largest relative drift of the probe-quantile estimates between
    /// the last two rounds (∞ until two comparable rounds exist).
    pub fn drift(&self) -> f64 {
        self.drift
    }

    /// True once the drift fell to the configured threshold or below
    /// without an intervening reseed.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The member's averaged protocol state.
    pub fn state(&self) -> &PeerState {
        &self.state
    }

    /// Estimated fleet size `p̃ = round(1/q̃)` (Algorithm 6).
    pub fn estimated_peers(&self) -> f64 {
        self.state.estimated_peers()
    }

    /// Estimated union-stream length `Ñ = round(p̃ · Ñ_l)`.
    pub fn estimated_total(&self) -> f64 {
        self.state.estimated_total()
    }

    /// Estimate the q-quantile of the **union** stream (Algorithm 6).
    pub fn query(&self, q: f64) -> Result<f64, SketchError> {
        self.state.query(q)
    }

    /// Batch union-stream quantile queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.state.query(q)).collect()
    }
}

impl QuantileReader for GlobalView {
    fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        self.state.query(q)
    }

    fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        self.state.cdf(x)
    }

    /// The estimated union-stream length (∞ before any information from
    /// the distinguished peer arrives).
    fn count(&self) -> f64 {
        self.estimated_total()
    }

    fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        GlobalView::quantiles(self, qs)
    }

    /// Overridden: `count()` can be ∞ before the distinguished peer's
    /// mass arrives, so emptiness is judged by the averaged sketch — the
    /// same condition under which [`GlobalView::query`] returns
    /// [`SketchError::Empty`].
    fn is_empty(&self) -> bool {
        self.state.sketch.is_empty()
    }
}

/// Telemetry for one executed loop round.
#[derive(Debug, Clone, Copy)]
pub struct GossipRoundReport {
    /// Rounds executed so far (this one included).
    pub round: u64,
    /// Current restart generation.
    pub generation: u64,
    /// True when this round reseeded the local members from fresh
    /// snapshots (local epoch advance, or a newer generation heard from a
    /// partner node).
    pub reseeded: bool,
    /// Why this round restarted the protocol; `None` whenever
    /// [`GossipRoundReport::reseeded`] is false. See [`RestartCause`].
    pub restart_cause: Option<RestartCause>,
    /// True when a local epoch advance was absorbed **in place** by the
    /// restart-free epoch carry — the stale services' additive deltas
    /// were folded into their averaged slots with no reseed and no
    /// generation bump (`docs/PROTOCOL.md` §10).
    pub epoch_carried: bool,
    /// Completed push–pull exchanges this round. An exchange that
    /// recovered from a stale pooled connection by retrying on a fresh
    /// connect counts here, not in `failed`.
    pub exchanges: usize,
    /// Exchanges cancelled this round — transport failures, missed
    /// deadlines, busy or stale partners. Both sides keep their pre-round
    /// state on every one of these (§7.2). Only *unrecovered* failures
    /// count: a stale pooled connection followed by a successful
    /// fresh-connect retry is one successful exchange.
    pub failed: usize,
    /// Wire traffic this round (push + pull frames, codec byte-exact for
    /// in-process exchanges; actual socket bytes for remote ones — delta
    /// frames make this shrink as the fleet converges).
    pub bytes: usize,
    /// Largest relative probe drift vs the previous round (∞ if not yet
    /// comparable).
    pub drift: f64,
    /// Whether the drift is at or below the configured threshold.
    pub converged: bool,
    /// Per-round movement of the transport's connection-pool and
    /// frame-mix counters (reuse/stale/expiry, delta-vs-full pushes) —
    /// all zeros for transports without a pool (in-process). Fleet
    /// dashboards read this instead of pulling
    /// [`PoolStats`](super::PoolStats) from the transport directly.
    pub pool: PoolStats,
    /// Membership-plane telemetry, when this loop runs the dynamic
    /// member set (`None` for static fleets).
    pub membership: Option<MembershipRoundStats>,
    /// Whole-round wall clock (refresh through view publication).
    pub duration: Duration,
    /// Wall clock of the refresh phase (epoch/generation check and, on a
    /// restart, the reseed itself).
    pub refresh_duration: Duration,
    /// Wall clock of the exchange phase — every initiated push–pull,
    /// membership piggyback included.
    pub exchange_duration: Duration,
    /// Wall clock spent in membership anti-entropy. A sub-span of
    /// [`GossipRoundReport::exchange_duration`] (the piggyback runs on
    /// the exchange connections), zero for static fleets.
    pub membership_duration: Duration,
    /// Wall clock of the probe → drift fold → view publication phase.
    pub publish_duration: Duration,
}

/// Why a refresh restarted the protocol (reseed + generation
/// handling), reported in [`GossipRoundReport::restart_cause`]. The
/// discriminants are stable diagnostic codes, machine-checked by
/// `dudd-analyze spec-sync` against the cause table in
/// `docs/PROTOCOL.md` §10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum RestartCause {
    /// A local service published a newer epoch while restart-free
    /// carry is disabled (`gossip_restart_free = false`): the classic
    /// PR 5 epoch-advance restart.
    EpochAdvance = 1,
    /// The membership view re-anchored — under restart-free rules a
    /// dead ↔ non-dead flip (death or resurrection); with restart-free
    /// off, any change of the non-dead member set.
    ViewChange = 2,
    /// A partner reported a newer restart generation (stale-rejection
    /// or inbound frame) and this node adopted it.
    GenerationCatchUp = 3,
    /// A local epoch advance whose additive delta was undefined — the
    /// new summary is not an insert-only extension of the seed (window
    /// eviction, lineage reset) — so the restart-free carry fell back
    /// to a full restart.
    EpochFallback = 4,
}

impl RestartCause {
    /// The cause's stable label value — the `cause` label of the
    /// `dudd_restarts_total` metric family and the `restart_cause`
    /// field of `round` event-log lines.
    pub fn name(self) -> &'static str {
        match self {
            RestartCause::EpochAdvance => "epoch_advance",
            RestartCause::ViewChange => "view_change",
            RestartCause::GenerationCatchUp => "generation_catch_up",
            RestartCause::EpochFallback => "epoch_fallback",
        }
    }
}

/// Outcome of the refresh phase (internal to the round path).
enum RefreshOutcome {
    /// Nothing moved: no restart, no carry.
    Idle,
    /// A pure local epoch advance was absorbed in place by the
    /// restart-free carry.
    Carried,
    /// The protocol restarted: reseed, plus generation handling per
    /// the cause.
    Restarted(RestartCause),
}

/// Per-round membership telemetry
/// ([`GossipRoundReport::membership`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MembershipRoundStats {
    /// Members currently alive (self included).
    pub alive: usize,
    /// Members currently suspect.
    pub suspect: usize,
    /// Tombstones currently held.
    pub dead: usize,
    /// New member ids learned since the last round (joins observed).
    pub joined: usize,
    /// Members that turned suspect since the last round.
    pub suspected: usize,
    /// Members that turned dead since the last round.
    pub died: usize,
    /// Membership-plane wire traffic this round (anti-entropy push +
    /// reply frames), not included in
    /// [`GossipRoundReport::bytes`].
    pub bytes: usize,
    /// This node's member id was claimed by a different address (a
    /// concurrent-join collision lost the merge tie-break); the loop has
    /// stopped initiating exchanges and the node must be rejoined for a
    /// fresh id. See
    /// [`Membership::identity_lost`](super::Membership::identity_lost).
    pub identity_lost: bool,
}

/// Immutable fleet wiring, fixed at [`GossipLoop::start_with`].
struct Fleet {
    cfg: GossipLoopConfig,
    members: Vec<GossipMember>,
    /// `true` where the member's state lives in this loop.
    local: Vec<bool>,
    /// Ascending indices of the local members (slot-lock order).
    local_members: Vec<usize>,
    /// Index of the member inbound exchanges are served against (the
    /// first local member — the node's own identity in a remote fleet).
    serve_member: usize,
    /// Member indices whose probe estimates drive the drift metric:
    /// every local service member, or the serve member in an all-static
    /// fleet.
    probe_members: Vec<usize>,
    graph: Graph,
    transport: Arc<dyn Transport>,
    /// The dynamic membership plane, when this loop draws partners from
    /// a live member table instead of the static member list.
    membership: Option<Arc<Membership>>,
}

/// Mutable round bookkeeping, behind the control lock. Never held
/// across a socket operation (see the module docs' lock order).
struct Ctl {
    rng: Xoshiro256pp,
    /// Trace-id stream for exchange correlation (`docs/PROTOCOL.md`
    /// §2). A **separate** stream from `rng`: drawing ids from the
    /// partner-selection stream would shift its draw sequence and
    /// break bit-exact parity with the simulation engine.
    trace_rng: Xoshiro256pp,
    online: Vec<bool>,
    /// Snapshot epoch each member was last seeded from (0 for
    /// static/remote).
    epochs: Vec<u64>,
    /// The summary each local **service** slot was last reseeded from
    /// or carried to — the baseline the restart-free epoch carry diffs
    /// the next snapshot against (`None` for static/remote members).
    seeds: Vec<Option<GossipSketch>>,
    round: u64,
    generation: u64,
    /// Highest remote generation heard via stale-rejections; adopted at
    /// the next refresh.
    pending_generation: u64,
    prev_probes: Option<Vec<f64>>,
    drift: f64,
    converged: bool,
    /// Last round's cumulative transport counters (diffed into the
    /// per-round [`GossipRoundReport::pool`] telemetry).
    prev_pool: PoolStats,
}

/// Everything the loop, its background threads, and the transport's
/// serve side share. See the module docs for the lock order.
struct LoopCore {
    fleet: Fleet,
    /// The node's metric handles. What a round moves lands here as it
    /// happens; [`GossipRoundReport`] is the per-round *diff* of these
    /// counters (one source of truth — the gate serializes rounds, so
    /// the diff is exactly one round's work).
    obs: NodeMetrics,
    /// Nanoseconds the in-flight round has spent in membership
    /// anti-entropy, accumulated inside the exchange phase and drained
    /// by `run_round` (the sub-span can't be timed from outside: it
    /// interleaves with the data exchanges on the same connections).
    membership_nanos: AtomicU64,
    /// Initiator-side exchange spans recorded by the in-flight round
    /// (one per attempted exchange, failures included) and drained into
    /// the round's [`RoundTrace`] by `run_round`. Leaf lock: taken with
    /// no other lock held, never nested.
    round_spans: Mutex<Vec<ExchangeSpan>>,
    /// Per-member state locks (the PR 4 split of the old worker mutex).
    slots: Vec<Mutex<PeerState>>,
    ctl: Mutex<Ctl>,
    /// Serializes whole rounds; serves never take it.
    round_gate: Mutex<()>,
    /// Cached overlay graph over the live member view (membership nodes
    /// with a non-complete `GraphKind`; `None` until first built or on
    /// static fleets). Rebuilt whenever the non-dead id set changes.
    overlay: Mutex<Option<OverlayCache>>,
    views: Vec<ArcSwapCell<GlobalView>>,
    stop: AtomicBool,
}

/// One overlay build over a concrete live member set: the sorted
/// non-dead ids the graph was generated for, and the graph itself
/// (vertex `i` ↔ `ids[i]`). Every node derives the same generator rng
/// from `(cfg.seed, id set)`, so all nodes that agree on the view agree
/// on the overlay — no coordination, same property the static fleet got
/// from sharing one seed.
struct OverlayCache {
    ids: Vec<u64>,
    graph: Graph,
}

/// Why an inbound exchange was refused (serve side of §7.2 — the
/// initiator keeps its pre-round state on every variant).
#[derive(Debug)]
pub enum ServeReject {
    /// The node is mid-exchange on the contended slot; the initiator
    /// retries next round.
    Busy,
    /// The push carried an older restart generation than ours (the
    /// payload — the initiator reseeds and catches up).
    StaleGeneration(u64),
    /// α₀ lineage mismatch: these nodes can never merge.
    Lineage,
    /// The reply could not be delivered; the serve-side state change was
    /// rolled back (cancelled exchange).
    Cancelled(String),
    /// A membership or join frame reached a node whose loop runs a
    /// static member list (no membership plane).
    NoMembership,
}

impl std::fmt::Display for ServeReject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeReject::Busy => write!(f, "busy"),
            ServeReject::StaleGeneration(g) => write!(f, "stale generation (ours is {g})"),
            ServeReject::Lineage => write!(f, "alpha0 lineage mismatch"),
            ServeReject::Cancelled(e) => write!(f, "reply delivery failed: {e}"),
            ServeReject::NoMembership => write!(f, "membership plane not enabled"),
        }
    }
}

/// The serve-side handle a [`Transport`] serve loop uses to apply
/// inbound exchanges to this node's state. Cheap to clone; opaque —
/// custom transports interact with the loop only through
/// [`NodeHandle::serve_exchange`] and [`NodeHandle::stopping`].
#[derive(Clone)]
pub struct NodeHandle {
    core: Arc<LoopCore>,
}

impl std::fmt::Debug for NodeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodeHandle(stopping={})", self.stopping())
    }
}

impl NodeHandle {
    /// True once the loop is shutting down; server threads must exit.
    pub fn stopping(&self) -> bool {
        self.core.stop.load(Ordering::SeqCst)
    }

    /// Apply one inbound push–pull atomically: average `incoming` (sent
    /// at restart generation `generation`) into the node's serve member
    /// and hand the averaged reply to `deliver`. The state change
    /// **commits only if `deliver` returns `Ok`** — the §7.2 contract:
    /// a reply that never reaches the initiator rolls the serve side
    /// back, so a cancelled exchange leaves both nodes at their
    /// pre-round state.
    ///
    /// Never blocks: the local member slots are **try**-locked, so a
    /// node mid-push–pull on its own slot yields [`ServeReject::Busy`]
    /// instead of queueing (the initiator counts a failed exchange and
    /// retries next round), which also makes cross-node deadlock
    /// impossible. A round merely *waiting on a dead peer's connect
    /// deadline* holds no slot, so serves keep landing (PR 4).
    pub fn serve_exchange(
        &self,
        incoming: PeerState,
        generation: u64,
        deliver: impl FnOnce(&PeerState, u64) -> std::io::Result<()>,
    ) -> Result<(), ServeReject> {
        self.core.serve_exchange(incoming, generation, deliver)
    }

    /// Serve one inbound membership anti-entropy push: merge `incoming`
    /// into the node's member table and return `(merged table, our
    /// restart generation)` for the reply. A push tagged with a newer
    /// generation schedules a catch-up reseed at the loop's next
    /// refresh. Fails with [`ServeReject::NoMembership`] on a
    /// static-member-list node. Never blocks on the member slots.
    pub fn serve_membership(
        &self,
        incoming: &MemberTable,
        generation: u64,
    ) -> Result<(MemberTable, u64), ServeReject> {
        self.core.serve_membership(incoming, generation)
    }

    /// Serve one `dudd-join` handshake: assign `addr` a stable member id
    /// and return `(full table, our restart generation)` for the reply.
    /// Fails with [`ServeReject::NoMembership`] on a static node.
    pub fn serve_join(&self, addr: SocketAddr) -> Result<(MemberTable, u64), ServeReject> {
        self.core.serve_join(addr)
    }

    /// True when this node exports an event log — the transport's serve
    /// path only assembles serve-side [`ExchangeSpan`]s when something
    /// consumes them.
    pub(crate) fn serve_tracing(&self) -> bool {
        self.core.obs.export.get().is_some()
    }

    /// Record one serve-side exchange span into the node's event log
    /// (no-op without one). Lock-free — the serve hot path reads only
    /// the rounds counter, never `ctl`.
    pub(crate) fn record_serve_span(&self, span: ExchangeSpan) {
        if let Some(sink) = self.core.obs.export.get() {
            sink.emit_exchange(self.core.obs.gossip.rounds.get(), &span);
        }
    }
}

/// A background gossip task over a fleet of services, simulated peers,
/// and remote nodes.
///
/// With `round_interval_ms > 0` a thread runs one round per interval;
/// [`GossipLoop::step`] additionally (or, at interval 0, exclusively)
/// runs rounds on demand — handy for deterministic tests and for the
/// `serve-gossip`/`serve-remote` CLIs' per-round reporting.
///
/// [`GossipLoop::start`] runs the fleet in process, exactly as PR 2 did
/// (the [`InProcessTransport`] reproduces those results bit for bit);
/// [`GossipLoop::start_with`] accepts any [`Transport`]. The primary
/// construction path is [`Node::builder()`](super::Node::builder).
///
/// ```
/// use duddsketch::config::GossipLoopConfig;
/// use duddsketch::service::{GossipLoop, GossipMember};
///
/// // Two simulated peers, each holding half of 1..=1000.
/// let lo: Vec<f64> = (1..=500).map(f64::from).collect();
/// let hi: Vec<f64> = (501..=1000).map(f64::from).collect();
/// let members = vec![
///     GossipMember::from_dataset(&lo, 0.001, 1024).unwrap(),
///     GossipMember::from_dataset(&hi, 0.001, 1024).unwrap(),
/// ];
/// let gl = GossipLoop::start(GossipLoopConfig::default(), members).unwrap();
/// gl.step(); // one exchange fully averages a 2-peer fleet
/// let view = gl.view();
/// let p50 = view.query(0.5).unwrap();
/// assert!((p50 - 500.0).abs() / 500.0 <= 0.001 + 1e-9);
/// assert_eq!(view.estimated_peers(), 2.0);
/// gl.shutdown();
/// ```
pub struct GossipLoop {
    core: Arc<LoopCore>,
    thread: Option<JoinHandle<()>>,
    server: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for GossipLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let v = self.view();
        write!(
            f,
            "GossipLoop(members={}, transport={}, round={}, generation={}, converged={})",
            self.core.slots.len(),
            self.core.fleet.transport.name(),
            v.round(),
            v.generation(),
            v.converged()
        )
    }
}

impl GossipLoop {
    /// [`GossipLoop::start_with`] on the [`InProcessTransport`] — PR 2's
    /// in-process fleet, byte-identical results.
    pub fn start(cfg: GossipLoopConfig, members: Vec<GossipMember>) -> Result<Self> {
        Self::start_with(cfg, members, Arc::new(InProcessTransport))
    }

    /// Validate, seed every local member from its current summary, build
    /// the overlay, publish the round-0 views, spawn the transport's
    /// serve loop (if it has one), and (when an interval is configured)
    /// the background round thread.
    ///
    /// Member index is the peer id — **globally**: a remote fleet lists
    /// every node in the same order everywhere (and shares one gossip
    /// seed/graph so all overlays agree); the member at the node's own
    /// position is its local service. Member 0 plays Algorithm 3's
    /// distinguished role (`q̃ = 1`). Small fleets should keep the
    /// default [`GraphKind::Complete`](crate::config::GraphKind::Complete)
    /// overlay; the simulation topologies carry their own minimum-size
    /// requirements.
    pub fn start_with(
        cfg: GossipLoopConfig,
        members: Vec<GossipMember>,
        transport: Arc<dyn Transport>,
    ) -> Result<Self> {
        Self::start_with_obs(cfg, members, transport, NodeMetrics::standalone())
    }

    /// [`GossipLoop::start_with`] reporting into `obs` — the
    /// [`Node::builder`](super::Node::builder) path, where every layer
    /// of the node shares one registry behind `/metrics`.
    pub(crate) fn start_with_obs(
        cfg: GossipLoopConfig,
        members: Vec<GossipMember>,
        transport: Arc<dyn Transport>,
        obs: NodeMetrics,
    ) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        if members.len() < 2 {
            bail!("gossip loop needs at least 2 members, got {}", members.len());
        }
        let local: Vec<bool> = members.iter().map(GossipMember::is_local).collect();
        let serve_member = local
            .iter()
            .position(|&b| b)
            .context("gossip loop needs at least one local member (service or static)")?;
        if local.iter().any(|&b| !b) {
            if !transport.supports_remote() {
                bail!(
                    "fleet lists remote members but the {} transport cannot reach \
                     them — use a remote-capable transport (e.g. TcpTransport)",
                    transport.name()
                );
            }
            // Inbound exchanges are served against the node's own member
            // (the push frame carries no target id), and a Static member
            // listed on several nodes would be independently mutated by
            // each — either way the generation's q̃ mass breaks. A remote
            // fleet therefore hosts exactly one local member per node;
            // simulated Static peers belong to in-process fleets.
            let locals = local.iter().filter(|&&b| b).count();
            if locals != 1 {
                bail!(
                    "a fleet with remote members must have exactly one local \
                     member (this node's own identity), found {locals}"
                );
            }
        }
        // Exchanges merge sketches, and merges require one shared α₀
        // lineage — catch a mismatched fleet here instead of panicking
        // mid-round. Remote members are checked at exchange time by the
        // frame protocol.
        let mut alpha0: Option<f64> = None;
        let mut lineage: Option<(f64, usize)> = None;
        for (i, m) in members.iter().enumerate() {
            let (a, mb) = match m {
                GossipMember::Service(svc) => (svc.config().alpha, svc.config().max_buckets),
                GossipMember::Static(sketch) => {
                    (sketch.mapping().alpha0(), sketch.max_buckets())
                }
                GossipMember::Remote(_) => continue,
            };
            match alpha0 {
                None => {
                    alpha0 = Some(a);
                    lineage = Some((a, mb));
                }
                Some(first) if first.to_bits() != a.to_bits() => bail!(
                    "gossip members must share one alpha0 lineage: \
                     member {serve_member} has {first}, member {i} has {a}"
                ),
                Some(_) => {}
            }
        }
        let (alpha, max_buckets) = lineage.expect("at least one local member");

        let n = members.len();
        let master = default_rng(cfg.seed);
        let mut grng = master.derive(0x6EA4);
        let graph = crate::graph::from_kind(cfg.graph, n, &mut grng);
        let interval_ms = cfg.round_interval_ms;
        let local_members: Vec<usize> = local
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i)
            .collect();
        let probe_members: Vec<usize> = {
            let svc: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(_, m)| matches!(m, GossipMember::Service(_)))
                .map(|(i, _)| i)
                .collect();
            if svc.is_empty() {
                vec![serve_member]
            } else {
                svc
            }
        };
        // Placeholder states for every slot (remote slots keep theirs —
        // their real state lives on the remote node); the seed loop below
        // fills the local ones.
        let blank: GossipSketch =
            UddSketch::new(alpha, max_buckets).map_err(anyhow::Error::msg)?;
        let mut states: Vec<PeerState> = (0..n)
            .map(|i| PeerState {
                id: i,
                sketch: blank.clone(),
                n_tilde: 0.0,
                q_tilde: 0.0,
            })
            .collect();
        let mut epochs = vec![0u64; n];
        let mut seeds: Vec<Option<GossipSketch>> = vec![None; n];
        for (i, m) in members.iter().enumerate() {
            match m {
                GossipMember::Service(svc) => {
                    let snap = svc.snapshot();
                    epochs[i] = snap.epoch();
                    let seed: GossipSketch = snap.sketch().convert_store();
                    states[i] = PeerState::from_sketch(i, &seed);
                    seeds[i] = Some(seed);
                }
                GossipMember::Static(sketch) => {
                    states[i] = PeerState::from_sketch(i, sketch);
                }
                GossipMember::Remote(_) => {}
            }
        }
        let ctl = Ctl {
            rng: master.derive(0x1005),
            trace_rng: master.derive(0x7ACE),
            online: vec![true; n],
            epochs,
            seeds,
            round: 0,
            generation: 1,
            pending_generation: 0,
            prev_probes: None,
            drift: f64::INFINITY,
            converged: false,
            prev_pool: PoolStats::default(),
        };
        let views: Vec<ArcSwapCell<GlobalView>> = states
            .iter()
            .enumerate()
            .map(|(i, s)| {
                ArcSwapCell::new(Arc::new(GlobalView {
                    round: 0,
                    generation: 1,
                    epoch: ctl.epochs[i],
                    drift: f64::INFINITY,
                    converged: false,
                    state: s.clone(),
                }))
            })
            .collect();
        let core = Arc::new(LoopCore {
            fleet: Fleet {
                cfg,
                members,
                local,
                local_members,
                serve_member,
                probe_members,
                graph,
                transport: transport.clone(),
                membership: None,
            },
            obs,
            membership_nanos: AtomicU64::new(0),
            round_spans: Mutex::new(Vec::new()),
            slots: states.into_iter().map(Mutex::new).collect(),
            ctl: Mutex::new(ctl),
            round_gate: Mutex::new(()),
            overlay: Mutex::new(None),
            views,
            stop: AtomicBool::new(false),
        });
        Self::spawn(core, &transport, interval_ms)
    }

    /// Start a **dynamic-membership** node: one local service whose
    /// exchange partners are drawn each round from the live member view
    /// (`membership`) instead of a static member list. This is the
    /// churn-first construction path (§7.2 made a runtime scenario):
    ///
    /// * partner selection draws from the table's alive members (plus
    ///   backoff-gated probes of suspects); dead members are skipped
    ///   entirely;
    /// * failed exchanges feed the suspicion clocks, replies of any kind
    ///   clear them;
    /// * after each data exchange the initiator piggybacks one
    ///   membership anti-entropy push–pull on the same (pooled)
    ///   connection;
    /// * under restart-free churn (the default), a **join** admits the
    ///   new member into the *current* generation with `q̃ = 0` — no
    ///   restart — and only a **dead ↔ non-dead flip** restarts the
    ///   protocol (generation bump + reseed-from-own-summary), with the
    ///   *distinguished* `q̃ = 1` role re-anchored on the lowest
    ///   non-dead id, so the generation's mass stays exactly 1 across
    ///   churn; with
    ///   [`GossipLoopConfig::restart_free`](crate::config::GossipLoopConfig::restart_free)
    ///   off, any change of the non-dead member set restarts (PR 5
    ///   rule).
    ///
    /// The transport must be remote-capable and bound on the address the
    /// membership table advertises for this node. `initial_generation`
    /// is the restart generation to start at — the seed's, as returned
    /// by the join handshake, so a joiner's first exchanges are not
    /// rejected `StaleGeneration` (bootstrap nodes pass 1). Construction
    /// normally goes through
    /// [`NodeBuilder::membership_bootstrap`](super::NodeBuilder::membership_bootstrap)
    /// / [`NodeBuilder::join`](super::NodeBuilder::join).
    pub fn start_membership(
        cfg: GossipLoopConfig,
        service: Arc<QuantileService>,
        transport: Arc<dyn Transport>,
        membership: Arc<Membership>,
        initial_generation: u64,
    ) -> Result<Self> {
        Self::start_membership_obs(
            cfg,
            GossipMember::Service(service),
            transport,
            membership,
            initial_generation,
            NodeMetrics::standalone(),
        )
    }

    /// [`GossipLoop::start_membership`] for an arbitrary **local**
    /// member. A [`GossipMember::Static`] member here is a node whose
    /// summary is a fixed pre-built sketch instead of a live ingest
    /// service — the simulator's per-node shape, where a thousand
    /// members in one process cannot each afford a shard/coordinator
    /// thread pool. [`GossipMember::Remote`] is rejected (a membership
    /// node's own member must live on the node).
    pub fn start_membership_member(
        cfg: GossipLoopConfig,
        member: GossipMember,
        transport: Arc<dyn Transport>,
        membership: Arc<Membership>,
        initial_generation: u64,
    ) -> Result<Self> {
        Self::start_membership_obs(
            cfg,
            member,
            transport,
            membership,
            initial_generation,
            NodeMetrics::standalone(),
        )
    }

    /// [`GossipLoop::start_membership`] reporting into `obs` (the
    /// builder path — see [`GossipLoop::start_with_obs`]).
    pub(crate) fn start_membership_obs(
        cfg: GossipLoopConfig,
        member: GossipMember,
        transport: Arc<dyn Transport>,
        membership: Arc<Membership>,
        initial_generation: u64,
        obs: NodeMetrics,
    ) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        if !member.is_local() {
            bail!("a membership node's own member must be local (service or static)");
        }
        if !transport.supports_remote() {
            bail!(
                "dynamic membership needs a remote-capable transport, got {}",
                transport.name()
            );
        }
        match transport.listen_addr() {
            Some(addr) if addr == membership.self_addr() => {}
            Some(addr) => bail!(
                "membership table advertises {} for this node but the \
                 transport serves on {addr}",
                membership.self_addr()
            ),
            None => bail!(
                "dynamic membership needs a serving transport (partners must \
                 be able to exchange back) — bind the transport first"
            ),
        }
        let self_id = membership.self_id();
        let (mut state, epoch, seed) = match &member {
            GossipMember::Service(svc) => {
                let snap = svc.snapshot();
                let seed: GossipSketch = snap.sketch().convert_store();
                let st = PeerState::from_sketch(self_id as usize, &seed);
                (st, snap.epoch(), Some(seed))
            }
            GossipMember::Static(sketch) => {
                (PeerState::from_sketch(self_id as usize, sketch), 0, None)
            }
            GossipMember::Remote(_) => unreachable!("checked local above"),
        };
        // Joiner rule (PROTOCOL §10): under restart-free churn a node
        // entering an existing fleet starts with `q̃ = 0` — zero mass
        // in, zero mass moved, so the running generation's Σq̃ = 1
        // invariant holds with no restart at all. Only a true bootstrap
        // (sole non-dead member in its own table) anchors the
        // distinguished `q̃ = 1`. This also covers a low-id node
        // rejoining fast enough to still be Alive in the survivors'
        // tables: it may be *distinguished*, but the generation's mass
        // anchor already lives with the survivors, so it must not bring
        // a second unit in. With restart-free off the PR 5 rule stands
        // (distinguished ⇒ `q̃ = 1`): the join itself restarts the
        // fleet, so a transient double anchor cannot survive a round.
        let (alive, suspect, _) = membership.counts();
        state.q_tilde = if membership.is_distinguished()
            && (!cfg.restart_free || alive + suspect <= 1)
        {
            1.0
        } else {
            0.0
        };
        let generation = initial_generation.max(1);
        let master = default_rng(cfg.seed);
        let interval_ms = cfg.round_interval_ms;
        let ctl = Ctl {
            // Derived once more by the node's own id: a membership fleet
            // shares `cfg.seed` (the overlay key), and without this every
            // node would draw the *same* partner-index stream — correlated
            // draws that visibly slow mixing at simulator scale.
            rng: master.derive(0x1005).derive(self_id),
            // Same per-node derivation as `rng` — shared `cfg.seed`
            // with distinct id streams per node.
            trace_rng: master.derive(0x7ACE).derive(self_id),
            online: vec![true],
            epochs: vec![epoch],
            seeds: vec![seed],
            round: 0,
            generation,
            pending_generation: 0,
            prev_probes: None,
            drift: f64::INFINITY,
            converged: false,
            prev_pool: PoolStats::default(),
        };
        let views = vec![ArcSwapCell::new(Arc::new(GlobalView {
            round: 0,
            generation,
            epoch,
            drift: f64::INFINITY,
            converged: false,
            state: state.clone(),
        }))];
        let core = Arc::new(LoopCore {
            fleet: Fleet {
                cfg,
                members: vec![member],
                local: vec![true],
                local_members: vec![0],
                serve_member: 0,
                probe_members: vec![0],
                // Placeholder: dynamic partner selection consults the
                // *overlay cache* (rebuilt over the live member table),
                // never this static graph. With `GraphKind::Complete`
                // the live view itself is the overlay.
                graph: crate::graph::complete(2),
                transport: transport.clone(),
                membership: Some(membership),
            },
            obs,
            membership_nanos: AtomicU64::new(0),
            round_spans: Mutex::new(Vec::new()),
            slots: vec![Mutex::new(state)],
            ctl: Mutex::new(ctl),
            round_gate: Mutex::new(()),
            overlay: Mutex::new(None),
            views,
            stop: AtomicBool::new(false),
        });
        Self::spawn(core, &transport, interval_ms)
    }

    /// Spawn the transport's serve loop and (with an interval) the
    /// background round thread — the shared tail of both constructors.
    fn spawn(
        core: Arc<LoopCore>,
        transport: &Arc<dyn Transport>,
        interval_ms: u64,
    ) -> Result<Self> {
        // Hand the lower layers their metric handles before any traffic
        // flows (both sides hold write-once slots, so a transport shared
        // across loops keeps the first bundle it was given).
        transport.install_metrics(core.obs.transport.clone());
        if let Some(m) = &core.fleet.membership {
            m.install_metrics(core.obs.membership.clone());
        }
        core.obs
            .gossip
            .generation
            .set(core.lock_ctl().generation as f64);
        let server = transport.spawn_server(NodeHandle { core: core.clone() })?;
        let thread = if interval_ms > 0 {
            let core = core.clone();
            let interval = Duration::from_millis(interval_ms);
            Some(
                std::thread::Builder::new()
                    .name("dudd-gossip".into())
                    .spawn(move || round_loop(&core, interval))
                    .context("spawning gossip loop thread")?,
            )
        } else {
            None
        };
        Ok(Self {
            core,
            thread,
            server,
        })
    }

    /// Number of members in the fleet (local + remote).
    pub fn members(&self) -> usize {
        self.core.slots.len()
    }

    /// The transport carrying this loop's exchanges.
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.core.fleet.transport
    }

    /// The membership runtime, when this loop runs the dynamic member
    /// set ([`GossipLoop::start_membership`]); `None` for static fleets.
    pub fn membership(&self) -> Option<&Arc<Membership>> {
        self.core.fleet.membership.as_ref()
    }

    /// The address this loop's transport serves inbound exchanges on
    /// (None for in-process or client-only transports).
    pub fn listen_addr(&self) -> Option<SocketAddr> {
        self.core.fleet.transport.listen_addr()
    }

    /// The metric-handle bundle this loop reports into: cumulative
    /// counters, gauges, latency histograms, and the round-trace ring
    /// ([`NodeMetrics::trace`]). Loops built directly get a standalone
    /// bundle on a private registry; loops built through
    /// [`Node::builder`](super::Node::builder) share the node-wide
    /// registry served at `/metrics`.
    pub fn metrics(&self) -> &NodeMetrics {
        &self.core.obs
    }

    /// Run one refresh → exchange → serve round synchronously and return
    /// its telemetry. Safe alongside the background thread (rounds
    /// serialize on the round gate) and the transport's serve loop
    /// (inbound exchanges contend only on the member slots).
    pub fn step(&self) -> GossipRoundReport {
        self.core.run_round()
    }

    /// The latest global view of the serve member — the first local
    /// member, i.e. the node's own identity (member 0 in an all-local
    /// fleet, as in PR 2). Lock-free.
    pub fn view(&self) -> Arc<GlobalView> {
        self.member_view(self.core.fleet.serve_member)
    }

    /// The latest global view of member `i`. Lock-free. For
    /// [`GossipMember::Remote`] members this node publishes only a
    /// placeholder (their real views live on their own node).
    pub fn member_view(&self, i: usize) -> Arc<GlobalView> {
        self.core.views[i].load()
    }

    /// The serve-side handle (what [`Transport::spawn_server`] receives).
    #[cfg(test)]
    fn handle(&self) -> NodeHandle {
        NodeHandle {
            core: self.core.clone(),
        }
    }

    /// Stop the background threads (round + serve loop, if any) and
    /// return the final view of the serve member.
    pub fn shutdown(mut self) -> Arc<GlobalView> {
        self.stop_thread();
        self.view()
    }

    fn stop_thread(&mut self) {
        self.core.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.server.take() {
            let _ = t.join();
        }
    }
}

impl Drop for GossipLoop {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

/// Background driver: one round per interval, stop-aware in ≤10 ms
/// steps so shutdown never waits out a long interval.
fn round_loop(core: &LoopCore, interval: Duration) {
    let step = Duration::from_millis(10).min(interval);
    'outer: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if core.stop.load(Ordering::SeqCst) {
                break 'outer;
            }
            let d = step.min(interval - slept);
            std::thread::sleep(d);
            slept += d;
        }
        if core.stop.load(Ordering::SeqCst) {
            break;
        }
        core.run_round();
    }
}

/// The span outcome label of a failed initiated exchange: protocol
/// refusals map to `reject:<reason>` (mirroring the serve side's
/// labels), everything else to an `error:<kind>` class.
fn failure_outcome(e: &TransportError) -> &'static str {
    match e {
        TransportError::Io(_) => "error:io",
        TransportError::StaleChannel(_) => "error:stale_channel",
        TransportError::Codec(_) => "error:codec",
        TransportError::Busy => "reject:busy",
        TransportError::StaleGeneration(_) => "reject:stale_generation",
        TransportError::Protocol(_) => "error:protocol",
        TransportError::Lineage(_) => "reject:lineage",
        TransportError::Unreachable(_) => "error:unreachable",
        TransportError::NoMembership => "reject:no_membership",
    }
}

impl LoopCore {
    fn lock_slot(&self, i: usize) -> MutexGuard<'_, PeerState> {
        self.slots[i].lock().expect("gossip member slot poisoned")
    }

    fn lock_ctl(&self) -> MutexGuard<'_, Ctl> {
        self.ctl.lock().expect("gossip control state poisoned")
    }

    /// The round gate is the outermost lock: one guard per round, never
    /// nested inside any other acquisition.
    fn lock_gate(&self) -> MutexGuard<'_, ()> {
        self.round_gate.lock().expect("gossip round gate poisoned")
    }

    fn lock_overlay(&self) -> MutexGuard<'_, Option<OverlayCache>> {
        self.overlay.lock().expect("overlay cache poisoned")
    }

    /// Lock every local slot in ascending index order (round paths only;
    /// serves use `try_lock`).
    fn lock_local_slots(&self) -> Vec<MutexGuard<'_, PeerState>> {
        self.fleet
            .local_members
            .iter()
            .map(|&i| self.lock_slot(i))
            .collect()
    }

    /// True when any local service member has published an epoch newer
    /// than the one its state was seeded from.
    fn any_stale(&self, ctl: &Ctl) -> bool {
        self.fleet.members.iter().enumerate().any(|(i, m)| match m {
            GossipMember::Service(svc) => svc.snapshot().epoch() != ctl.epochs[i],
            _ => false,
        })
    }

    /// Seed every **local** member's slot from its current local summary
    /// and reset the drift bookkeeping. The caller holds every local
    /// slot (ascending) plus `ctl` — restarting all local members
    /// together keeps the generation's `q̃` mass exact (see the module
    /// docs); remote members restart on their own nodes, carried by the
    /// generation tags.
    fn reseed_locked(&self, ctl: &mut Ctl, guards: &mut [MutexGuard<'_, PeerState>]) {
        for (k, &i) in self.fleet.local_members.iter().enumerate() {
            match &self.fleet.members[i] {
                GossipMember::Service(svc) => {
                    let snap = svc.snapshot();
                    ctl.epochs[i] = snap.epoch();
                    let seed: GossipSketch = snap.sketch().convert_store();
                    *guards[k] = match &self.fleet.membership {
                        // Dynamic member set: the peer id is the stable
                        // membership id and the distinguished `q̃ = 1`
                        // role belongs to the lowest non-dead id in the
                        // current view (not hard-wired to id 0, which
                        // may have died).
                        Some(m) => {
                            let mut st =
                                PeerState::from_sketch(m.self_id() as usize, &seed);
                            st.q_tilde = if m.is_distinguished() { 1.0 } else { 0.0 };
                            st
                        }
                        None => PeerState::from_sketch(i, &seed),
                    };
                    ctl.seeds[i] = Some(seed);
                }
                GossipMember::Static(sketch) => {
                    *guards[k] = match &self.fleet.membership {
                        // Same dynamic identity rules as the Service arm
                        // (the simulator's nodes are Static members).
                        Some(m) => {
                            let mut st =
                                PeerState::from_sketch(m.self_id() as usize, sketch);
                            st.q_tilde = if m.is_distinguished() { 1.0 } else { 0.0 };
                            st
                        }
                        None => PeerState::from_sketch(i, sketch),
                    };
                }
                GossipMember::Remote(_) => {
                    unreachable!("local_members holds only local indices")
                }
            }
        }
        ctl.prev_probes = None;
        ctl.drift = f64::INFINITY;
        ctl.converged = false;
    }

    /// Refresh step: decide between doing nothing, the restart-free
    /// epoch carry, and a full protocol restart.
    ///
    /// A restart happens when a partner reported a newer generation
    /// (adopt it), the membership view re-anchored (under restart-free
    /// rules a dead ↔ non-dead flip; any non-dead-set change
    /// otherwise), or local data moved while restart-free carry is off
    /// or inapplicable. A *pure* local epoch advance under restart-free
    /// rules instead folds each stale service's additive delta into its
    /// averaged slot in place — no reseed, no generation bump
    /// (`docs/PROTOCOL.md` §10).
    fn refresh(&self) -> RefreshOutcome {
        // Cheap peek without slot locks; the decisive check repeats
        // under the full locks (a concurrent serve may have caught the
        // generation up in between).
        let view_peek = self
            .fleet
            .membership
            .as_ref()
            .is_some_and(|m| m.view_change_pending());
        let needed = view_peek || {
            let ctl = self.lock_ctl();
            self.any_stale(&ctl) || ctl.pending_generation > ctl.generation
        };
        if !needed {
            return RefreshOutcome::Idle;
        }
        let mut guards = self.lock_local_slots();
        let mut ctl = self.lock_ctl();
        let wanted = std::mem::take(&mut ctl.pending_generation);
        let stale = self.any_stale(&ctl);
        let view_changed = self
            .fleet
            .membership
            .as_ref()
            .is_some_and(|m| m.take_view_changed());
        if !stale && !view_changed && wanted <= ctl.generation {
            return RefreshOutcome::Idle;
        }
        if self.fleet.cfg.restart_free
            && stale
            && !view_changed
            && wanted <= ctl.generation
        {
            // Pure epoch advance: carry instead of restarting.
            if self.try_epoch_carry(&mut ctl, &mut guards) {
                return RefreshOutcome::Carried;
            }
            // Some delta was undefined (window eviction, lineage
            // reset, …): fall back to the full restart. The reseed
            // below also repairs any partially applied carry — it
            // overwrites every local slot from fresh snapshots.
            self.reseed_locked(&mut ctl, &mut guards);
            ctl.generation = ctl.generation.saturating_add(1).max(wanted);
            return RefreshOutcome::Restarted(RestartCause::EpochFallback);
        }
        let cause = if view_changed {
            RestartCause::ViewChange
        } else if stale && !self.fleet.cfg.restart_free {
            RestartCause::EpochAdvance
        } else {
            RestartCause::GenerationCatchUp
        };
        self.reseed_locked(&mut ctl, &mut guards);
        // Saturating: a (hostile or corrupt) partner could have pushed the
        // generation near u64::MAX — the counter must never overflow-panic
        // mid-round or wrap back to 0 (which would read as "stale" to the
        // whole fleet). Frame authentication is the real fix (ROADMAP).
        let bumped = if stale || view_changed {
            ctl.generation.saturating_add(1)
        } else {
            ctl.generation
        };
        ctl.generation = bumped.max(wanted);
        RefreshOutcome::Restarted(cause)
    }

    /// Attempt the restart-free epoch carry: for every local service
    /// whose published epoch moved past the one its slot was seeded
    /// from, diff the new snapshot against the seed summary retained in
    /// [`Ctl::seeds`] ([`UddSketch::additive_delta`]) and fold the
    /// delta into the averaged slot
    /// ([`PeerState::carry_epoch_delta`]). Returns `false` when any
    /// seed is missing or any delta is undefined — the caller then
    /// falls back to a full reseed + generation bump, which overwrites
    /// every local slot and thereby also repairs a partially applied
    /// carry.
    fn try_epoch_carry(
        &self,
        ctl: &mut Ctl,
        guards: &mut [MutexGuard<'_, PeerState>],
    ) -> bool {
        for (k, &i) in self.fleet.local_members.iter().enumerate() {
            let svc = match &self.fleet.members[i] {
                GossipMember::Service(svc) => svc,
                _ => continue,
            };
            let snap = svc.snapshot();
            if snap.epoch() == ctl.epochs[i] {
                continue;
            }
            let new: GossipSketch = snap.sketch().convert_store();
            let delta = match ctl.seeds[i].as_ref().and_then(|s| new.additive_delta(s)) {
                Some(d) => d,
                None => return false,
            };
            if guards[k].carry_epoch_delta(&delta).is_err() {
                return false;
            }
            ctl.epochs[i] = snap.epoch();
            ctl.seeds[i] = Some(new);
        }
        true
    }

    /// Probe-quantile estimates across the probe members, or `None`
    /// while any probe member cannot answer yet (empty sketch).
    fn probes(&self) -> Option<Vec<f64>> {
        let mut out = Vec::with_capacity(
            self.fleet.probe_members.len() * self.fleet.cfg.probe_quantiles.len(),
        );
        for &i in &self.fleet.probe_members {
            let guard = self.lock_slot(i);
            for &q in &self.fleet.cfg.probe_quantiles {
                match guard.query(q) {
                    Ok(v) => out.push(v),
                    Err(_) => return None,
                }
            }
        }
        Some(out)
    }

    /// Draw the next nonzero exchange trace id (`docs/PROTOCOL.md` §2:
    /// 0 on the wire means *untraced*). Dedicated rng stream — see
    /// [`Ctl::trace_rng`].
    fn next_trace_id(&self) -> u64 {
        let mut ctl = self.lock_ctl();
        loop {
            let id = ctl.trace_rng.next_u64();
            if id != 0 {
                return id;
            }
        }
    }

    /// Record one initiator-side span of the in-flight round (drained
    /// into the [`RoundTrace`] by `run_round`). Called with no other
    /// lock held.
    fn record_span(&self, span: ExchangeSpan) {
        self.round_spans
            .lock()
            .expect("round span buffer poisoned")
            .push(span);
    }

    /// One push–pull with partner `j`, initiated by local member `l`.
    /// Remote exchanges run in the transport's two phases so the connect
    /// deadline burns with no slot held; a stale pooled connection gets
    /// exactly one fresh-connect retry (only unrecovered failures reach
    /// the round report). Every attempt — local, remote, failed —
    /// records one [`ExchangeSpan`] for the round trace.
    fn one_exchange(&self, l: usize, j: usize) -> Result<usize, TransportError> {
        if self.fleet.local[j] {
            let trace_id = self.next_trace_id();
            // Both slots co-located: lock in ascending index order
            // (servers only try-lock, so blocking here cannot deadlock).
            let lo = l.min(j);
            let hi = l.max(j);
            let started = Instant::now();
            let result = {
                let mut g_lo = self.lock_slot(lo);
                let mut g_hi = self.lock_slot(hi);
                let (a, b) = if l < j {
                    (&mut *g_lo, &mut *g_hi)
                } else {
                    (&mut *g_hi, &mut *g_lo)
                };
                self.fleet.transport.exchange_local(a, b)
            };
            let push = started.elapsed();
            let generation = self.lock_ctl().generation;
            let (bytes, outcome) = match &result {
                Ok(b) => (*b, "ok"),
                Err(e) => (0, failure_outcome(e)),
            };
            self.record_span(ExchangeSpan {
                trace_id,
                initiator: true,
                peer: format!("member:{j}"),
                generation,
                kind: "local",
                bytes,
                outcome,
                connect: Duration::ZERO,
                push,
                reply: Duration::ZERO,
                commit: Duration::ZERO,
            });
            result
        } else {
            let addr = match &self.fleet.members[j] {
                GossipMember::Remote(addr) => *addr,
                _ => unreachable!("non-local member is remote by construction"),
            };
            self.remote_exchange(l, addr)
        }
    }

    /// The remote half of [`LoopCore::one_exchange`], addressed
    /// directly — shared by the static member list and the dynamic
    /// membership round. The trace id drawn here rides the push frame,
    /// the partner echoes it in its answer and stamps it on its own
    /// serve-side span, so both ends' event logs join into one causal
    /// record (`docs/PROTOCOL.md` §2).
    fn remote_exchange(&self, l: usize, addr: SocketAddr) -> Result<usize, TransportError> {
        let trace_id = self.next_trace_id();
        // Phase 1 — connect with NO lock held: a dead peer's connect
        // deadline burns here while inbound serves keep landing.
        let connect_start = Instant::now();
        let chan = match self.fleet.transport.open_remote(addr) {
            Ok(chan) => chan,
            Err(e) => {
                self.record_remote_failure(trace_id, addr, connect_start.elapsed(), &e);
                return Err(e);
            }
        };
        let connect = connect_start.elapsed();
        // Phase 2 — push–pull holding only our own slot.
        let result = {
            let mut guard = self.lock_slot(l);
            let gen = self.lock_ctl().generation;
            let first = self
                .fleet
                .transport
                .exchange_traced(chan, &mut guard, gen, trace_id);
            match first {
                Err(TransportError::StaleChannel(_)) => {
                    // The pooled connection was dead before any reply
                    // byte (see `TransportError::StaleChannel` for the
                    // safety argument). Release the slot, open a fresh
                    // connection, retry once.
                    drop(guard);
                    let retry_start = Instant::now();
                    match self.fleet.transport.open_remote(addr) {
                        Ok(chan) => {
                            let retry_connect = connect + retry_start.elapsed();
                            let mut guard = self.lock_slot(l);
                            let gen = self.lock_ctl().generation;
                            self.fleet
                                .transport
                                .exchange_traced(chan, &mut guard, gen, trace_id)
                                .map(|o| (o, retry_connect))
                        }
                        Err(e) => Err(e),
                    }
                }
                r => r.map(|o| (o, connect)),
            }
        };
        match result {
            Ok((outcome, connect)) => {
                if let Some(mut span) = outcome.span {
                    // The transport cannot see the pre-exchange connect
                    // phase; the loop measured it.
                    span.connect = connect;
                    self.record_span(span);
                }
                Ok(outcome.bytes)
            }
            Err(e) => {
                self.record_remote_failure(trace_id, addr, connect, &e);
                Err(e)
            }
        }
    }

    /// Synthesize and record the initiator-side span of a remote
    /// exchange the transport could not complete (the transport
    /// returns spans only for committed push–pulls).
    fn record_remote_failure(
        &self,
        trace_id: u64,
        addr: SocketAddr,
        connect: Duration,
        e: &TransportError,
    ) {
        let generation = self.lock_ctl().generation;
        self.record_span(ExchangeSpan {
            trace_id,
            initiator: true,
            peer: addr.to_string(),
            generation,
            kind: "unknown",
            bytes: 0,
            outcome: failure_outcome(e),
            connect,
            push: Duration::ZERO,
            reply: Duration::ZERO,
            commit: Duration::ZERO,
        });
    }

    /// One fan-out push–pull round over the overlay, every partner
    /// interaction through the transport. All randomness is drawn up
    /// front under `ctl` — the identical call sequence to the simulation
    /// engine (permutation, then per-initiator partner draws in
    /// permutation order), which is what keeps the PR 2 parity test
    /// bit-exact — then the exchanges execute with per-slot locking.
    /// Outcomes land directly on the registry counters
    /// (`dudd_exchanges_total` & co.); `run_round` diffs them into the
    /// report.
    fn exchange_round(&self) {
        if let Some(m) = self.fleet.membership.clone() {
            return self.exchange_round_dynamic(&m);
        }
        let p = self.slots.len();
        let plan: Vec<(usize, Vec<usize>)> = {
            let mut ctl = self.lock_ctl();
            let ctl = &mut *ctl;
            let order = ctl.rng.permutation(p);
            let mut scratch: Vec<usize> = Vec::new();
            let mut plan = Vec::new();
            for &l in &order {
                if !ctl.online[l] || !self.fleet.local[l] {
                    continue;
                }
                let k = select_exchange_partners(
                    &self.fleet.graph,
                    &ctl.online,
                    l,
                    self.fleet.cfg.fan_out,
                    &mut scratch,
                    &mut ctl.rng,
                );
                plan.push((l, scratch[..k].to_vec()));
            }
            plan
        };
        let g = &self.obs.gossip;
        for (l, partners) in plan {
            for j in partners {
                match self.one_exchange(l, j) {
                    Ok(b) => {
                        g.exchanges.inc();
                        g.exchange_bytes.add(b as u64);
                    }
                    Err(TransportError::StaleGeneration(newer)) => {
                        // We're behind the fleet's restart: catch up at
                        // the next refresh. The exchange itself was
                        // cancelled (§7.2).
                        g.failed.inc();
                        let mut ctl = self.lock_ctl();
                        ctl.pending_generation = ctl.pending_generation.max(newer);
                    }
                    Err(_) => g.failed.inc(),
                }
            }
        }
    }

    /// Restrict a dynamic round's partner candidates to this node's
    /// neighbours in the configured overlay topology, rebuilt over the
    /// **live member view**. With `GraphKind::Complete` (the default)
    /// this is a pass-through — the live view is the overlay. For
    /// BA/ER/WS/Ring the overlay vertices are the non-dead member ids in
    /// ascending order, and the generator rng is derived from
    /// `(cfg.seed, id set)`, so every node that agrees on the view
    /// builds the identical graph with zero coordination. The build is
    /// cached until the non-dead id set changes (churn). Views too small
    /// for the generator's minimum size — and views that do not contain
    /// this node yet — fall back to the complete view rather than
    /// stalling the round.
    fn overlay_restrict(
        &self,
        m: &Membership,
        candidates: Vec<(u64, SocketAddr)>,
    ) -> Vec<(u64, SocketAddr)> {
        use crate::config::GraphKind;
        let kind = self.fleet.cfg.graph;
        if matches!(kind, GraphKind::Complete) {
            return candidates;
        }
        let table = m.table();
        let ids: Vec<u64> = table
            .iter()
            .filter(|e| e.status != MemberStatus::Dead)
            .map(|e| e.id)
            .collect();
        // Generator minimum sizes (`graph::from_kind` asserts them):
        // BA needs n > m = 5, WS/Ring need n ≥ 2k + 1 = 11.
        let min = match kind {
            GraphKind::Complete => 2,
            GraphKind::BarabasiAlbert => 6,
            GraphKind::ErdosRenyi => 2,
            GraphKind::WattsStrogatz | GraphKind::Ring => 11,
        };
        if ids.len() < min {
            return candidates;
        }
        let Ok(self_pos) = ids.binary_search(&m.self_id()) else {
            return candidates;
        };
        let mut overlay = self.lock_overlay();
        if overlay.as_ref().map_or(true, |c| c.ids != ids) {
            // Key the generator stream by the id set: same view ⇒ same
            // stream ⇒ same graph, on every node.
            let mut fold: u64 = 0x9E37_79B9_7F4A_7C15;
            for &id in &ids {
                fold = fold.rotate_left(5).wrapping_mul(0x1000_0000_01B3) ^ id;
            }
            let mut grng = default_rng(self.fleet.cfg.seed).derive(0x6EA4).derive(fold);
            let graph = crate::graph::from_kind(kind, ids.len(), &mut grng);
            *overlay = Some(OverlayCache {
                ids: ids.clone(),
                graph,
            });
        }
        let cache = overlay.as_ref().expect("cache built above");
        let allowed: std::collections::BTreeSet<u64> = cache
            .graph
            .neighbours(self_pos)
            .iter()
            .map(|&v| cache.ids[v])
            .collect();
        candidates
            .into_iter()
            .filter(|(id, _)| allowed.contains(id))
            .collect()
    }

    /// One round over the **dynamic member set**: partners are drawn
    /// from the live view (alive members, plus backoff-elapsed probes of
    /// suspects — dead members never burn a connect deadline again, and
    /// a non-complete `GraphKind` further restricts draws to overlay
    /// neighbours), the exchange outcome feeds the suspicion clocks, and
    /// each contacted partner also gets one membership anti-entropy
    /// push–pull on the same pooled connection.
    fn exchange_round_dynamic(&self, m: &Arc<Membership>) {
        // A node whose id was claimed by another address (concurrent
        // joins through different seeds collided) must stop initiating:
        // gossiping under a stolen id would silently corrupt the
        // generation's q̃ mass. The operator rejoins it for a fresh id;
        // the report's membership section carries the flag.
        if m.identity_lost() {
            return;
        }
        // The membership's time source, not `Instant::now()`: under
        // simulation this is the scenario's virtual clock, so suspicion
        // and tombstone GC advance with virtual rounds.
        let now = m.now();
        // Wall-clock sweep first: a suspect whose probes are
        // backoff-gated still turns dead on schedule.
        m.tick(now);
        m.gc(now);
        let candidates = self.overlay_restrict(m, m.eligible_partners(now));
        let plan: Vec<(u64, SocketAddr)> = {
            // The engine's partial-Fisher–Yates draw over the
            // deterministically ordered candidate list.
            let mut ctl = self.lock_ctl();
            let mut idx: Vec<usize> = Vec::new();
            let k = crate::gossip::draw_fan_out(
                candidates.len(),
                self.fleet.cfg.fan_out,
                &mut idx,
                &mut ctl.rng,
            );
            idx[..k].iter().map(|&i| candidates[i]).collect()
        };
        let l = self.fleet.serve_member;
        let g = &self.obs.gossip;
        for (id, addr) in plan {
            // Any reply at all — including Busy/StaleGeneration rejects
            // — proves the partner alive; only connection-level failures
            // feed the suspicion clocks.
            let spoke = match self.remote_exchange(l, addr) {
                Ok(b) => {
                    g.exchanges.inc();
                    g.exchange_bytes.add(b as u64);
                    true
                }
                Err(TransportError::StaleGeneration(newer)) => {
                    g.failed.inc();
                    let mut ctl = self.lock_ctl();
                    ctl.pending_generation = ctl.pending_generation.max(newer);
                    true
                }
                Err(
                    TransportError::Io(_)
                    | TransportError::StaleChannel(_)
                    | TransportError::Unreachable(_),
                ) => {
                    g.failed.inc();
                    false
                }
                Err(_) => {
                    g.failed.inc();
                    true
                }
            };
            if spoke {
                m.record_success(id);
                // Piggyback the membership plane on the warm connection
                // — unless this partner already rejected the plane
                // (static node / pre-plane peer): repeating the push
                // would burn a frame pair (and, for a Malformed-answering
                // peer, the pooled connection) every round for nothing.
                if m.plane_enabled(id) {
                    let anti_entropy_start = Instant::now();
                    let gen = self.lock_ctl().generation;
                    match self.fleet.transport.exchange_membership(addr, gen, &m.table()) {
                        Ok((table, peer_gen, b)) => {
                            g.membership_bytes.add(b as u64);
                            m.merge_remote(&table);
                            if peer_gen > gen {
                                let mut ctl = self.lock_ctl();
                                ctl.pending_generation =
                                    ctl.pending_generation.max(peer_gen);
                            }
                        }
                        Err(
                            TransportError::NoMembership | TransportError::Protocol(_),
                        ) => m.mark_planeless(id),
                        // Transient failures just wait for the next round
                        // (the data exchange above already counted).
                        Err(_) => {}
                    }
                    self.membership_nanos.fetch_add(
                        anti_entropy_start.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                }
            } else {
                m.record_failure(id);
            }
        }
    }

    /// One full refresh → exchange → publish round, timed per phase.
    /// The exchange phase writes the registry counters as it runs; the
    /// returned report is the *diff* of those counters across the round
    /// — one source of truth, exact because rounds serialize on the
    /// gate and serves never touch the gossip counters.
    fn run_round(&self) -> GossipRoundReport {
        let _gate = self.lock_gate();
        let g = &self.obs.gossip;
        let base_exchanges = g.exchanges.get();
        let base_failed = g.failed.get();
        let base_bytes = g.exchange_bytes.get();
        let base_membership_bytes = g.membership_bytes.get();
        let round_start = Instant::now();
        let outcome = self.refresh();
        let refresh_duration = round_start.elapsed();
        g.rounds.inc();
        let restart_cause = match outcome {
            RefreshOutcome::Restarted(cause) => Some(cause),
            RefreshOutcome::Idle | RefreshOutcome::Carried => None,
        };
        let reseeded = restart_cause.is_some();
        let epoch_carried = matches!(outcome, RefreshOutcome::Carried);
        if let Some(cause) = restart_cause {
            g.reseeds.inc();
            g.restarts.cause(cause).inc();
        }
        self.lock_ctl().round += 1;
        self.membership_nanos.store(0, Ordering::Relaxed);
        let exchange_start = Instant::now();
        self.exchange_round();
        let exchange_duration = exchange_start.elapsed();
        // Rounds serialize on the gate and serves never write the span
        // buffer, so this drain is exactly the round's exchanges.
        let exchange_spans: Vec<ExchangeSpan> = std::mem::take(
            &mut *self.round_spans.lock().expect("round span buffer poisoned"),
        );
        let membership_duration =
            Duration::from_nanos(self.membership_nanos.swap(0, Ordering::Relaxed));
        let publish_start = Instant::now();
        // Saturating diffs: serves never touch these counters and rounds
        // serialize on the gate, but a reset (or a future concurrent
        // writer) must degrade to a zero delta, not a u64 wrap.
        let exchanges = g.exchanges.get().saturating_sub(base_exchanges) as usize;
        let failed = g.failed.get().saturating_sub(base_failed) as usize;
        let bytes = g.exchange_bytes.get().saturating_sub(base_bytes) as usize;
        let membership_bytes = g
            .membership_bytes
            .get()
            .saturating_sub(base_membership_bytes) as usize;
        let cur = self.probes();
        let pool_now = self.fleet.transport.pool_stats().unwrap_or_default();
        let membership = self.fleet.membership.as_ref().map(|m| {
            let (alive, suspect, dead) = m.counts();
            let ev = m.take_events();
            MembershipRoundStats {
                alive,
                suspect,
                dead,
                joined: ev.joined,
                suspected: ev.suspected,
                died: ev.died,
                bytes: membership_bytes,
                identity_lost: m.identity_lost(),
            }
        });
        let (round, generation, drift, converged, pool) = {
            let mut ctl = self.lock_ctl();
            ctl.drift = match (&ctl.prev_probes, &cur) {
                (Some(prev), Some(cur)) => prev
                    .iter()
                    .zip(cur)
                    .map(|(&p, &c)| relative_error(c, p))
                    .fold(0.0, f64::max),
                _ => f64::INFINITY,
            };
            ctl.converged = ctl.drift <= self.fleet.cfg.convergence_rel;
            ctl.prev_probes = cur;
            let pool = pool_now.delta_since(ctl.prev_pool);
            ctl.prev_pool = pool_now;
            g.generation.set(ctl.generation as f64);
            g.drift.set(ctl.drift);
            g.converged.set(if ctl.converged { 1.0 } else { 0.0 });
            (ctl.round, ctl.generation, ctl.drift, ctl.converged, pool)
        };
        self.publish_all();
        g.union_bound.set(self.union_bound());
        let publish_duration = publish_start.elapsed();
        let duration = round_start.elapsed();
        g.round_seconds.observe(duration.as_secs_f64());
        g.phase(RoundPhase::Refresh)
            .observe(refresh_duration.as_secs_f64());
        g.phase(RoundPhase::Exchange)
            .observe(exchange_duration.as_secs_f64());
        g.phase(RoundPhase::Membership)
            .observe(membership_duration.as_secs_f64());
        g.phase(RoundPhase::Publish)
            .observe(publish_duration.as_secs_f64());
        let mut trace = RoundTrace::default()
            .with_phase(RoundPhase::Refresh, refresh_duration)
            .with_phase(RoundPhase::Exchange, exchange_duration)
            .with_phase(RoundPhase::Membership, membership_duration)
            .with_phase(RoundPhase::Publish, publish_duration);
        trace.round = round;
        trace.generation = generation;
        trace.reseeded = reseeded;
        trace.restart_cause = restart_cause.map(RestartCause::name);
        trace.exchanges = exchanges;
        trace.failed = failed;
        trace.bytes = bytes;
        trace.total = duration;
        trace.exchange_spans = exchange_spans;
        if let Some(sink) = self.obs.export.get() {
            for span in &trace.exchange_spans {
                sink.emit_exchange(round, span);
            }
            sink.emit_round(&trace);
            if let Some(ms) = &membership {
                if ms.joined + ms.suspected + ms.died > 0 {
                    sink.emit_membership(
                        round,
                        ms.joined as u64,
                        ms.suspected as u64,
                        ms.died as u64,
                    );
                }
            }
        }
        self.obs.trace.push(trace);
        GossipRoundReport {
            round,
            generation,
            reseeded,
            restart_cause,
            epoch_carried,
            exchanges,
            failed,
            bytes,
            drift,
            converged,
            pool,
            membership,
            duration,
            refresh_duration,
            exchange_duration,
            membership_duration,
            publish_duration,
        }
    }

    /// Publish every member's fresh view (round path: clones each slot
    /// one at a time, then stamps the views under `ctl`).
    fn publish_all(&self) {
        let states: Vec<PeerState> =
            (0..self.slots.len()).map(|i| self.lock_slot(i).clone()).collect();
        let ctl = self.lock_ctl();
        for (i, state) in states.into_iter().enumerate() {
            self.views[i].store(Arc::new(GlobalView {
                round: ctl.round,
                generation: ctl.generation,
                epoch: ctl.epochs[i],
                drift: ctl.drift,
                converged: ctl.converged,
                state,
            }));
        }
    }

    /// Publish the local members' views from the slot guards the caller
    /// already holds (serve path).
    fn publish_locked(&self, guards: &[MutexGuard<'_, PeerState>]) {
        let ctl = self.lock_ctl();
        for (k, &i) in self.fleet.local_members.iter().enumerate() {
            self.views[i].store(Arc::new(GlobalView {
                round: ctl.round,
                generation: ctl.generation,
                epoch: ctl.epochs[i],
                drift: ctl.drift,
                converged: ctl.converged,
                state: guards[k].clone(),
            }));
        }
    }

    /// The live Theorem 2 relative-error bound of this node's union
    /// estimate (`dudd_union_rel_err_bound`): `theorem2_bound` over the
    /// averaged serve-member sketch's estimated value range and bucket
    /// budget. NaN while undefined — empty sketch, or a value range
    /// reaching zero/negative values (the paper's relative-value-error
    /// guarantee covers positive streams).
    fn union_bound(&self) -> f64 {
        let (range, m) = {
            let guard = self.lock_slot(self.fleet.serve_member);
            (
                guard.query(0.0).and_then(|mn| guard.query(1.0).map(|mx| (mn, mx))),
                guard.sketch.max_buckets(),
            )
        };
        match range {
            Ok((mn, mx)) if mn > 0.0 && mx >= mn && m >= 2 => theorem2_bound(mn, mx, m),
            _ => f64::NAN,
        }
    }

    /// Serve one inbound push against the serve member (the body of
    /// [`NodeHandle::serve_exchange`]).
    fn serve_exchange(
        &self,
        mut incoming: PeerState,
        generation: u64,
        deliver: impl FnOnce(&PeerState, u64) -> std::io::Result<()>,
    ) -> Result<(), ServeReject> {
        // An inbound push is liveness evidence for its sender even when
        // the exchange itself ends Busy/stale: without this, a member we
        // can't dial but that reaches us fine (asymmetric routing) would
        // be suspected and killed while actively communicating —
        // dead/refute flapping that churns the whole fleet's generation.
        if let Some(m) = &self.fleet.membership {
            m.record_success(incoming.id as u64);
        }
        // Try-lock every local slot in ascending order — never blocks.
        // (A remote fleet has exactly one local slot; holding all of
        // them is what lets a heard newer generation reseed atomically.)
        let mut guards = Vec::with_capacity(self.fleet.local_members.len());
        for &i in &self.fleet.local_members {
            match self.slots[i].try_lock() {
                Ok(g) => guards.push(g),
                Err(TryLockError::WouldBlock) => return Err(ServeReject::Busy),
                // A poisoned slot means a round thread panicked: fail
                // loudly instead of masquerading as a forever-Busy node.
                Err(TryLockError::Poisoned(e)) => {
                    panic!("gossip member slot poisoned: {e}")
                }
            }
        }
        let gen_now = {
            let mut ctl = self.lock_ctl();
            if generation < ctl.generation {
                return Err(ServeReject::StaleGeneration(ctl.generation));
            }
            if generation > ctl.generation {
                // The fleet restarted ahead of us: join that generation
                // by reseeding from our own latest summaries *before*
                // averaging — states from different generations never
                // mix.
                self.reseed_locked(&mut ctl, &mut guards);
                ctl.generation = generation;
            }
            ctl.generation
        };
        let serve_pos = self
            .fleet
            .local_members
            .iter()
            .position(|&i| i == self.fleet.serve_member)
            .expect("serve member is local by construction");
        // Lineage check before the (~16 KiB) rollback clone, so rejected
        // pushes stay cheap on the serve hot path.
        if !guards[serve_pos]
            .sketch
            .mapping()
            .same_lineage(incoming.sketch.mapping())
        {
            return Err(ServeReject::Lineage);
        }
        let pre = guards[serve_pos].clone();
        if PeerState::exchange(&mut guards[serve_pos], &mut incoming).is_err() {
            *guards[serve_pos] = pre;
            return Err(ServeReject::Lineage);
        }
        match deliver(&incoming, gen_now) {
            Ok(()) => {
                // Inbound progress is served immediately — the node's
                // published views must not wait for its own next round.
                self.publish_locked(&guards);
                Ok(())
            }
            Err(e) => {
                // §7.2: the reply never reached the initiator, so the
                // exchange is cancelled on both sides.
                *guards[serve_pos] = pre;
                Err(ServeReject::Cancelled(e.to_string()))
            }
        }
    }

    /// Serve one inbound membership push (the body of
    /// [`NodeHandle::serve_membership`]). Touches no member slot — the
    /// table merge and the generation peek are both short lock-free-ish
    /// critical sections, so membership traffic lands even while a round
    /// is mid-exchange.
    fn serve_membership(
        &self,
        incoming: &MemberTable,
        generation: u64,
    ) -> Result<(MemberTable, u64), ServeReject> {
        let Some(m) = &self.fleet.membership else {
            return Err(ServeReject::NoMembership);
        };
        m.merge_remote(incoming);
        let gen = {
            let mut ctl = self.lock_ctl();
            if generation > ctl.generation {
                // The sender's fleet restarted ahead of us: catch up at
                // the next refresh (states never mix across generations,
                // so nothing to do on the slots here).
                ctl.pending_generation = ctl.pending_generation.max(generation);
            }
            ctl.generation
        };
        Ok((m.table(), gen))
    }

    /// Serve one `dudd-join` handshake (the body of
    /// [`NodeHandle::serve_join`]).
    fn serve_join(&self, addr: SocketAddr) -> Result<(MemberTable, u64), ServeReject> {
        let Some(m) = &self.fleet.membership else {
            return Err(ServeReject::NoMembership);
        };
        let table = m.serve_join(addr);
        Ok((table, self.lock_ctl().generation))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::service::transport::{in_process_exchange, RemoteChannel};
    use std::time::Instant;

    fn static_member(values: &[f64]) -> GossipMember {
        GossipMember::from_dataset(values, 0.001, 1024).unwrap()
    }

    fn service_with(values: &[f64]) -> Arc<QuantileService> {
        let mut cfg = ServiceConfig::default();
        cfg.shards = 2;
        let svc = QuantileService::start(cfg).unwrap();
        let mut w = svc.writer();
        w.insert_batch(values);
        w.flush();
        svc.flush();
        Arc::new(svc)
    }

    #[test]
    fn loop_requires_two_members() {
        let cfg = GossipLoopConfig::default();
        let err = GossipLoop::start(cfg, vec![static_member(&[1.0])]).unwrap_err();
        assert!(err.to_string().contains("at least 2"));
    }

    #[test]
    fn loop_requires_one_local_member() {
        let cfg = GossipLoopConfig::default();
        let a: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let b: SocketAddr = "127.0.0.1:9002".parse().unwrap();
        let err = GossipLoop::start(
            cfg,
            vec![GossipMember::remote(a), GossipMember::remote(b)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("local member"), "{err}");
    }

    #[test]
    fn in_process_transport_rejects_remote_members() {
        let cfg = GossipLoopConfig::default();
        let addr: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let err = GossipLoop::start(
            cfg,
            vec![static_member(&[1.0, 2.0]), GossipMember::remote(addr)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("remote-capable"), "{err}");
    }

    #[test]
    fn remote_fleets_require_exactly_one_local_member() {
        let t = crate::service::TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        let addr: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let err = GossipLoop::start_with(
            GossipLoopConfig::default(),
            vec![
                static_member(&[1.0]),
                static_member(&[2.0]),
                GossipMember::remote(addr),
            ],
            Arc::new(t),
        )
        .unwrap_err();
        assert!(err.to_string().contains("exactly one local"), "{err}");
    }

    #[test]
    fn loop_rejects_mismatched_alpha_lineages() {
        let a = GossipMember::from_dataset(&[1.0, 2.0], 0.001, 1024).unwrap();
        let b = GossipMember::from_dataset(&[3.0, 4.0], 0.01, 1024).unwrap();
        let err = GossipLoop::start(GossipLoopConfig::default(), vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("alpha0 lineage"), "{err}");
    }

    #[test]
    fn two_static_members_average_in_one_round() {
        let xs: Vec<f64> = (1..=600).map(|i| i as f64).collect();
        let ys: Vec<f64> = (601..=1000).map(|i| i as f64).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();

        // Round 0: seeded but unexchanged — member 0 only knows itself.
        let v0 = gl.view();
        assert_eq!(v0.round(), 0);
        assert_eq!(v0.generation(), 1);
        assert!(!v0.converged());
        assert_eq!(v0.estimated_peers(), 1.0);

        let r1 = gl.step();
        assert_eq!(r1.round, 1);
        assert!(r1.exchanges >= 1);
        assert_eq!(r1.failed, 0);
        assert!(r1.bytes > 0);
        assert!(!r1.reseeded);

        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&xs);
        seq.extend(&ys);
        for i in 0..2 {
            let v = gl.member_view(i);
            assert_eq!(v.estimated_peers(), 2.0);
            assert_eq!(v.estimated_total(), 1000.0);
            for q in [0.01, 0.5, 0.99] {
                assert_eq!(
                    v.query(q).unwrap(),
                    seq.quantile(q).unwrap(),
                    "member {i} q={q}"
                );
            }
        }

        // A second identical round changes nothing: drift hits 0.
        let r2 = gl.step();
        assert_eq!(r2.drift, 0.0);
        assert!(r2.converged);
        assert!(gl.view().converged());
        gl.shutdown();
    }

    /// ISSUE 5 satellite: the per-round report carries the pool/frame
    /// telemetry (all zeros for the pool-less in-process transport) and
    /// no membership section on a static fleet.
    #[test]
    fn in_process_report_has_empty_pool_and_no_membership() {
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        let r = gl.step();
        assert_eq!(r.pool, PoolStats::default());
        assert!(r.membership.is_none());
        assert!(gl.membership().is_none());
        gl.shutdown();
    }

    /// ISSUE 6 satellite: the per-round report carries the phase
    /// wall-clocks populated from the span layer, the trace ring mirrors
    /// them, and the report's counts agree with the registry counters it
    /// is derived from.
    #[test]
    fn round_report_carries_phase_timings_from_the_span_layer() {
        let xs: Vec<f64> = (1..=600).map(|i| i as f64).collect();
        let ys: Vec<f64> = (601..=1000).map(|i| i as f64).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();
        let r1 = gl.step();
        let r2 = gl.step();

        // An in-process exchange clones multi-hundred-bucket sketches —
        // the round cannot take zero wall clock.
        assert!(r1.duration > Duration::ZERO);
        assert!(r1.refresh_duration <= r1.duration);
        assert!(r1.exchange_duration <= r1.duration);
        assert!(r1.publish_duration <= r1.duration);
        // Static fleet: no membership anti-entropy ran.
        assert_eq!(r1.membership_duration, Duration::ZERO);

        // The report is a diff of the loop's cumulative counters.
        let obs = gl.metrics();
        assert_eq!(obs.gossip.rounds.get(), 2);
        assert_eq!(
            obs.gossip.exchanges.get() as usize,
            r1.exchanges + r2.exchanges
        );
        assert_eq!(
            obs.gossip.exchange_bytes.get() as usize,
            r1.bytes + r2.bytes
        );
        assert_eq!(obs.gossip.round_seconds.count(), 2);
        assert_eq!(
            obs.gossip.phase(crate::obs::RoundPhase::Exchange).count(),
            2
        );

        // The trace ring holds one span record per round, newest last.
        assert_eq!(obs.trace.len(), 2);
        let traces = obs.trace.recent(1);
        let t = &traces[0];
        assert_eq!(t.round, r2.round);
        assert_eq!(t.exchanges, r2.exchanges);
        assert_eq!(t.total, r2.duration);
        assert_eq!(
            t.phase(crate::obs::RoundPhase::Exchange),
            r2.exchange_duration
        );

        // ISSUE 10 tentpole: every attempted exchange left one child
        // span on the round trace, with a nonzero correlator.
        assert_eq!(t.exchange_spans.len(), r2.exchanges + r2.failed);
        let s = &t.exchange_spans[0];
        assert_ne!(s.trace_id, 0);
        assert!(s.initiator);
        assert_eq!(s.kind, "local", "in-process pair averaging");
        assert_eq!(s.outcome, "ok");
        assert_eq!(s.generation, 1);
        assert!(t.restart_cause.is_none());

        // The live Theorem 2 bound gauge is defined on positive data.
        let bound = obs.gossip.union_bound.get();
        assert!(bound > 0.0 && bound < 1.0, "bound = {bound}");

        // Gauges follow the round outcome, and the whole plane renders.
        assert_eq!(obs.gossip.generation.get(), 1.0);
        assert_eq!(obs.gossip.converged.get(), 1.0, "round 2 drift is 0");
        let text = obs.registry().render();
        assert!(text.contains("dudd_rounds_total 2"), "{text}");
        assert!(
            text.contains("dudd_round_phase_seconds_count{phase=\"exchange\"} 2"),
            "{text}"
        );
        gl.shutdown();
    }

    #[test]
    fn start_membership_validates_transport() {
        use crate::service::membership::{Membership, MembershipConfig};
        use crate::service::TcpTransport;

        let svc = service_with(&[1.0, 2.0]);
        let cfg = GossipLoopConfig::default();
        let m = Arc::new(Membership::bootstrap(
            "127.0.0.1:9100".parse().unwrap(),
            MembershipConfig::default(),
        ));

        // In-process transport cannot carry a dynamic fleet.
        let err = GossipLoop::start_membership(
            cfg.clone(),
            svc.clone(),
            Arc::new(InProcessTransport),
            m.clone(),
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("remote-capable"), "{err}");

        // Connect-only transport: nobody could exchange back.
        let t = TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        let err = GossipLoop::start_membership(cfg.clone(), svc.clone(), Arc::new(t), m, 1)
            .unwrap_err();
        assert!(err.to_string().contains("serving transport"), "{err}");

        // Advertised address must be the transport's listen address.
        let t = TcpTransport::bind("127.0.0.1:0", Duration::from_millis(50)).unwrap();
        let wrong = Arc::new(Membership::bootstrap(
            "127.0.0.1:9101".parse().unwrap(),
            MembershipConfig::default(),
        ));
        let err = GossipLoop::start_membership(cfg, svc.clone(), Arc::new(t), wrong, 1)
            .unwrap_err();
        assert!(err.to_string().contains("advertises"), "{err}");
        Arc::try_unwrap(svc).unwrap().shutdown();
    }

    #[test]
    fn global_view_implements_quantile_reader() {
        let xs: Vec<f64> = (1..=500).map(f64::from).collect();
        let ys: Vec<f64> = (501..=1000).map(f64::from).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();
        gl.step();
        let v = gl.view();
        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&xs);
        seq.extend(&ys);

        let reader: &dyn QuantileReader = v.as_ref();
        assert_eq!(reader.count(), 1000.0);
        assert!(!reader.is_empty());
        assert_eq!(
            reader.quantile(0.5).unwrap(),
            seq.quantile(0.5).unwrap()
        );
        assert_eq!(reader.cdf(250.0).unwrap(), seq.cdf(250.0).unwrap());
        assert_eq!(
            reader.quantiles(&[0.1, 0.9]).unwrap(),
            seq.quantiles(&[0.1, 0.9]).unwrap()
        );
        gl.shutdown();
    }

    /// Restart-free (default): a pure epoch advance is absorbed by the
    /// epoch carry — no reseed, no generation bump, and the union
    /// estimate still lands on the extended stream.
    #[test]
    fn service_epoch_advance_carries_without_restart() {
        let svc = service_with(&[1.0, 2.0, 3.0, 4.0]);
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::service(svc.clone()),
                static_member(&[10.0, 20.0]),
            ],
        )
        .unwrap();
        assert_eq!(gl.view().epoch(), 1);
        let r1 = gl.step();
        assert!(!r1.reseeded);
        assert!(!r1.epoch_carried);
        assert!(r1.restart_cause.is_none());
        let r2 = gl.step();
        assert!(r2.converged, "tiny fleet converges immediately");
        assert_eq!(r2.generation, 1);

        // New data, new epoch: the carry folds the one-item delta into
        // the averaged slot in place — the round is NOT a restart.
        let mut w = svc.writer();
        w.insert(5.0);
        w.flush();
        svc.flush();
        let r3 = gl.step();
        assert!(!r3.reseeded, "epoch advance must not reseed");
        assert!(r3.epoch_carried);
        assert!(r3.restart_cause.is_none());
        assert_eq!(r3.generation, 1, "no generation bump on carry");
        let v = gl.view();
        assert_eq!(v.epoch(), 2, "the view still tracks the new epoch");
        assert_eq!(v.generation(), 1);

        // The carried mass re-averages onto the union of 5+2 items.
        gl.step();
        let v = gl.view();
        assert_eq!(v.estimated_total(), 7.0);
        gl.shutdown();
        Arc::try_unwrap(svc).unwrap().shutdown();
    }

    /// A/B of the above with `restart_free` off: the PR 5 behavior —
    /// every epoch advance restarts the protocol with a generation
    /// bump — is still available behind the flag.
    #[test]
    fn service_epoch_advance_triggers_reseed_with_restart_free_off() {
        let svc = service_with(&[1.0, 2.0, 3.0, 4.0]);
        let mut cfg = GossipLoopConfig::default();
        cfg.restart_free = false;
        let gl = GossipLoop::start(
            cfg,
            vec![
                GossipMember::service(svc.clone()),
                static_member(&[10.0, 20.0]),
            ],
        )
        .unwrap();
        assert_eq!(gl.view().epoch(), 1);
        gl.step();
        let r2 = gl.step();
        assert!(r2.converged, "tiny fleet converges immediately");
        assert_eq!(r2.generation, 1);

        // New data, new epoch: the next round restarts the protocol.
        let mut w = svc.writer();
        w.insert(5.0);
        w.flush();
        svc.flush();
        let r3 = gl.step();
        assert!(r3.reseeded);
        assert!(!r3.epoch_carried);
        assert_eq!(r3.restart_cause, Some(RestartCause::EpochAdvance));
        assert_eq!(r3.generation, 2);
        assert!(!r3.converged, "drift resets on reseed");
        let v = gl.view();
        assert_eq!(v.epoch(), 2);
        assert_eq!(v.generation(), 2);

        // ISSUE 10 satellite: the restart is counted by cause and the
        // cause name rides the round trace (and the event schema).
        let obs = gl.metrics();
        assert_eq!(obs.gossip.restarts.epoch_advance.get(), 1);
        assert_eq!(obs.gossip.reseeds.get(), 1);
        let traces = obs.trace.recent(1);
        assert_eq!(traces[0].restart_cause, Some("epoch_advance"));
        assert_eq!(RestartCause::EpochAdvance.name(), "epoch_advance");
        let text = obs.registry().render();
        assert!(
            text.contains("dudd_restarts_total{cause=\"epoch_advance\"} 1"),
            "{text}"
        );

        // Steps without new epochs re-converge on the union of 5+2 items.
        gl.step();
        let v = gl.view();
        assert_eq!(v.estimated_total(), 7.0);
        gl.shutdown();
        Arc::try_unwrap(svc).unwrap().shutdown();
    }

    #[test]
    fn empty_members_step_without_panicking() {
        let empty: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::from_sketch(&empty),
                GossipMember::from_sketch(&empty),
            ],
        )
        .unwrap();
        let r = gl.step();
        assert!(!r.converged, "no probes on empty sketches");
        assert!(r.drift.is_infinite());
        assert!(matches!(gl.view().query(0.5), Err(SketchError::Empty)));
        gl.shutdown();
    }

    #[test]
    fn background_thread_runs_rounds() {
        let mut cfg = GossipLoopConfig::default();
        cfg.round_interval_ms = 2;
        let gl = GossipLoop::start(
            cfg,
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let v = gl.view();
            if v.round() >= 3 && v.converged() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "background loop never converged (round {})",
                v.round()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        let v = gl.shutdown();
        assert_eq!(v.estimated_total(), 4.0);
    }

    /// The serve side's §7.2 contract, exercised without sockets: a
    /// failing delivery rolls the serve member back bit-for-bit, and
    /// stale/busy pushes are refused with the state untouched.
    #[test]
    fn serve_exchange_commit_and_rollback_semantics() {
        let xs: Vec<f64> = (1..=400).map(f64::from).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&[1e4, 2e4])],
        )
        .unwrap();
        let handle = gl.handle();
        let incoming = PeerState::init(7, &[5.0, 6.0, 7.0], 0.001, 1024).unwrap();
        let before = gl.view().state().clone();

        // Delivery fails → cancelled: serve state identical to before.
        let err = handle
            .serve_exchange(incoming.clone(), 1, |_, _| {
                Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "cut"))
            })
            .unwrap_err();
        assert!(matches!(err, ServeReject::Cancelled(_)), "{err}");
        let after = gl.view().state().clone();
        assert_eq!(after.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(after.q_tilde.to_bits(), before.q_tilde.to_bits());
        assert_eq!(
            after.sketch.positive_store().entries(),
            before.sketch.positive_store().entries()
        );

        // Stale generation → refused, untouched.
        let err = handle
            .serve_exchange(incoming.clone(), 0, |_, _| Ok(()))
            .unwrap_err();
        assert!(matches!(err, ServeReject::StaleGeneration(1)), "{err}");

        // A held member slot → Busy (the per-member analogue of PR 3's
        // busy worker).
        {
            let _slot = gl.core.slots[0].lock().unwrap();
            let err = handle
                .serve_exchange(incoming.clone(), 1, |_, _| Ok(()))
                .unwrap_err();
            assert!(matches!(err, ServeReject::Busy), "{err}");
        }

        // Lineage mismatch → refused, untouched.
        let alien = PeerState::init(9, &[1.0], 0.5, 64).unwrap();
        let err = handle.serve_exchange(alien, 1, |_, _| Ok(())).unwrap_err();
        assert!(matches!(err, ServeReject::Lineage), "{err}");

        // Successful delivery commits: the averaged reply matches the
        // adopted serve state (both sides of the exchange agree).
        let mut delivered: Option<PeerState> = None;
        handle
            .serve_exchange(incoming, 1, |reply, gen| {
                assert_eq!(gen, 1);
                delivered = Some(reply.clone());
                Ok(())
            })
            .unwrap();
        let served = gl.view().state().clone();
        let reply = delivered.expect("delivered");
        assert_eq!(served.n_tilde.to_bits(), reply.n_tilde.to_bits());
        assert_eq!(served.q_tilde.to_bits(), reply.q_tilde.to_bits());
        assert_eq!(reply.id, 7, "reply keeps the initiator's id");
        gl.shutdown();
    }

    /// Hearing a newer generation (inbound push) makes the node reseed
    /// from its own summaries and adopt that generation before averaging.
    #[test]
    fn inbound_newer_generation_adopts_and_reseeds() {
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        // Mix the fleet first so a reseed is observable.
        gl.step();
        let handle = gl.handle();
        let incoming = PeerState::init(5, &[9.0, 10.0], 0.001, 1024).unwrap();
        handle.serve_exchange(incoming, 6, |_, _| Ok(())).unwrap();
        let v = gl.view();
        assert_eq!(v.generation(), 6, "adopted the partner's generation");
        // Serve member reseeded (q̃ back to 1 for member 0) then averaged
        // once with the incoming state: q̃ = 0.5.
        assert_eq!(v.state().q_tilde, 0.5);
        gl.shutdown();
    }

    /// A transport whose connect phase hangs (a dead peer burning the
    /// connect deadline), instrumented so the test knows when the round
    /// is parked inside it.
    #[derive(Debug)]
    struct HangTransport {
        entered: Arc<AtomicBool>,
        release: Arc<AtomicBool>,
    }

    impl Transport for HangTransport {
        fn name(&self) -> &'static str {
            "hang"
        }

        fn supports_remote(&self) -> bool {
            true
        }

        fn exchange_local(
            &self,
            a: &mut PeerState,
            b: &mut PeerState,
        ) -> Result<usize, TransportError> {
            in_process_exchange(a, b)
        }

        fn open_remote(&self, peer: SocketAddr) -> Result<RemoteChannel, TransportError> {
            self.entered.store(true, Ordering::SeqCst);
            let deadline = Instant::now() + Duration::from_secs(10);
            while !self.release.load(Ordering::SeqCst) && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(TransportError::Io(format!("dead peer {peer}")))
        }
    }

    /// The PR 4 acceptance property: a round stalled on a dead peer's
    /// connect deadline holds no member slot, so inbound serves keep
    /// landing instead of drawing `Busy` for fan-out × deadline.
    #[test]
    fn serve_stays_available_while_round_hangs_on_dead_peer() {
        let entered = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        let transport = Arc::new(HangTransport {
            entered: entered.clone(),
            release: release.clone(),
        });
        let gl = GossipLoop::start_with(
            GossipLoopConfig::default(),
            vec![
                static_member(&[1.0, 2.0]),
                GossipMember::remote("127.0.0.1:9".parse().unwrap()),
            ],
            transport,
        )
        .unwrap();
        let handle = gl.handle();
        let core = gl.core.clone();
        let stepper = std::thread::spawn(move || core.run_round());
        let wait_deadline = Instant::now() + Duration::from_secs(5);
        while !entered.load(Ordering::SeqCst) {
            assert!(Instant::now() < wait_deadline, "round never reached connect");
            std::thread::sleep(Duration::from_millis(1));
        }

        // The round is parked inside open_remote. Serves must land now.
        let t0 = Instant::now();
        let incoming = PeerState::init(5, &[9.0, 10.0], 0.001, 1024).unwrap();
        handle
            .serve_exchange(incoming, 1, |_, _| Ok(()))
            .expect("inbound exchange served while the round hangs");
        let latency = t0.elapsed();
        assert!(
            latency < Duration::from_millis(500),
            "serve blocked behind the hung round for {latency:?}"
        );
        assert_eq!(
            gl.view().state().q_tilde,
            0.5,
            "the serve committed while the round was hung"
        );

        release.store(true, Ordering::SeqCst);
        let r = stepper.join().unwrap();
        assert_eq!(r.exchanges, 0);
        assert_eq!(r.failed, 1, "the dead-peer exchange is one failure");

        // ISSUE 10: the cancelled attempt still left a failure span
        // with the connect phase (where the deadline burned) timed.
        let traces = gl.metrics().trace.recent(1);
        assert_eq!(traces[0].exchange_spans.len(), 1);
        let s = &traces[0].exchange_spans[0];
        assert_eq!(s.outcome, "error:io");
        assert_eq!(s.kind, "unknown");
        assert_ne!(s.trace_id, 0);
        assert!(s.connect > Duration::ZERO);
        gl.shutdown();
    }

    /// ISSUE 10: with an event sink installed, every round emits one
    /// `round` line plus one `exchange` line per attempted exchange,
    /// all parseable by the schema's own reader.
    #[test]
    fn rounds_emit_event_log_lines_when_sink_installed() {
        use crate::obs::{parse_flat_json, EventSink};

        let dir = std::env::temp_dir().join(format!(
            "dudd-loop-events-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        let obs = gl.metrics();
        let sink =
            EventSink::create(&path, "n0", obs.gossip.events_dropped.clone()).unwrap();
        obs.export.install(Arc::new(sink));
        let r1 = gl.step();
        let expected = 1 + r1.exchanges + r1.failed;

        // The sink's writer thread is asynchronous by contract: poll
        // until the lines land (they flush per burst).
        let deadline = Instant::now() + Duration::from_secs(5);
        let lines: Vec<String> = loop {
            let text = std::fs::read_to_string(&path).unwrap_or_default();
            let lines: Vec<String> = text.lines().map(str::to_string).collect();
            if lines.len() >= expected {
                break lines;
            }
            assert!(
                Instant::now() < deadline,
                "writer never flushed: {} of {expected} lines",
                lines.len()
            );
            std::thread::sleep(Duration::from_millis(5));
        };
        let mut rounds = 0;
        let mut exchanges = 0;
        for line in &lines {
            let obj = parse_flat_json(line).expect("schema-valid line");
            assert_eq!(obj["node"].as_str(), Some("n0"));
            match obj["event"].as_str() {
                Some("round") => {
                    rounds += 1;
                    assert_eq!(obj["round"].as_u64(), Some(1));
                    assert_eq!(obj["exchanges"].as_u64(), Some(r1.exchanges as u64));
                }
                Some("exchange") => {
                    exchanges += 1;
                    assert_eq!(obj["role"].as_str(), Some("initiator"));
                    assert_eq!(obj["kind"].as_str(), Some("local"));
                    let id: u64 = obj["trace_id"]
                        .as_str()
                        .expect("trace ids travel as strings")
                        .parse()
                        .expect("decimal trace id");
                    assert_ne!(id, 0);
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(rounds, 1);
        assert_eq!(exchanges, r1.exchanges + r1.failed);
        assert_eq!(gl.metrics().gossip.events_dropped.get(), 0);
        gl.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
