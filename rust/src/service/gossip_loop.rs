//! The continuous service-driven gossip loop: refresh → exchange → serve.
//!
//! PR 1 connected a [`QuantileService`] to the protocol one shot at a
//! time ([`ServicePeer`](super::ServicePeer)); this module closes the
//! paper's full production loop. A [`GossipLoop`] owns the node's view of
//! a fleet of **members** — live services, simulated peers, and (since
//! the transport redesign) **remote nodes** — and runs the cycle
//! continuously while ingest keeps flowing:
//!
//! ```text
//!        ┌────────────────────────── every round ─────────────────────────┐
//!        │ refresh: any service published a newer epoch? a partner        │
//!        │          reported a newer restart generation?                  │
//!        │   └─ yes → reseed every local PeerState (protocol restart,     │
//!        │            Prop. 4: averaging re-converges from any states)    │
//!        │ exchange: one fan-out push–pull round over the overlay,        │
//!        │           every partner interaction through the Transport      │
//!        │           trait (in-process or framed TCP; failures cancel     │
//!        │           the exchange, §7.2)                                  │
//!        │ serve: publish one GlobalView per local member through an      │
//!        │        ArcSwapCell — reads never block, never see a torn state │
//!        └────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Queries can therefore read **two** estimates: the service's own
//! [`Snapshot`](super::Snapshot) (local stream only, exact fold) and the
//! loop's [`GlobalView`] (network-converged estimate of the *union*
//! stream, Algorithm 6). Convergence is observable: each round the loop
//! probes a configured quantile set and reports the largest relative
//! drift since the previous round; once the drift falls below
//! [`GossipLoopConfig::convergence_rel`] the view is flagged converged.
//!
//! **Restart generations.** The reseed-all policy is load-bearing: `q̃`
//! mass must stay exactly 1 across the fleet for the network-size
//! estimate `p̃ = 1/q̃` to be unbiased, so a newer epoch anywhere restarts
//! *every* member rather than patching one peer in place. In-process
//! fleets restart atomically, as in PR 2. Across machines the restart is
//! coordinated by a **generation counter** carried in every exchange
//! frame: a node whose local epoch advances reseeds and bumps its
//! generation; a node that *hears* a newer generation (in an inbound
//! push, or in a partner's stale-rejection) reseeds **from its own latest
//! summary** and adopts that generation before any averaging. States
//! from different generations never average together, so within each
//! generation the `q̃` mass is exactly 1 and the fixed point is the union
//! of the freshest local summaries.
//!
//! The serve side of the transport ([`NodeHandle`]) applies inbound
//! exchanges under the same worker lock rounds use, with §7.2 atomicity:
//! the averaged state commits only once the reply reaches the wire and
//! rolls back otherwise.

use super::coordinator::QuantileService;
use super::swap::ArcSwapCell;
use super::transport::{InProcessTransport, Transport, TransportError};
use crate::config::GossipLoopConfig;
use crate::gossip::{select_exchange_partners, GossipSketch, PeerState};
use crate::graph::Graph;
use crate::metrics::relative_error;
use crate::rng::{default_rng, Xoshiro256pp};
use crate::sketch::{QuantileReader, SketchError, Store, UddSketch};
use anyhow::{bail, Context, Result};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One participant in a [`GossipLoop`].
#[derive(Debug)]
pub enum GossipMember {
    /// A live ingest service: reseeded from its latest published
    /// snapshot whenever a newer epoch appears.
    Service(Arc<QuantileService>),
    /// A simulated remote peer with a fixed local summary.
    Static(GossipSketch),
    /// A real remote node reached through the loop's
    /// [`Transport`](super::Transport) (its state lives on that node; the
    /// member's own loop drives its exchanges). Requires a
    /// remote-capable transport such as
    /// [`TcpTransport`](super::TcpTransport).
    Remote(SocketAddr),
}

impl GossipMember {
    /// A member fronting a live service.
    pub fn service(svc: Arc<QuantileService>) -> Self {
        GossipMember::Service(svc)
    }

    /// A simulated peer summarizing `data` with the given sketch
    /// parameters.
    pub fn from_dataset(data: &[f64], alpha: f64, max_buckets: usize) -> Result<Self> {
        let mut s: UddSketch = UddSketch::new(alpha, max_buckets)
            .map_err(anyhow::Error::msg)
            .context("static member sketch")?;
        s.extend(data);
        Ok(GossipMember::Static(s.convert_store()))
    }

    /// A simulated peer fronting an already-built local summary.
    pub fn from_sketch<S: Store>(sketch: &UddSketch<S>) -> Self {
        GossipMember::Static(sketch.convert_store())
    }

    /// A remote node at `addr` (see [`GossipMember::Remote`]).
    pub fn remote(addr: SocketAddr) -> Self {
        GossipMember::Remote(addr)
    }

    /// True for members whose state lives in this loop (service/static).
    pub fn is_local(&self) -> bool {
        !matches!(self, GossipMember::Remote(_))
    }
}

/// The network-converged estimate one member serves after a round.
///
/// Immutable, like [`Snapshot`](super::Snapshot): a handle keeps
/// answering consistently no matter how far the loop advances. Also
/// queryable through [`QuantileReader`].
#[derive(Debug, Clone)]
pub struct GlobalView {
    round: u64,
    generation: u64,
    epoch: u64,
    drift: f64,
    converged: bool,
    state: PeerState,
}

impl GlobalView {
    /// Gossip rounds executed when this view was published.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Restart generations so far (bumped whenever a service published a
    /// newer epoch, or a partner node reported a newer generation, and
    /// the protocol restarted).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Service epoch this member's local state was seeded from (0 for
    /// static/remote members and before the first epoch).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Largest relative drift of the probe-quantile estimates between
    /// the last two rounds (∞ until two comparable rounds exist).
    pub fn drift(&self) -> f64 {
        self.drift
    }

    /// True once the drift fell to the configured threshold or below
    /// without an intervening reseed.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The member's averaged protocol state.
    pub fn state(&self) -> &PeerState {
        &self.state
    }

    /// Estimated fleet size `p̃ = round(1/q̃)` (Algorithm 6).
    pub fn estimated_peers(&self) -> f64 {
        self.state.estimated_peers()
    }

    /// Estimated union-stream length `Ñ = round(p̃ · Ñ_l)`.
    pub fn estimated_total(&self) -> f64 {
        self.state.estimated_total()
    }

    /// Estimate the q-quantile of the **union** stream (Algorithm 6).
    pub fn query(&self, q: f64) -> Result<f64, SketchError> {
        self.state.query(q)
    }

    /// Batch union-stream quantile queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.state.query(q)).collect()
    }
}

impl QuantileReader for GlobalView {
    fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        self.state.query(q)
    }

    fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        self.state.cdf(x)
    }

    /// The estimated union-stream length (∞ before any information from
    /// the distinguished peer arrives).
    fn count(&self) -> f64 {
        self.estimated_total()
    }

    fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        GlobalView::quantiles(self, qs)
    }

    /// Overridden: `count()` can be ∞ before the distinguished peer's
    /// mass arrives, so emptiness is judged by the averaged sketch — the
    /// same condition under which [`GlobalView::query`] returns
    /// [`SketchError::Empty`].
    fn is_empty(&self) -> bool {
        self.state.sketch.is_empty()
    }
}

/// Telemetry for one executed loop round.
#[derive(Debug, Clone, Copy)]
pub struct GossipRoundReport {
    /// Rounds executed so far (this one included).
    pub round: u64,
    /// Current restart generation.
    pub generation: u64,
    /// True when this round reseeded the local members from fresh
    /// snapshots (local epoch advance, or a newer generation heard from a
    /// partner node).
    pub reseeded: bool,
    /// Completed push–pull exchanges this round.
    pub exchanges: usize,
    /// Exchanges cancelled this round — transport failures, missed
    /// deadlines, busy or stale partners. Both sides keep their pre-round
    /// state on every one of these (§7.2).
    pub failed: usize,
    /// Wire traffic this round (push + pull frames, codec byte-exact for
    /// in-process exchanges; actual socket bytes for remote ones).
    pub bytes: usize,
    /// Largest relative probe drift vs the previous round (∞ if not yet
    /// comparable).
    pub drift: f64,
    /// Whether the drift is at or below the configured threshold.
    pub converged: bool,
}

/// Shared read side: one view cell per member.
struct Shared {
    views: Vec<ArcSwapCell<GlobalView>>,
}

/// Mutable loop state, owned by whichever thread runs the next round (or
/// serves an inbound exchange).
struct Worker {
    cfg: GossipLoopConfig,
    members: Vec<GossipMember>,
    /// `true` where the member's state lives in this loop.
    local: Vec<bool>,
    /// Index of the member inbound exchanges are served against (the
    /// first local member — the node's own identity in a remote fleet).
    serve_member: usize,
    transport: Arc<dyn Transport>,
    states: Vec<PeerState>,
    /// Snapshot epoch each member was last seeded from (0 for
    /// static/remote).
    epochs: Vec<u64>,
    /// Member indices whose probe estimates drive the drift metric:
    /// every local service member, or the serve member in an all-static
    /// fleet.
    probe_members: Vec<usize>,
    graph: Graph,
    rng: Xoshiro256pp,
    online: Vec<bool>,
    round: u64,
    generation: u64,
    /// Highest remote generation heard via stale-rejections; adopted at
    /// the next refresh.
    pending_generation: u64,
    prev_probes: Option<Vec<f64>>,
    drift: f64,
    converged: bool,
}

/// Why an inbound exchange was refused (serve side of §7.2 — the
/// initiator keeps its pre-round state on every variant).
#[derive(Debug)]
pub enum ServeReject {
    /// The node is mid-round or mid-exchange; the initiator retries next
    /// round.
    Busy,
    /// The push carried an older restart generation than ours (the
    /// payload — the initiator reseeds and catches up).
    StaleGeneration(u64),
    /// α₀ lineage mismatch: these nodes can never merge.
    Lineage,
    /// The reply could not be delivered; the serve-side state change was
    /// rolled back (cancelled exchange).
    Cancelled(String),
}

impl std::fmt::Display for ServeReject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeReject::Busy => write!(f, "busy"),
            ServeReject::StaleGeneration(g) => write!(f, "stale generation (ours is {g})"),
            ServeReject::Lineage => write!(f, "alpha0 lineage mismatch"),
            ServeReject::Cancelled(e) => write!(f, "reply delivery failed: {e}"),
        }
    }
}

/// The serve-side handle a [`Transport`] accept loop uses to apply
/// inbound exchanges to this node's state. Cheap to clone; opaque —
/// custom transports interact with the loop only through
/// [`NodeHandle::serve_exchange`] and [`NodeHandle::stopping`].
#[derive(Clone)]
pub struct NodeHandle {
    worker: Arc<Mutex<Worker>>,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
}

impl std::fmt::Debug for NodeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodeHandle(stopping={})", self.stopping())
    }
}

impl NodeHandle {
    /// True once the loop is shutting down; server threads must exit.
    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Apply one inbound push–pull atomically: average `incoming` (sent
    /// at restart generation `generation`) into the node's serve member
    /// and hand the averaged reply to `deliver`. The state change
    /// **commits only if `deliver` returns `Ok`** — the §7.2 contract:
    /// a reply that never reaches the initiator rolls the serve side
    /// back, so a cancelled exchange leaves both nodes at their
    /// pre-round state.
    ///
    /// Never blocks: a worker busy with its own round yields
    /// [`ServeReject::Busy`] instead of queueing (the initiator counts a
    /// failed exchange and retries next round), which also makes
    /// cross-node deadlock impossible.
    pub fn serve_exchange(
        &self,
        incoming: PeerState,
        generation: u64,
        deliver: impl FnOnce(&PeerState, u64) -> std::io::Result<()>,
    ) -> Result<(), ServeReject> {
        let mut w = match self.worker.try_lock() {
            Ok(w) => w,
            Err(std::sync::TryLockError::WouldBlock) => return Err(ServeReject::Busy),
            // A poisoned worker means a round thread panicked: fail loudly
            // (matching `GossipLoop::step`) instead of masquerading as a
            // forever-Busy node.
            Err(std::sync::TryLockError::Poisoned(e)) => {
                panic!("gossip worker poisoned: {e}")
            }
        };
        w.serve_exchange(&self.shared, incoming, generation, deliver)
    }
}

/// A background gossip task over a fleet of services, simulated peers,
/// and remote nodes.
///
/// With `round_interval_ms > 0` a thread runs one round per interval;
/// [`GossipLoop::step`] additionally (or, at interval 0, exclusively)
/// runs rounds on demand — handy for deterministic tests and for the
/// `serve-gossip`/`serve-remote` CLIs' per-round reporting.
///
/// [`GossipLoop::start`] runs the fleet in process, exactly as PR 2 did
/// (the [`InProcessTransport`] reproduces those results bit for bit);
/// [`GossipLoop::start_with`] accepts any [`Transport`]. The primary
/// construction path is [`Node::builder()`](super::Node::builder).
///
/// ```
/// use duddsketch::config::GossipLoopConfig;
/// use duddsketch::service::{GossipLoop, GossipMember};
///
/// // Two simulated peers, each holding half of 1..=1000.
/// let lo: Vec<f64> = (1..=500).map(f64::from).collect();
/// let hi: Vec<f64> = (501..=1000).map(f64::from).collect();
/// let members = vec![
///     GossipMember::from_dataset(&lo, 0.001, 1024).unwrap(),
///     GossipMember::from_dataset(&hi, 0.001, 1024).unwrap(),
/// ];
/// let gl = GossipLoop::start(GossipLoopConfig::default(), members).unwrap();
/// gl.step(); // one exchange fully averages a 2-peer fleet
/// let view = gl.view();
/// let p50 = view.query(0.5).unwrap();
/// assert!((p50 - 500.0).abs() / 500.0 <= 0.001 + 1e-9);
/// assert_eq!(view.estimated_peers(), 2.0);
/// gl.shutdown();
/// ```
pub struct GossipLoop {
    shared: Arc<Shared>,
    worker: Arc<Mutex<Worker>>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    server: Option<JoinHandle<()>>,
    transport: Arc<dyn Transport>,
    /// First local member: the node's own identity (immutable).
    serve_member: usize,
}

impl std::fmt::Debug for GossipLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let v = self.view();
        write!(
            f,
            "GossipLoop(members={}, transport={}, round={}, generation={}, converged={})",
            self.shared.views.len(),
            self.transport.name(),
            v.round(),
            v.generation(),
            v.converged()
        )
    }
}

impl GossipLoop {
    /// [`GossipLoop::start_with`] on the [`InProcessTransport`] — PR 2's
    /// in-process fleet, byte-identical results.
    pub fn start(cfg: GossipLoopConfig, members: Vec<GossipMember>) -> Result<Self> {
        Self::start_with(cfg, members, Arc::new(InProcessTransport))
    }

    /// Validate, seed every local member from its current summary, build
    /// the overlay, publish the round-0 views, spawn the transport's
    /// accept loop (if it serves one), and (when an interval is
    /// configured) the background round thread.
    ///
    /// Member index is the peer id — **globally**: a remote fleet lists
    /// every node in the same order everywhere (and shares one gossip
    /// seed/graph so all overlays agree); the member at the node's own
    /// position is its local service. Member 0 plays Algorithm 3's
    /// distinguished role (`q̃ = 1`). Small fleets should keep the
    /// default [`GraphKind::Complete`](crate::config::GraphKind::Complete)
    /// overlay; the simulation topologies carry their own minimum-size
    /// requirements.
    pub fn start_with(
        cfg: GossipLoopConfig,
        members: Vec<GossipMember>,
        transport: Arc<dyn Transport>,
    ) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        if members.len() < 2 {
            bail!("gossip loop needs at least 2 members, got {}", members.len());
        }
        let local: Vec<bool> = members.iter().map(GossipMember::is_local).collect();
        let serve_member = local
            .iter()
            .position(|&b| b)
            .context("gossip loop needs at least one local member (service or static)")?;
        if local.iter().any(|&b| !b) {
            if !transport.supports_remote() {
                bail!(
                    "fleet lists remote members but the {} transport cannot reach \
                     them — use a remote-capable transport (e.g. TcpTransport)",
                    transport.name()
                );
            }
            // Inbound exchanges are served against the node's own member
            // (the push frame carries no target id), and a Static member
            // listed on several nodes would be independently mutated by
            // each — either way the generation's q̃ mass breaks. A remote
            // fleet therefore hosts exactly one local member per node;
            // simulated Static peers belong to in-process fleets.
            let locals = local.iter().filter(|&&b| b).count();
            if locals != 1 {
                bail!(
                    "a fleet with remote members must have exactly one local \
                     member (this node's own identity), found {locals}"
                );
            }
        }
        // Exchanges merge sketches, and merges require one shared α₀
        // lineage — catch a mismatched fleet here instead of panicking
        // mid-round. Remote members are checked at exchange time by the
        // frame protocol.
        let mut alpha0: Option<f64> = None;
        let mut lineage: Option<(f64, usize)> = None;
        for (i, m) in members.iter().enumerate() {
            let (a, mb) = match m {
                GossipMember::Service(svc) => (svc.config().alpha, svc.config().max_buckets),
                GossipMember::Static(sketch) => {
                    (sketch.mapping().alpha0(), sketch.max_buckets())
                }
                GossipMember::Remote(_) => continue,
            };
            match alpha0 {
                None => {
                    alpha0 = Some(a);
                    lineage = Some((a, mb));
                }
                Some(first) if first.to_bits() != a.to_bits() => bail!(
                    "gossip members must share one alpha0 lineage: \
                     member {serve_member} has {first}, member {i} has {a}"
                ),
                Some(_) => {}
            }
        }
        let (alpha, max_buckets) = lineage.expect("at least one local member");

        let n = members.len();
        let master = default_rng(cfg.seed);
        let mut grng = master.derive(0x6EA4);
        let graph = crate::graph::from_kind(cfg.graph, n, &mut grng);
        let interval_ms = cfg.round_interval_ms;
        let probe_members: Vec<usize> = {
            let svc: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(_, m)| matches!(m, GossipMember::Service(_)))
                .map(|(i, _)| i)
                .collect();
            if svc.is_empty() {
                vec![serve_member]
            } else {
                svc
            }
        };
        // Placeholder states for every slot (remote slots keep theirs —
        // their real state lives on the remote node); the reseed below
        // fills the local ones.
        let blank: GossipSketch =
            UddSketch::new(alpha, max_buckets).map_err(anyhow::Error::msg)?;
        let states: Vec<PeerState> = (0..n)
            .map(|i| PeerState {
                id: i,
                sketch: blank.clone(),
                n_tilde: 0.0,
                q_tilde: 0.0,
            })
            .collect();
        let mut worker = Worker {
            rng: master.derive(0x1005),
            cfg,
            members,
            local,
            serve_member,
            transport: transport.clone(),
            states,
            epochs: vec![0; n],
            probe_members,
            graph,
            online: vec![true; n],
            round: 0,
            generation: 0,
            pending_generation: 0,
            prev_probes: None,
            drift: f64::INFINITY,
            converged: false,
        };
        worker.reseed_states();
        worker.generation = 1;
        let shared = Arc::new(Shared {
            views: (0..n)
                .map(|i| ArcSwapCell::new(Arc::new(worker.view_of(i))))
                .collect(),
        });
        let worker = Arc::new(Mutex::new(worker));
        let stop = Arc::new(AtomicBool::new(false));
        let server = transport.spawn_server(NodeHandle {
            worker: worker.clone(),
            shared: shared.clone(),
            stop: stop.clone(),
        })?;
        let thread = if interval_ms > 0 {
            let worker = worker.clone();
            let shared = shared.clone();
            let stop = stop.clone();
            let interval = Duration::from_millis(interval_ms);
            Some(
                std::thread::Builder::new()
                    .name("dudd-gossip".into())
                    .spawn(move || round_loop(&worker, &shared, &stop, interval))
                    .context("spawning gossip loop thread")?,
            )
        } else {
            None
        };
        Ok(Self {
            shared,
            worker,
            stop,
            thread,
            server,
            transport,
            serve_member,
        })
    }

    /// Number of members in the fleet (local + remote).
    pub fn members(&self) -> usize {
        self.shared.views.len()
    }

    /// The transport carrying this loop's exchanges.
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// The address this loop's transport serves inbound exchanges on
    /// (None for in-process or client-only transports).
    pub fn listen_addr(&self) -> Option<SocketAddr> {
        self.transport.listen_addr()
    }

    /// Run one refresh → exchange → serve round synchronously and return
    /// its telemetry. Safe alongside the background thread and the
    /// transport's accept loop (rounds and inbound exchanges serialize on
    /// the worker lock).
    pub fn step(&self) -> GossipRoundReport {
        let mut w = self.worker.lock().expect("gossip worker poisoned");
        let report = w.run_round();
        w.publish(&self.shared);
        report
    }

    /// The latest global view of the serve member — the first local
    /// member, i.e. the node's own identity (member 0 in an all-local
    /// fleet, as in PR 2). Lock-free.
    pub fn view(&self) -> Arc<GlobalView> {
        self.member_view(self.serve_member)
    }

    /// The latest global view of member `i`. Lock-free. For
    /// [`GossipMember::Remote`] members this node publishes only a
    /// placeholder (their real views live on their own node).
    pub fn member_view(&self, i: usize) -> Arc<GlobalView> {
        self.shared.views[i].load()
    }

    /// Stop the background threads (round + accept loop, if any) and
    /// return the final view of the serve member.
    pub fn shutdown(mut self) -> Arc<GlobalView> {
        self.stop_thread();
        self.view()
    }

    fn stop_thread(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.server.take() {
            let _ = t.join();
        }
    }
}

impl Drop for GossipLoop {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

/// Background driver: one round per interval, stop-aware in ≤10 ms
/// steps so shutdown never waits out a long interval.
fn round_loop(
    worker: &Mutex<Worker>,
    shared: &Shared,
    stop: &AtomicBool,
    interval: Duration,
) {
    let step = Duration::from_millis(10).min(interval);
    'outer: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'outer;
            }
            let d = step.min(interval - slept);
            std::thread::sleep(d);
            slept += d;
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let mut w = worker.lock().expect("gossip worker poisoned");
        w.run_round();
        w.publish(shared);
    }
}

impl Worker {
    /// Seed every **local** member's `PeerState` from its current local
    /// summary and reset the drift bookkeeping. Restarting all local
    /// members together keeps the generation's `q̃` mass exact (see the
    /// module docs); remote members restart on their own nodes, carried
    /// by the generation tags.
    fn reseed_states(&mut self) {
        for i in 0..self.members.len() {
            match &self.members[i] {
                GossipMember::Service(svc) => {
                    let snap = svc.snapshot();
                    self.epochs[i] = snap.epoch();
                    self.states[i] = PeerState::from_sketch(i, snap.sketch());
                }
                GossipMember::Static(sketch) => {
                    self.states[i] = PeerState::from_sketch(i, sketch);
                }
                GossipMember::Remote(_) => {}
            }
        }
        self.prev_probes = None;
        self.drift = f64::INFINITY;
        self.converged = false;
    }

    /// True when any local service member has published an epoch newer
    /// than the one its state was seeded from.
    fn stale(&self) -> bool {
        self.members.iter().enumerate().any(|(i, m)| match m {
            GossipMember::Service(svc) => svc.snapshot().epoch() != self.epochs[i],
            _ => false,
        })
    }

    /// Refresh step: restart the protocol when local data moved (epoch
    /// advance ⇒ strictly newer generation) or a partner reported a newer
    /// generation (adopt it). Returns whether a reseed happened.
    fn refresh(&mut self) -> bool {
        let wanted = std::mem::take(&mut self.pending_generation);
        let stale = self.stale();
        if !stale && wanted <= self.generation {
            return false;
        }
        self.reseed_states();
        // Saturating: a (hostile or corrupt) partner could have pushed the
        // generation near u64::MAX — the counter must never overflow-panic
        // mid-round or wrap back to 0 (which would read as "stale" to the
        // whole fleet). Frame authentication is the real fix (ROADMAP).
        let bumped = if stale {
            self.generation.saturating_add(1)
        } else {
            self.generation
        };
        self.generation = bumped.max(wanted);
        true
    }

    /// Probe-quantile estimates across the probe members, or `None`
    /// while any probe member cannot answer yet (empty sketch).
    fn probes(&self) -> Option<Vec<f64>> {
        let mut out =
            Vec::with_capacity(self.probe_members.len() * self.cfg.probe_quantiles.len());
        for &i in &self.probe_members {
            for &q in &self.cfg.probe_quantiles {
                match self.states[i].query(q) {
                    Ok(v) => out.push(v),
                    Err(_) => return None,
                }
            }
        }
        Some(out)
    }

    /// One fan-out push–pull round over the overlay, every partner
    /// interaction through the transport. Local members initiate
    /// (Algorithm 4's inner loop, identical partner draws to the
    /// simulation engine); remote members initiate from their own nodes.
    /// Returns `(exchanges, failed, bytes)`.
    fn exchange_round(&mut self) -> (usize, usize, usize) {
        let p = self.states.len();
        let mut exchanges = 0;
        let mut failed = 0;
        let mut bytes = 0usize;
        let order = self.rng.permutation(p);
        let mut scratch: Vec<usize> = Vec::new();
        for &l in &order {
            if !self.online[l] || !self.local[l] {
                continue;
            }
            let k = select_exchange_partners(
                &self.graph,
                &self.online,
                l,
                self.cfg.fan_out,
                &mut scratch,
                &mut self.rng,
            );
            for &j in scratch.iter().take(k) {
                let outcome = if self.local[j] {
                    // Atomic in-process exchange (both slots co-located).
                    let (lo, hi) = self.states.split_at_mut(l.max(j));
                    let (a, b) = if l < j {
                        (&mut lo[l], &mut hi[0])
                    } else {
                        (&mut hi[0], &mut lo[j])
                    };
                    self.transport.exchange_local(a, b)
                } else {
                    let addr = match &self.members[j] {
                        GossipMember::Remote(addr) => *addr,
                        _ => unreachable!("non-local member is remote by construction"),
                    };
                    self.transport
                        .exchange_remote(&mut self.states[l], self.generation, addr)
                };
                match outcome {
                    Ok(b) => {
                        exchanges += 1;
                        bytes += b;
                    }
                    Err(TransportError::StaleGeneration(g)) => {
                        // We're behind the fleet's restart: catch up at
                        // the next refresh. The exchange itself was
                        // cancelled (§7.2).
                        failed += 1;
                        self.pending_generation = self.pending_generation.max(g);
                    }
                    Err(_) => failed += 1,
                }
            }
        }
        (exchanges, failed, bytes)
    }

    /// One full refresh → exchange cycle (the serve half is
    /// [`Worker::publish`]).
    fn run_round(&mut self) -> GossipRoundReport {
        let reseeded = self.refresh();
        self.round += 1;
        let (exchanges, failed, bytes) = self.exchange_round();
        let cur = self.probes();
        self.drift = match (&self.prev_probes, &cur) {
            (Some(prev), Some(cur)) => prev
                .iter()
                .zip(cur)
                .map(|(&p, &c)| relative_error(c, p))
                .fold(0.0, f64::max),
            _ => f64::INFINITY,
        };
        self.converged = self.drift <= self.cfg.convergence_rel;
        self.prev_probes = cur;
        GossipRoundReport {
            round: self.round,
            generation: self.generation,
            reseeded,
            exchanges,
            failed,
            bytes,
            drift: self.drift,
            converged: self.converged,
        }
    }

    /// Serve one inbound push against the serve member (the body of
    /// [`NodeHandle::serve_exchange`]; the caller holds the worker lock).
    fn serve_exchange(
        &mut self,
        shared: &Shared,
        mut incoming: PeerState,
        generation: u64,
        deliver: impl FnOnce(&PeerState, u64) -> std::io::Result<()>,
    ) -> Result<(), ServeReject> {
        if generation < self.generation {
            return Err(ServeReject::StaleGeneration(self.generation));
        }
        if generation > self.generation {
            // The fleet restarted ahead of us: join that generation by
            // reseeding from our own latest summaries *before* averaging
            // — states from different generations never mix.
            self.reseed_states();
            self.generation = generation;
        }
        let serve = self.serve_member;
        if !self.states[serve]
            .sketch
            .mapping()
            .same_lineage(incoming.sketch.mapping())
        {
            return Err(ServeReject::Lineage);
        }
        let pre = self.states[serve].clone();
        if PeerState::exchange(&mut self.states[serve], &mut incoming).is_err() {
            self.states[serve] = pre;
            return Err(ServeReject::Lineage);
        }
        match deliver(&incoming, self.generation) {
            Ok(()) => {
                // Inbound progress is served immediately — the node's
                // published views must not wait for its own next round.
                self.publish(shared);
                Ok(())
            }
            Err(e) => {
                // §7.2: the reply never reached the initiator, so the
                // exchange is cancelled on both sides.
                self.states[serve] = pre;
                Err(ServeReject::Cancelled(e.to_string()))
            }
        }
    }

    /// Build the view a round publishes for member `i`.
    fn view_of(&self, i: usize) -> GlobalView {
        GlobalView {
            round: self.round,
            generation: self.generation,
            epoch: self.epochs[i],
            drift: self.drift,
            converged: self.converged,
            state: self.states[i].clone(),
        }
    }

    /// Serve: publish every member's fresh view.
    fn publish(&self, shared: &Shared) {
        for (i, cell) in shared.views.iter().enumerate() {
            cell.store(Arc::new(self.view_of(i)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;

    fn static_member(values: &[f64]) -> GossipMember {
        GossipMember::from_dataset(values, 0.001, 1024).unwrap()
    }

    fn service_with(values: &[f64]) -> Arc<QuantileService> {
        let mut cfg = ServiceConfig::default();
        cfg.shards = 2;
        let svc = QuantileService::start(cfg).unwrap();
        let mut w = svc.writer();
        w.insert_batch(values);
        w.flush();
        svc.flush();
        Arc::new(svc)
    }

    #[test]
    fn loop_requires_two_members() {
        let cfg = GossipLoopConfig::default();
        let err = GossipLoop::start(cfg, vec![static_member(&[1.0])]).unwrap_err();
        assert!(err.to_string().contains("at least 2"));
    }

    #[test]
    fn loop_requires_one_local_member() {
        let cfg = GossipLoopConfig::default();
        let a: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let b: SocketAddr = "127.0.0.1:9002".parse().unwrap();
        let err = GossipLoop::start(
            cfg,
            vec![GossipMember::remote(a), GossipMember::remote(b)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("local member"), "{err}");
    }

    #[test]
    fn in_process_transport_rejects_remote_members() {
        let cfg = GossipLoopConfig::default();
        let addr: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let err = GossipLoop::start(
            cfg,
            vec![static_member(&[1.0, 2.0]), GossipMember::remote(addr)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("remote-capable"), "{err}");
    }

    #[test]
    fn remote_fleets_require_exactly_one_local_member() {
        let t = crate::service::TcpTransport::connect_only(Duration::from_millis(50)).unwrap();
        let addr: SocketAddr = "127.0.0.1:9001".parse().unwrap();
        let err = GossipLoop::start_with(
            GossipLoopConfig::default(),
            vec![
                static_member(&[1.0]),
                static_member(&[2.0]),
                GossipMember::remote(addr),
            ],
            Arc::new(t),
        )
        .unwrap_err();
        assert!(err.to_string().contains("exactly one local"), "{err}");
    }

    #[test]
    fn loop_rejects_mismatched_alpha_lineages() {
        let a = GossipMember::from_dataset(&[1.0, 2.0], 0.001, 1024).unwrap();
        let b = GossipMember::from_dataset(&[3.0, 4.0], 0.01, 1024).unwrap();
        let err = GossipLoop::start(GossipLoopConfig::default(), vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("alpha0 lineage"), "{err}");
    }

    #[test]
    fn two_static_members_average_in_one_round() {
        let xs: Vec<f64> = (1..=600).map(|i| i as f64).collect();
        let ys: Vec<f64> = (601..=1000).map(|i| i as f64).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();

        // Round 0: seeded but unexchanged — member 0 only knows itself.
        let v0 = gl.view();
        assert_eq!(v0.round(), 0);
        assert_eq!(v0.generation(), 1);
        assert!(!v0.converged());
        assert_eq!(v0.estimated_peers(), 1.0);

        let r1 = gl.step();
        assert_eq!(r1.round, 1);
        assert!(r1.exchanges >= 1);
        assert_eq!(r1.failed, 0);
        assert!(r1.bytes > 0);
        assert!(!r1.reseeded);

        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&xs);
        seq.extend(&ys);
        for i in 0..2 {
            let v = gl.member_view(i);
            assert_eq!(v.estimated_peers(), 2.0);
            assert_eq!(v.estimated_total(), 1000.0);
            for q in [0.01, 0.5, 0.99] {
                assert_eq!(
                    v.query(q).unwrap(),
                    seq.quantile(q).unwrap(),
                    "member {i} q={q}"
                );
            }
        }

        // A second identical round changes nothing: drift hits 0.
        let r2 = gl.step();
        assert_eq!(r2.drift, 0.0);
        assert!(r2.converged);
        assert!(gl.view().converged());
        gl.shutdown();
    }

    #[test]
    fn global_view_implements_quantile_reader() {
        let xs: Vec<f64> = (1..=500).map(f64::from).collect();
        let ys: Vec<f64> = (501..=1000).map(f64::from).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();
        gl.step();
        let v = gl.view();
        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&xs);
        seq.extend(&ys);

        let reader: &dyn QuantileReader = v.as_ref();
        assert_eq!(reader.count(), 1000.0);
        assert!(!reader.is_empty());
        assert_eq!(
            reader.quantile(0.5).unwrap(),
            seq.quantile(0.5).unwrap()
        );
        assert_eq!(reader.cdf(250.0).unwrap(), seq.cdf(250.0).unwrap());
        assert_eq!(
            reader.quantiles(&[0.1, 0.9]).unwrap(),
            seq.quantiles(&[0.1, 0.9]).unwrap()
        );
        gl.shutdown();
    }

    #[test]
    fn service_epoch_advance_triggers_reseed() {
        let svc = service_with(&[1.0, 2.0, 3.0, 4.0]);
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::service(svc.clone()),
                static_member(&[10.0, 20.0]),
            ],
        )
        .unwrap();
        assert_eq!(gl.view().epoch(), 1);
        let r1 = gl.step();
        assert!(!r1.reseeded);
        let r2 = gl.step();
        assert!(r2.converged, "tiny fleet converges immediately");
        assert_eq!(r2.generation, 1);

        // New data, new epoch: the next round restarts the protocol.
        let mut w = svc.writer();
        w.insert(5.0);
        w.flush();
        svc.flush();
        let r3 = gl.step();
        assert!(r3.reseeded);
        assert_eq!(r3.generation, 2);
        assert!(!r3.converged, "drift resets on reseed");
        let v = gl.view();
        assert_eq!(v.epoch(), 2);
        assert_eq!(v.generation(), 2);

        // Steps without new epochs re-converge on the union of 5+2 items.
        gl.step();
        let v = gl.view();
        assert_eq!(v.estimated_total(), 7.0);
        gl.shutdown();
        Arc::try_unwrap(svc).unwrap().shutdown();
    }

    #[test]
    fn empty_members_step_without_panicking() {
        let empty: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::from_sketch(&empty),
                GossipMember::from_sketch(&empty),
            ],
        )
        .unwrap();
        let r = gl.step();
        assert!(!r.converged, "no probes on empty sketches");
        assert!(r.drift.is_infinite());
        assert!(matches!(gl.view().query(0.5), Err(SketchError::Empty)));
        gl.shutdown();
    }

    #[test]
    fn background_thread_runs_rounds() {
        let mut cfg = GossipLoopConfig::default();
        cfg.round_interval_ms = 2;
        let gl = GossipLoop::start(
            cfg,
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let v = gl.view();
            if v.round() >= 3 && v.converged() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "background loop never converged (round {})",
                v.round()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        let v = gl.shutdown();
        assert_eq!(v.estimated_total(), 4.0);
    }

    /// The serve side's §7.2 contract, exercised without sockets: a
    /// failing delivery rolls the serve member back bit-for-bit, and
    /// stale/busy pushes are refused with the state untouched.
    #[test]
    fn serve_exchange_commit_and_rollback_semantics() {
        let xs: Vec<f64> = (1..=400).map(f64::from).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&[1e4, 2e4])],
        )
        .unwrap();
        let handle = NodeHandle {
            worker: gl.worker.clone(),
            shared: gl.shared.clone(),
            stop: gl.stop.clone(),
        };
        let incoming = PeerState::init(7, &[5.0, 6.0, 7.0], 0.001, 1024).unwrap();
        let before = gl.view().state().clone();

        // Delivery fails → cancelled: serve state identical to before.
        let err = handle
            .serve_exchange(incoming.clone(), 1, |_, _| {
                Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "cut"))
            })
            .unwrap_err();
        assert!(matches!(err, ServeReject::Cancelled(_)), "{err}");
        let after = gl.view().state().clone();
        assert_eq!(after.n_tilde.to_bits(), before.n_tilde.to_bits());
        assert_eq!(after.q_tilde.to_bits(), before.q_tilde.to_bits());
        assert_eq!(
            after.sketch.positive_store().entries(),
            before.sketch.positive_store().entries()
        );

        // Stale generation → refused, untouched.
        let err = handle
            .serve_exchange(incoming.clone(), 0, |_, _| Ok(()))
            .unwrap_err();
        assert!(matches!(err, ServeReject::StaleGeneration(1)), "{err}");

        // Busy worker → refused.
        {
            let _round = gl.worker.lock().unwrap();
            let err = handle
                .serve_exchange(incoming.clone(), 1, |_, _| Ok(()))
                .unwrap_err();
            assert!(matches!(err, ServeReject::Busy), "{err}");
        }

        // Lineage mismatch → refused, untouched.
        let alien = PeerState::init(9, &[1.0], 0.5, 64).unwrap();
        let err = handle.serve_exchange(alien, 1, |_, _| Ok(())).unwrap_err();
        assert!(matches!(err, ServeReject::Lineage), "{err}");

        // Successful delivery commits: the averaged reply matches the
        // adopted serve state (both sides of the exchange agree).
        let mut delivered: Option<PeerState> = None;
        handle
            .serve_exchange(incoming, 1, |reply, gen| {
                assert_eq!(gen, 1);
                delivered = Some(reply.clone());
                Ok(())
            })
            .unwrap();
        let served = gl.view().state().clone();
        let reply = delivered.expect("delivered");
        assert_eq!(served.n_tilde.to_bits(), reply.n_tilde.to_bits());
        assert_eq!(served.q_tilde.to_bits(), reply.q_tilde.to_bits());
        assert_eq!(reply.id, 7, "reply keeps the initiator's id");
        gl.shutdown();
    }

    /// Hearing a newer generation (inbound push) makes the node reseed
    /// from its own summaries and adopt that generation before averaging.
    #[test]
    fn inbound_newer_generation_adopts_and_reseeds() {
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        // Mix the fleet first so a reseed is observable.
        gl.step();
        let handle = NodeHandle {
            worker: gl.worker.clone(),
            shared: gl.shared.clone(),
            stop: gl.stop.clone(),
        };
        let incoming = PeerState::init(5, &[9.0, 10.0], 0.001, 1024).unwrap();
        handle.serve_exchange(incoming, 6, |_, _| Ok(())).unwrap();
        let v = gl.view();
        assert_eq!(v.generation(), 6, "adopted the partner's generation");
        // Serve member reseeded (q̃ back to 1 for member 0) then averaged
        // once with the incoming state: q̃ = 0.5.
        assert_eq!(v.state().q_tilde, 0.5);
        gl.shutdown();
    }
}
