//! The continuous service-driven gossip loop: refresh → exchange → serve.
//!
//! PR 1 connected a [`QuantileService`] to the protocol one shot at a
//! time ([`ServicePeer`](super::ServicePeer)); this module closes the
//! paper's full production loop. A [`GossipLoop`] owns a small fleet of
//! **members** — live services and/or simulated remote peers — and runs
//! the cycle continuously while ingest keeps flowing:
//!
//! ```text
//!        ┌────────────────────────── every round ─────────────────────────┐
//!        │ refresh: any service published a newer epoch?                  │
//!        │   └─ yes → reseed every member's PeerState (protocol restart,  │
//!        │            Prop. 4: averaging re-converges from any states)    │
//!        │ exchange: one fan-out push–pull round over the overlay         │
//!        │            (the same Algorithm 4 loop the simulation runs)     │
//!        │ serve: publish one GlobalView per member through an            │
//!        │        ArcSwapCell — reads never block, never see a torn state │
//!        └────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Queries can therefore read **two** estimates: the service's own
//! [`Snapshot`](super::Snapshot) (local stream only, exact fold) and the
//! loop's [`GlobalView`] (network-converged estimate of the *union*
//! stream, Algorithm 6). Convergence is observable: each round the loop
//! probes a configured quantile set and reports the largest relative
//! drift since the previous round; once the drift falls below
//! [`GossipLoopConfig::convergence_rel`] the view is flagged converged.
//!
//! The reseed-all policy is load-bearing: `q̃` mass must stay exactly 1
//! across the fleet for the network-size estimate `p̃ = 1/q̃` to be
//! unbiased, so a newer epoch anywhere restarts *every* member from its
//! current local summary (the fusion-model restart of the stream-fusion
//! line of work) rather than patching one peer in place.
//!
//! Members are in-process today; the codec (`sketch::codec`) already
//! frames `PeerState`s byte-exactly, so a remote-peer transport can slot
//! in behind [`GossipMember`] without touching the loop.

use super::coordinator::QuantileService;
use super::swap::ArcSwapCell;
use crate::config::GossipLoopConfig;
use crate::gossip::{fan_out_round, GossipSketch, PeerState};
use crate::graph::Graph;
use crate::metrics::relative_error;
use crate::rng::{default_rng, Xoshiro256pp};
use crate::sketch::{SketchError, Store, UddSketch};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One participant in a [`GossipLoop`].
#[derive(Debug)]
pub enum GossipMember {
    /// A live ingest service: reseeded from its latest published
    /// snapshot whenever a newer epoch appears.
    Service(Arc<QuantileService>),
    /// A simulated remote peer with a fixed local summary (stands in for
    /// a codec-framed network peer until a transport lands).
    Static(GossipSketch),
}

impl GossipMember {
    /// A member fronting a live service.
    pub fn service(svc: Arc<QuantileService>) -> Self {
        GossipMember::Service(svc)
    }

    /// A simulated peer summarizing `data` with the given sketch
    /// parameters.
    pub fn from_dataset(data: &[f64], alpha: f64, max_buckets: usize) -> Result<Self> {
        let mut s: UddSketch = UddSketch::new(alpha, max_buckets)
            .map_err(anyhow::Error::msg)
            .context("static member sketch")?;
        s.extend(data);
        Ok(GossipMember::Static(s.convert_store()))
    }

    /// A simulated peer fronting an already-built local summary.
    pub fn from_sketch<S: Store>(sketch: &UddSketch<S>) -> Self {
        GossipMember::Static(sketch.convert_store())
    }
}

/// The network-converged estimate one member serves after a round.
///
/// Immutable, like [`Snapshot`](super::Snapshot): a handle keeps
/// answering consistently no matter how far the loop advances.
#[derive(Debug, Clone)]
pub struct GlobalView {
    round: u64,
    generation: u64,
    epoch: u64,
    drift: f64,
    converged: bool,
    state: PeerState,
}

impl GlobalView {
    /// Gossip rounds executed when this view was published.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Reseed generations so far (bumped whenever a service published a
    /// newer epoch and the protocol restarted).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Service epoch this member's local state was seeded from (0 for
    /// static members and before the first epoch).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Largest relative drift of the probe-quantile estimates between
    /// the last two rounds (∞ until two comparable rounds exist).
    pub fn drift(&self) -> f64 {
        self.drift
    }

    /// True once the drift fell to the configured threshold or below
    /// without an intervening reseed.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The member's averaged protocol state.
    pub fn state(&self) -> &PeerState {
        &self.state
    }

    /// Estimated fleet size `p̃ = round(1/q̃)` (Algorithm 6).
    pub fn estimated_peers(&self) -> f64 {
        self.state.estimated_peers()
    }

    /// Estimated union-stream length `Ñ = round(p̃ · Ñ_l)`.
    pub fn estimated_total(&self) -> f64 {
        self.state.estimated_total()
    }

    /// Estimate the q-quantile of the **union** stream (Algorithm 6).
    pub fn query(&self, q: f64) -> Result<f64, SketchError> {
        self.state.query(q)
    }

    /// Batch union-stream quantile queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.state.query(q)).collect()
    }
}

/// Telemetry for one executed loop round.
#[derive(Debug, Clone, Copy)]
pub struct GossipRoundReport {
    /// Rounds executed so far (this one included).
    pub round: u64,
    /// Current reseed generation.
    pub generation: u64,
    /// True when this round reseeded the fleet from fresh snapshots.
    pub reseeded: bool,
    /// Completed push–pull exchanges this round.
    pub exchanges: usize,
    /// Wire traffic this round (push + pull frames, codec byte-exact).
    pub bytes: usize,
    /// Largest relative probe drift vs the previous round (∞ if not yet
    /// comparable).
    pub drift: f64,
    /// Whether the drift is at or below the configured threshold.
    pub converged: bool,
}

/// Shared read side: one view cell per member.
struct Shared {
    views: Vec<ArcSwapCell<GlobalView>>,
}

/// Mutable loop state, owned by whichever thread runs the next round.
struct Worker {
    cfg: GossipLoopConfig,
    members: Vec<GossipMember>,
    states: Vec<PeerState>,
    /// Snapshot epoch each member was last seeded from (0 for static).
    epochs: Vec<u64>,
    /// Member indices whose probe estimates drive the drift metric:
    /// every service member, or member 0 in an all-static fleet.
    probe_members: Vec<usize>,
    graph: Graph,
    rng: Xoshiro256pp,
    online: Vec<bool>,
    round: u64,
    generation: u64,
    prev_probes: Option<Vec<f64>>,
    drift: f64,
    converged: bool,
}

/// A background gossip task over a fleet of services and simulated peers.
///
/// With `round_interval_ms > 0` a thread runs one round per interval;
/// [`GossipLoop::step`] additionally (or, at interval 0, exclusively)
/// runs rounds on demand — handy for deterministic tests and for the
/// `serve-gossip` CLI's per-round reporting.
///
/// ```
/// use duddsketch::config::GossipLoopConfig;
/// use duddsketch::service::{GossipLoop, GossipMember};
///
/// // Two simulated peers, each holding half of 1..=1000.
/// let lo: Vec<f64> = (1..=500).map(f64::from).collect();
/// let hi: Vec<f64> = (501..=1000).map(f64::from).collect();
/// let members = vec![
///     GossipMember::from_dataset(&lo, 0.001, 1024).unwrap(),
///     GossipMember::from_dataset(&hi, 0.001, 1024).unwrap(),
/// ];
/// let gl = GossipLoop::start(GossipLoopConfig::default(), members).unwrap();
/// gl.step(); // one exchange fully averages a 2-peer fleet
/// let view = gl.view();
/// let p50 = view.query(0.5).unwrap();
/// assert!((p50 - 500.0).abs() / 500.0 <= 0.001 + 1e-9);
/// assert_eq!(view.estimated_peers(), 2.0);
/// gl.shutdown();
/// ```
pub struct GossipLoop {
    shared: Arc<Shared>,
    worker: Arc<Mutex<Worker>>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for GossipLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let v = self.view();
        write!(
            f,
            "GossipLoop(members={}, round={}, generation={}, converged={})",
            self.shared.views.len(),
            v.round(),
            v.generation(),
            v.converged()
        )
    }
}

impl GossipLoop {
    /// Validate, seed every member from its current local summary, build
    /// the overlay, publish the round-0 views, and (when an interval is
    /// configured) spawn the background round thread.
    ///
    /// Member index is the peer id: member 0 plays Algorithm 3's
    /// distinguished role (`q̃ = 1`). Small fleets should keep the
    /// default [`GraphKind::Complete`](crate::config::GraphKind::Complete)
    /// overlay; the simulation
    /// topologies carry their own minimum-size requirements.
    pub fn start(cfg: GossipLoopConfig, members: Vec<GossipMember>) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        if members.len() < 2 {
            bail!("gossip loop needs at least 2 members, got {}", members.len());
        }
        // Exchanges merge sketches, and merges require one shared α₀
        // lineage — catch a mismatched fleet here instead of panicking
        // mid-round (possibly inside the background thread).
        let mut alpha0: Option<f64> = None;
        for (i, m) in members.iter().enumerate() {
            let a = match m {
                GossipMember::Service(svc) => svc.config().alpha,
                GossipMember::Static(sketch) => sketch.mapping().alpha0(),
            };
            match alpha0 {
                None => alpha0 = Some(a),
                Some(first) if first.to_bits() != a.to_bits() => bail!(
                    "gossip members must share one alpha0 lineage: \
                     member 0 has {first}, member {i} has {a}"
                ),
                Some(_) => {}
            }
        }
        let n = members.len();
        let master = default_rng(cfg.seed);
        let mut grng = master.derive(0x6EA4);
        let graph = crate::graph::from_kind(cfg.graph, n, &mut grng);
        let interval_ms = cfg.round_interval_ms;
        let probe_members: Vec<usize> = {
            let svc: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(_, m)| matches!(m, GossipMember::Service(_)))
                .map(|(i, _)| i)
                .collect();
            if svc.is_empty() {
                vec![0]
            } else {
                svc
            }
        };
        let mut worker = Worker {
            rng: master.derive(0x1005),
            cfg,
            members,
            states: Vec::new(),
            epochs: vec![0; n],
            probe_members,
            graph,
            online: vec![true; n],
            round: 0,
            generation: 0,
            prev_probes: None,
            drift: f64::INFINITY,
            converged: false,
        };
        worker.reseed();
        let shared = Arc::new(Shared {
            views: (0..n)
                .map(|i| ArcSwapCell::new(Arc::new(worker.view_of(i))))
                .collect(),
        });
        let worker = Arc::new(Mutex::new(worker));
        let stop = Arc::new(AtomicBool::new(false));
        let thread = if interval_ms > 0 {
            let worker = worker.clone();
            let shared = shared.clone();
            let stop = stop.clone();
            let interval = Duration::from_millis(interval_ms);
            Some(
                std::thread::Builder::new()
                    .name("dudd-gossip".into())
                    .spawn(move || round_loop(&worker, &shared, &stop, interval))
                    .context("spawning gossip loop thread")?,
            )
        } else {
            None
        };
        Ok(Self {
            shared,
            worker,
            stop,
            thread,
        })
    }

    /// Number of members in the fleet.
    pub fn members(&self) -> usize {
        self.shared.views.len()
    }

    /// Run one refresh → exchange → serve round synchronously and return
    /// its telemetry. Safe alongside the background thread (rounds
    /// serialize on the worker lock).
    pub fn step(&self) -> GossipRoundReport {
        let mut w = self.worker.lock().expect("gossip worker poisoned");
        let report = w.run_round();
        w.publish(&self.shared);
        report
    }

    /// The latest global view of member 0. Lock-free.
    pub fn view(&self) -> Arc<GlobalView> {
        self.member_view(0)
    }

    /// The latest global view of member `i` (panics when out of range).
    pub fn member_view(&self, i: usize) -> Arc<GlobalView> {
        self.shared.views[i].load()
    }

    /// Stop the background thread (if any) and return the final view of
    /// member 0.
    pub fn shutdown(mut self) -> Arc<GlobalView> {
        self.stop_thread();
        self.view()
    }

    fn stop_thread(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for GossipLoop {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

/// Background driver: one round per interval, stop-aware in ≤10 ms
/// steps so shutdown never waits out a long interval.
fn round_loop(
    worker: &Mutex<Worker>,
    shared: &Shared,
    stop: &AtomicBool,
    interval: Duration,
) {
    let step = Duration::from_millis(10).min(interval);
    'outer: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'outer;
            }
            let d = step.min(interval - slept);
            std::thread::sleep(d);
            slept += d;
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let mut w = worker.lock().expect("gossip worker poisoned");
        w.run_round();
        w.publish(shared);
    }
}

impl Worker {
    /// Seed every member's `PeerState` from its current local summary
    /// and start a new generation. Restarting *all* members keeps the
    /// averaged `q̃` mass at exactly 1 (see the module docs).
    fn reseed(&mut self) {
        let mut states = Vec::with_capacity(self.members.len());
        for (i, m) in self.members.iter().enumerate() {
            let state = match m {
                GossipMember::Service(svc) => {
                    let snap = svc.snapshot();
                    self.epochs[i] = snap.epoch();
                    PeerState::from_sketch(i, snap.sketch())
                }
                GossipMember::Static(sketch) => PeerState::from_sketch(i, sketch),
            };
            states.push(state);
        }
        self.states = states;
        self.generation += 1;
        self.prev_probes = None;
        self.drift = f64::INFINITY;
        self.converged = false;
    }

    /// True when any service member has published an epoch newer than
    /// the one its state was seeded from.
    fn stale(&self) -> bool {
        self.members.iter().enumerate().any(|(i, m)| match m {
            GossipMember::Service(svc) => svc.snapshot().epoch() != self.epochs[i],
            GossipMember::Static(_) => false,
        })
    }

    /// Probe-quantile estimates across the probe members, or `None`
    /// while any probe member cannot answer yet (empty sketch).
    fn probes(&self) -> Option<Vec<f64>> {
        let mut out =
            Vec::with_capacity(self.probe_members.len() * self.cfg.probe_quantiles.len());
        for &i in &self.probe_members {
            for &q in &self.cfg.probe_quantiles {
                match self.states[i].query(q) {
                    Ok(v) => out.push(v),
                    Err(_) => return None,
                }
            }
        }
        Some(out)
    }

    /// One full refresh → exchange cycle (the serve half is
    /// [`Worker::publish`]).
    fn run_round(&mut self) -> GossipRoundReport {
        let reseeded = self.stale();
        if reseeded {
            self.reseed();
        }
        self.round += 1;
        let (exchanges, _dropped, bytes) = fan_out_round(
            &mut self.states,
            &self.graph,
            &self.online,
            self.cfg.fan_out,
            0.0,
            &mut self.rng,
        );
        let cur = self.probes();
        self.drift = match (&self.prev_probes, &cur) {
            (Some(prev), Some(cur)) => prev
                .iter()
                .zip(cur)
                .map(|(&p, &c)| relative_error(c, p))
                .fold(0.0, f64::max),
            _ => f64::INFINITY,
        };
        self.converged = self.drift <= self.cfg.convergence_rel;
        self.prev_probes = cur;
        GossipRoundReport {
            round: self.round,
            generation: self.generation,
            reseeded,
            exchanges,
            bytes,
            drift: self.drift,
            converged: self.converged,
        }
    }

    /// Build the view a round publishes for member `i`.
    fn view_of(&self, i: usize) -> GlobalView {
        GlobalView {
            round: self.round,
            generation: self.generation,
            epoch: self.epochs[i],
            drift: self.drift,
            converged: self.converged,
            state: self.states[i].clone(),
        }
    }

    /// Serve: publish every member's fresh view.
    fn publish(&self, shared: &Shared) {
        for (i, cell) in shared.views.iter().enumerate() {
            cell.store(Arc::new(self.view_of(i)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;

    fn static_member(values: &[f64]) -> GossipMember {
        GossipMember::from_dataset(values, 0.001, 1024).unwrap()
    }

    fn service_with(values: &[f64]) -> Arc<QuantileService> {
        let mut cfg = ServiceConfig::default();
        cfg.shards = 2;
        let svc = QuantileService::start(cfg).unwrap();
        let mut w = svc.writer();
        w.insert_batch(values);
        w.flush();
        svc.flush();
        Arc::new(svc)
    }

    #[test]
    fn loop_requires_two_members() {
        let cfg = GossipLoopConfig::default();
        let err = GossipLoop::start(cfg, vec![static_member(&[1.0])]).unwrap_err();
        assert!(err.to_string().contains("at least 2"));
    }

    #[test]
    fn loop_rejects_mismatched_alpha_lineages() {
        let a = GossipMember::from_dataset(&[1.0, 2.0], 0.001, 1024).unwrap();
        let b = GossipMember::from_dataset(&[3.0, 4.0], 0.01, 1024).unwrap();
        let err = GossipLoop::start(GossipLoopConfig::default(), vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("alpha0 lineage"), "{err}");
    }

    #[test]
    fn two_static_members_average_in_one_round() {
        let xs: Vec<f64> = (1..=600).map(|i| i as f64).collect();
        let ys: Vec<f64> = (601..=1000).map(|i| i as f64).collect();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![static_member(&xs), static_member(&ys)],
        )
        .unwrap();

        // Round 0: seeded but unexchanged — member 0 only knows itself.
        let v0 = gl.view();
        assert_eq!(v0.round(), 0);
        assert_eq!(v0.generation(), 1);
        assert!(!v0.converged());
        assert_eq!(v0.estimated_peers(), 1.0);

        let r1 = gl.step();
        assert_eq!(r1.round, 1);
        assert!(r1.exchanges >= 1);
        assert!(r1.bytes > 0);
        assert!(!r1.reseeded);

        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&xs);
        seq.extend(&ys);
        for i in 0..2 {
            let v = gl.member_view(i);
            assert_eq!(v.estimated_peers(), 2.0);
            assert_eq!(v.estimated_total(), 1000.0);
            for q in [0.01, 0.5, 0.99] {
                assert_eq!(
                    v.query(q).unwrap(),
                    seq.quantile(q).unwrap(),
                    "member {i} q={q}"
                );
            }
        }

        // A second identical round changes nothing: drift hits 0.
        let r2 = gl.step();
        assert_eq!(r2.drift, 0.0);
        assert!(r2.converged);
        assert!(gl.view().converged());
        gl.shutdown();
    }

    #[test]
    fn service_epoch_advance_triggers_reseed() {
        let svc = service_with(&[1.0, 2.0, 3.0, 4.0]);
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::service(svc.clone()),
                static_member(&[10.0, 20.0]),
            ],
        )
        .unwrap();
        assert_eq!(gl.view().epoch(), 1);
        let r1 = gl.step();
        assert!(!r1.reseeded);
        let r2 = gl.step();
        assert!(r2.converged, "tiny fleet converges immediately");
        assert_eq!(r2.generation, 1);

        // New data, new epoch: the next round restarts the protocol.
        let mut w = svc.writer();
        w.insert(5.0);
        w.flush();
        svc.flush();
        let r3 = gl.step();
        assert!(r3.reseeded);
        assert_eq!(r3.generation, 2);
        assert!(!r3.converged, "drift resets on reseed");
        let v = gl.view();
        assert_eq!(v.epoch(), 2);
        assert_eq!(v.generation(), 2);

        // Steps without new epochs re-converge on the union of 5+2 items.
        gl.step();
        let v = gl.view();
        assert_eq!(v.estimated_total(), 7.0);
        gl.shutdown();
        Arc::try_unwrap(svc).unwrap().shutdown();
    }

    #[test]
    fn empty_members_step_without_panicking() {
        let empty: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        let gl = GossipLoop::start(
            GossipLoopConfig::default(),
            vec![
                GossipMember::from_sketch(&empty),
                GossipMember::from_sketch(&empty),
            ],
        )
        .unwrap();
        let r = gl.step();
        assert!(!r.converged, "no probes on empty sketches");
        assert!(r.drift.is_infinite());
        assert!(matches!(gl.view().query(0.5), Err(SketchError::Empty)));
        gl.shutdown();
    }

    #[test]
    fn background_thread_runs_rounds() {
        let mut cfg = GossipLoopConfig::default();
        cfg.round_interval_ms = 2;
        let gl = GossipLoop::start(
            cfg,
            vec![static_member(&[1.0, 2.0]), static_member(&[3.0, 4.0])],
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let v = gl.view();
            if v.round() >= 3 && v.converged() {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "background loop never converged (round {})",
                v.round()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        let v = gl.shutdown();
        assert_eq!(v.estimated_total(), 4.0);
    }
}
