//! Ingest shards: worker threads each owning a private sketch.
//!
//! A shard is a `std::thread` plus a **bounded** mpsc queue of batches
//! (backpressure: producers block when a shard falls behind instead of
//! growing memory without bound). Each worker folds its batches into a
//! private [`UddSketch<DenseStore>`] — the fast bulk-ingest
//! representation — with zero synchronization on the hot path; the only
//! cross-thread traffic is whole batches in and epoch drains out.
//!
//! A drain hands the accumulated *delta* sketch to the coordinator and
//! resets the shard, so mergeability (Definition 7) makes the epoch fold
//! exact: the merged deltas equal one sequential sketch over the union
//! of everything the shards consumed.

#![forbid(unsafe_code)]

use crate::obs::ServiceMetrics;
use crate::sketch::{DenseStore, UddSketch};
use anyhow::{Context, Result};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::thread::JoinHandle;

/// Messages a shard worker consumes, in FIFO order. `Drain` therefore
/// observes every batch enqueued before it.
pub(crate) enum ShardMsg {
    /// Insert a batch of values (weight +1 each).
    Ingest(Vec<f64>),
    /// Apply weighted updates (turnstile: weight −1 deletes).
    Update(Vec<(f64, f64)>),
    /// Hand the delta sketch accumulated since the last drain to the
    /// coordinator and reset.
    Drain(Sender<ShardDelta>),
    /// Retire the worker. Sent by service shutdown/teardown so joining
    /// never depends on every outstanding `ServiceWriter` (each holds a
    /// sender clone) having been dropped first.
    Stop,
}

/// One shard's contribution to an epoch.
#[derive(Debug)]
pub struct ShardDelta {
    /// Shard index (0-based).
    pub shard: usize,
    /// Everything this shard ingested since its previous drain.
    pub sketch: UddSketch<DenseStore>,
    /// Operations (inserts + updates) folded into `sketch`.
    pub ops: u64,
}

/// A running shard: its queue plus the worker's join handle.
pub(crate) struct ShardHandle {
    pub tx: SyncSender<ShardMsg>,
    pub join: JoinHandle<()>,
}

/// Spawn shard `id`. Sketch parameters are validated here so service
/// startup fails fast instead of panicking a worker. `metrics`, when
/// present, receives the shard's ingest counters (values / batches /
/// dropped) — flushed once per batch, so the per-value hot loop never
/// touches an atomic.
pub(crate) fn spawn_shard(
    id: usize,
    alpha: f64,
    max_buckets: usize,
    queue_depth: usize,
    metrics: Option<ServiceMetrics>,
) -> Result<ShardHandle> {
    let sketch: UddSketch<DenseStore> = UddSketch::new(alpha, max_buckets)
        .with_context(|| format!("shard {id}: invalid sketch parameters"))?;
    let (tx, rx) = std::sync::mpsc::sync_channel(queue_depth.max(1));
    let join = std::thread::Builder::new()
        .name(format!("dudd-shard-{id}"))
        .spawn(move || shard_loop(id, alpha, max_buckets, sketch, rx, metrics))
        .with_context(|| format!("spawning shard {id}"))?;
    Ok(ShardHandle { tx, join })
}

fn shard_loop(
    id: usize,
    alpha: f64,
    max_buckets: usize,
    mut sketch: UddSketch<DenseStore>,
    rx: Receiver<ShardMsg>,
    metrics: Option<ServiceMetrics>,
) {
    let mut ops: u64 = 0;
    while let Ok(msg) = rx.recv() {
        match msg {
            // Non-finite values are dropped here rather than inherited as
            // the sequential path's assert: a production stream must not
            // be able to panic a worker and silently lose the shard's
            // un-drained data.
            ShardMsg::Ingest(xs) => {
                let mut kept: u64 = 0;
                for &x in &xs {
                    if x.is_finite() {
                        sketch.insert(x);
                        kept += 1;
                    }
                }
                ops += kept;
                if let Some(m) = &metrics {
                    m.batches.inc();
                    m.values.add(kept);
                    m.dropped.add(xs.len() as u64 - kept);
                }
            }
            ShardMsg::Update(us) => {
                let total = us.len() as u64;
                let mut kept: u64 = 0;
                for (x, w) in us {
                    if x.is_finite() && w.is_finite() {
                        sketch.update(x, w);
                        kept += 1;
                    }
                }
                ops += kept;
                if let Some(m) = &metrics {
                    m.batches.inc();
                    m.values.add(kept);
                    m.dropped.add(total - kept);
                }
            }
            ShardMsg::Drain(reply) => {
                let drained = std::mem::replace(
                    &mut sketch,
                    UddSketch::new(alpha, max_buckets)
                        .expect("parameters validated at spawn"),
                );
                // A vanished coordinator just means the delta is dropped
                // along with the service; nothing to do.
                let _ = reply.send(ShardDelta {
                    shard: id,
                    sketch: drained,
                    ops,
                });
                ops = 0;
            }
            ShardMsg::Stop => break,
        }
    }
    // Stop received (graceful shutdown drained us first) or every sender
    // dropped. Writers still alive see a disconnected channel and skip
    // this shard from here on.
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn shard_folds_batches_and_drains_delta() {
        let h = spawn_shard(3, 0.01, 256, 8, None).unwrap();
        h.tx.send(ShardMsg::Ingest(vec![1.0, 2.0, 3.0])).unwrap();
        h.tx.send(ShardMsg::Update(vec![(4.0, 1.0), (4.0, -1.0)]))
            .unwrap();
        let (tx, rx) = mpsc::channel();
        h.tx.send(ShardMsg::Drain(tx)).unwrap();
        let delta = rx.recv().unwrap();
        assert_eq!(delta.shard, 3);
        assert_eq!(delta.ops, 5);
        assert_eq!(delta.sketch.count(), 3.0);

        // Drain resets: the next delta only holds newer data.
        h.tx.send(ShardMsg::Ingest(vec![10.0])).unwrap();
        let (tx, rx) = mpsc::channel();
        h.tx.send(ShardMsg::Drain(tx)).unwrap();
        let delta = rx.recv().unwrap();
        assert_eq!(delta.ops, 1);
        assert_eq!(delta.sketch.count(), 1.0);

        drop(h.tx);
        h.join.join().unwrap();
    }

    #[test]
    fn non_finite_values_are_dropped_not_fatal() {
        let h = spawn_shard(0, 0.01, 256, 8, None).unwrap();
        h.tx.send(ShardMsg::Ingest(vec![1.0, f64::NAN, f64::INFINITY, 2.0]))
            .unwrap();
        h.tx.send(ShardMsg::Update(vec![
            (3.0, 1.0),
            (f64::NEG_INFINITY, 1.0),
            (4.0, f64::NAN),
        ]))
        .unwrap();
        let (tx, rx) = mpsc::channel();
        h.tx.send(ShardMsg::Drain(tx)).unwrap();
        let delta = rx.recv().unwrap();
        // Only {1.0, 2.0, 3.0} applied; the worker survived.
        assert_eq!(delta.ops, 3);
        assert_eq!(delta.sketch.count(), 3.0);
        drop(h.tx);
        h.join.join().unwrap();
    }

    #[test]
    fn spawn_rejects_bad_parameters() {
        assert!(spawn_shard(0, 2.0, 256, 8, None).is_err());
        assert!(spawn_shard(0, 0.01, 1, 8, None).is_err());
    }

    /// An instrumented shard books every batch, every folded value, and
    /// every dropped non-finite on the installed counters.
    #[test]
    fn instrumented_shard_counts_values_batches_and_drops() {
        let obs = crate::obs::NodeMetrics::standalone();
        let h = spawn_shard(0, 0.01, 256, 8, Some(obs.service.clone())).unwrap();
        h.tx.send(ShardMsg::Ingest(vec![1.0, f64::NAN, 2.0])).unwrap();
        h.tx.send(ShardMsg::Update(vec![(3.0, 1.0), (f64::INFINITY, 1.0)]))
            .unwrap();
        let (tx, rx) = mpsc::channel();
        h.tx.send(ShardMsg::Drain(tx)).unwrap();
        let delta = rx.recv().unwrap();
        assert_eq!(delta.ops, 3);
        assert_eq!(obs.service.batches.get(), 2);
        assert_eq!(obs.service.values.get(), 3);
        assert_eq!(obs.service.dropped.get(), 2);
        drop(h.tx);
        h.join.join().unwrap();
    }
}
