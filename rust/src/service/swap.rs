//! Lock-free snapshot publication (arc-swap is unavailable offline —
//! DESIGN.md §6, so this is a minimal in-tree equivalent).
//!
//! [`ArcSwapCell`] holds an `Arc<T>` that one writer (the coordinator)
//! replaces wholesale while any number of readers (query threads) take
//! cheap strong references. Readers never block on the writer and never
//! touch a `RwLock`: a load is two atomic RMWs plus an atomic pointer
//! read.
//!
//! Reclamation uses an RCU-style quiescence scheme instead of hazard
//! pointers: every published `Arc` is also retained in a writer-side
//! retire list, so the raw pointer a reader observes is always backed by
//! at least one strong count. A retired entry is dropped only after the
//! writer observes a moment with **zero** readers inside the load window
//! (pointer read → refcount bump) *after* the entry was unpublished — at
//! which point no reader can still resurrect it. Publishing is rare
//! (once per epoch) and readers are fast, so the retire list stays at a
//! handful of entries in practice and is bounded by the service lifetime
//! in the worst case.
//!
//! This is the **only** module in the crate allowed to contain `unsafe`
//! — the `unsafe` rule of `dudd-analyze` pins it here and demands
//! `#![forbid(unsafe_code)]` everywhere else (see `docs/ANALYSIS.md`).
//! The reclamation claim is exercised dynamically in CI: Miri
//! interprets these tests, and `rust/tests/loom_swap.rs` model-checks
//! the announce/swap/trim interleavings under loom.

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A swappable `Arc<T>` with a lock-free read path.
///
/// ```
/// use std::sync::Arc;
/// use duddsketch::service::ArcSwapCell;
///
/// let cell = ArcSwapCell::new(Arc::new(1u64));
/// assert_eq!(*cell.load(), 1);
/// cell.store(Arc::new(2));
/// assert_eq!(*cell.load(), 2);
/// ```
#[derive(Debug)]
pub struct ArcSwapCell<T> {
    /// Raw pointer obtained from `Arc::into_raw`; always points at a `T`
    /// kept alive by `retired` (and therefore safe to resurrect).
    ptr: AtomicPtr<T>,
    /// Readers currently between the pointer read and the refcount bump.
    readers: AtomicUsize,
    /// Strong handles pinning every published value until a quiescent
    /// trim proves no reader can still observe its pointer.
    retired: Mutex<Vec<Arc<T>>>,
}

impl<T> ArcSwapCell<T> {
    fn lock_retired(&self) -> std::sync::MutexGuard<'_, Vec<Arc<T>>> {
        self.retired.lock().expect("retire list poisoned")
    }

    /// Create the cell with an initial value.
    pub fn new(value: Arc<T>) -> Self {
        let retired = Mutex::new(vec![value.clone()]);
        let ptr = AtomicPtr::new(Arc::into_raw(value) as *mut T);
        Self {
            ptr,
            readers: AtomicUsize::new(0),
            retired,
        }
    }

    /// Take a strong reference to the current value. Never blocks; never
    /// takes a lock.
    pub fn load(&self) -> Arc<T> {
        self.readers.fetch_add(1, Ordering::SeqCst);
        let p = self.ptr.load(Ordering::SeqCst);
        // SAFETY: `p` came from `Arc::into_raw`, and the retire list keeps
        // a strong handle for every pointer ever published until a
        // quiescent period (readers == 0, observed under the retire lock)
        // has passed *after* it was unpublished. We announced ourselves
        // via `readers` before reading the pointer, so no trim that could
        // free `p` can have been decided while we are in this window:
        // the strong count is >= 1 here.
        let arc = unsafe {
            Arc::increment_strong_count(p);
            Arc::from_raw(p)
        };
        self.readers.fetch_sub(1, Ordering::SeqCst);
        arc
    }

    /// Publish a new value, retiring the previous one. Intended for a
    /// single (or externally serialized) writer; concurrent stores are
    /// nevertheless safe — they serialize on the retire lock.
    pub fn store(&self, value: Arc<T>) {
        let mut retired = self.lock_retired();
        retired.push(value.clone());
        let new = Arc::into_raw(value) as *mut T;
        let old = self.ptr.swap(new, Ordering::SeqCst);
        // SAFETY: `old` was published via `Arc::into_raw`; this reclaims
        // exactly that reference. The retire list still holds a strong
        // handle, so stragglers resurrecting `old` stay sound.
        unsafe { drop(Arc::from_raw(old)) };
        // Quiescent trim: once a moment with no reader inside the load
        // window is observed, every reader that saw an unpublished
        // pointer has either finished (its interest shows up as
        // strong_count > 1, possibly already dropped again) or never saw
        // it; new readers can only observe `new`, which is always
        // retained. A reader's window is two atomic ops wide while
        // publishes are per-epoch, so a short bounded spin virtually
        // always catches a quiescent instant even under saturated query
        // traffic — and a miss just defers the trim to the next publish.
        for _ in 0..1024 {
            if self.readers.load(Ordering::SeqCst) == 0 {
                let current = new as *const T;
                retired
                    .retain(|a| Arc::as_ptr(a) == current || Arc::strong_count(a) > 1);
                break;
            }
            std::hint::spin_loop();
        }
    }

    /// Entries currently pinned by the reclamation scheme (diagnostics;
    /// ≥ 1, the current value).
    pub fn retired_len(&self) -> usize {
        self.lock_retired().len()
    }
}

impl<T> Drop for ArcSwapCell<T> {
    fn drop(&mut self) {
        let p = *self.ptr.get_mut();
        // SAFETY: reclaims the `into_raw` reference of the still-published
        // value; the matching retire-list handle drops with `self.retired`.
        unsafe { drop(Arc::from_raw(p)) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// Miri executes these same interleavings with its much slower
    /// interpreter; shrink the iteration counts there, keep the full
    /// counts everywhere else.
    fn iters(n: u64) -> u64 {
        if cfg!(miri) {
            (n / 50).max(2)
        } else {
            n
        }
    }

    #[test]
    fn store_then_load_roundtrip() {
        let cell = ArcSwapCell::new(Arc::new(0u64));
        for k in 1..=iters(100) {
            cell.store(Arc::new(k));
            assert_eq!(*cell.load(), k);
        }
    }

    #[test]
    fn quiescent_trim_bounds_retire_list() {
        let cell = ArcSwapCell::new(Arc::new(0u64));
        let n = iters(1000);
        for k in 1..=n {
            cell.store(Arc::new(k));
        }
        // Single-threaded: every store observes zero readers, so only the
        // current value stays pinned.
        assert_eq!(cell.retired_len(), 1);
        assert_eq!(*cell.load(), n);
    }

    #[test]
    fn held_reference_survives_many_publishes() {
        let cell = ArcSwapCell::new(Arc::new(7u64));
        let held = cell.load();
        let n = iters(100);
        for k in 0..n {
            cell.store(Arc::new(k));
        }
        assert_eq!(*held, 7);
        assert_eq!(*cell.load(), n - 1);
    }

    #[test]
    fn concurrent_readers_see_monotone_values() {
        let cell = Arc::new(ArcSwapCell::new(Arc::new(0u64)));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let cell = cell.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                let mut seen = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let v = *cell.load();
                    assert!(v >= last, "snapshot went backwards: {last} -> {v}");
                    last = v;
                    seen += 1;
                }
                seen
            }));
        }
        let n = iters(20_000);
        for k in 1..=n {
            cell.store(Arc::new(k));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
        assert_eq!(*cell.load(), n);
    }
}
