//! Time source injection for the membership plane's suspicion, backoff,
//! and tombstone clocks.
//!
//! Production nodes read the monotonic wall clock ([`SystemClock`], the
//! default everywhere). The discrete-event simulator
//! ([`sim`](crate::sim)) swaps in a [`VirtualClock`] shared by every
//! simulated node and advanced explicitly between rounds, which makes
//! every time-based membership transition (alive → suspect → dead,
//! backoff gating, tombstone GC) a *deterministic* function of the
//! scenario instead of a race against the test host's scheduler.
//!
//! The abstraction deliberately stays on [`Instant`]: a virtual instant
//! is a fixed base instant plus an explicitly-advanced offset, so all
//! existing `Instant + Duration` / `duration_since` arithmetic in the
//! membership plane works unchanged.

#![forbid(unsafe_code)]

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A monotonic time source the membership plane reads instead of calling
/// [`Instant::now`] directly.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// The current instant of this time source.
    fn now(&self) -> Instant;
}

/// The production clock: [`Instant::now`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// A simulated clock: a fixed base instant plus an offset that advances
/// only when [`VirtualClock::advance`] is called. Shared (via `Arc`)
/// across every node of a simulated fleet so they observe one timeline.
///
/// ```
/// use duddsketch::service::clock::{Clock, VirtualClock};
/// use std::time::Duration;
///
/// let clock = VirtualClock::new();
/// let t0 = clock.now();
/// clock.advance(Duration::from_millis(500));
/// assert_eq!(clock.now().duration_since(t0), Duration::from_millis(500));
/// assert_eq!(clock.elapsed(), Duration::from_millis(500));
/// ```
#[derive(Debug)]
pub struct VirtualClock {
    base: Instant,
    offset: Mutex<Duration>,
}

impl VirtualClock {
    /// A virtual clock starting at offset zero.
    pub fn new() -> Self {
        Self {
            base: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
        }
    }

    fn lock_offset(&self) -> std::sync::MutexGuard<'_, Duration> {
        self.offset.lock().expect("virtual clock poisoned")
    }

    /// Advance virtual time by `d`.
    pub fn advance(&self, d: Duration) {
        *self.lock_offset() += d;
    }

    /// Virtual time elapsed since construction.
    pub fn elapsed(&self) -> Duration {
        *self.lock_offset()
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Instant {
        self.base + self.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_only_moves_on_advance() {
        let c = VirtualClock::new();
        let a = c.now();
        assert_eq!(c.now(), a, "virtual time must not flow on its own");
        c.advance(Duration::from_secs(2));
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now().duration_since(a), Duration::from_millis(2_250));
    }
}
