//! Fluent construction of a serving node: ingest service + gossip loop +
//! transport in one expression.
//!
//! [`Node::builder()`] is the primary construction path for the service
//! layer — it replaces the mutate-a-default-[`ServiceConfig`] pattern:
//! every knob is a named method, validation runs once at
//! [`NodeBuilder::build`] with the offending key named, and the gossip
//! loop / transport wiring (member ordering, serve identity, accept
//! loop) is handled in one place instead of at every call site.
//!
//! ```
//! use duddsketch::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! // A standalone ingest node (no gossip):
//! let node = Node::builder().alpha(0.001).shards(2).build()?;
//! let mut w = node.writer();
//! w.insert_batch(&[1.0, 2.0, 3.0]);
//! w.flush();
//! assert_eq!(node.flush().count(), 3.0);
//! node.shutdown();
//!
//! // A node gossiping with two simulated peers:
//! let data: Vec<f64> = (1..=1000).map(f64::from).collect();
//! let node = Node::builder()
//!     .alpha(0.001)
//!     .shards(2)
//!     .window(0)
//!     .peer(GossipMember::from_dataset(&data[..500], 0.001, 1024)?)
//!     .peer(GossipMember::from_dataset(&data[500..], 0.001, 1024)?)
//!     .build()?;
//! let mut streak = 0;
//! for _ in 0..500 {
//!     let report = node.step().expect("gossip enabled");
//!     streak = if report.converged { streak + 1 } else { 0 };
//!     if streak >= 3 {
//!         break;
//!     }
//! }
//! let view = node.global_view().expect("gossip enabled");
//! assert_eq!(view.estimated_peers(), 3.0);
//! node.shutdown();
//! # Ok(())
//! # }
//! ```
//!
//! For a TCP fleet, bind every node's transport first (so the address
//! book exists before any loop starts), then build each node with
//! `.remote_peer(addr)` entries in **global member order** and
//! `.self_index(k)` marking where this node's own service sits — member
//! index is the peer id, so all nodes must agree on the ordering (and
//! share one gossip seed/graph). See the `serve-remote` CLI subcommand
//! and `rust/tests/integration_remote.rs` for complete fleets.

#![forbid(unsafe_code)]

use super::coordinator::{QuantileService, ServiceWriter};
use super::gossip_loop::{GlobalView, GossipLoop, GossipMember, GossipRoundReport};
use super::membership::{MemberStatus, MemberTable, Membership, MembershipConfig};
use super::snapshot::Snapshot;
use super::transport::{InProcessTransport, Transport};
use crate::config::{GossipLoopConfig, ServiceConfig};
use crate::obs::{EventSink, MembersSource, MetricsRegistry, MetricsServer, NodeMetrics};
use anyhow::{bail, Context, Result};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;

/// A serving node: one [`QuantileService`] plus (optionally) the
/// [`GossipLoop`] that keeps it converged with a fleet.
///
/// Queries pick their surface: [`Node::snapshot`] (this node's stream,
/// exact epoch fold) or [`Node::global_view`] (the fleet's union stream,
/// Algorithm 6) — both implement
/// [`QuantileReader`](crate::sketch::QuantileReader).
#[derive(Debug)]
pub struct Node {
    service: Arc<QuantileService>,
    gossip: Option<GossipLoop>,
    self_member: usize,
    /// Every layer of this node reports into this bundle's shared
    /// registry — scrapable when a `/metrics` listener is bound, and
    /// readable in-process either way.
    obs: NodeMetrics,
    metrics_server: Option<MetricsServer>,
}

impl Node {
    /// Start building a node. See the [module docs](self) for examples.
    pub fn builder() -> NodeBuilder {
        NodeBuilder {
            cfg: ServiceConfig::default(),
            peers: Vec::new(),
            self_index: 0,
            transport: None,
            bootstrap: false,
        }
    }

    /// The underlying ingest service.
    pub fn service(&self) -> &Arc<QuantileService> {
        &self.service
    }

    /// A new batching ingest handle (one per producer thread).
    pub fn writer(&self) -> ServiceWriter {
        self.service.writer()
    }

    /// The latest published local snapshot. Lock-free.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.service.snapshot()
    }

    /// Run one epoch synchronously and return the fresh snapshot.
    pub fn flush(&self) -> Arc<Snapshot> {
        self.service.flush()
    }

    /// The node's gossip loop, when peers were configured.
    pub fn gossip(&self) -> Option<&GossipLoop> {
        self.gossip.as_ref()
    }

    /// The node's membership runtime (dynamic fleets only — see
    /// [`NodeBuilder::membership_bootstrap`] / [`NodeBuilder::join`]).
    pub fn membership(&self) -> Option<&Arc<Membership>> {
        self.gossip.as_ref().and_then(|g| g.membership())
    }

    /// Run one gossip round synchronously (None without gossip).
    pub fn step(&self) -> Option<GossipRoundReport> {
        self.gossip.as_ref().map(|g| g.step())
    }

    /// This node's latest [`GlobalView`] (None without gossip). Lock-free.
    pub fn global_view(&self) -> Option<Arc<GlobalView>> {
        self.gossip.as_ref().map(|g| g.member_view(self.self_member))
    }

    /// This node's member index (= peer id) in the fleet.
    pub fn self_member(&self) -> usize {
        self.self_member
    }

    /// The address this node serves inbound exchanges on (None for
    /// in-process or client-only transports, or without gossip).
    pub fn listen_addr(&self) -> Option<SocketAddr> {
        self.gossip.as_ref().and_then(|g| g.listen_addr())
    }

    /// The node's metric handles. Every instrumented layer (ingest
    /// shards, gossip loop, transport, membership) reports into this
    /// bundle's shared registry whether or not a `/metrics` listener is
    /// bound.
    pub fn metrics(&self) -> &NodeMetrics {
        &self.obs
    }

    /// The bound `GET /metrics` listen address (resolves port 0), or
    /// `None` when [`NodeBuilder::metrics_bind`] was not configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_server.as_ref().map(MetricsServer::local_addr)
    }

    /// Stop the gossip loop (if any) and the service; returns the final
    /// local snapshot.
    pub fn shutdown(self) -> Arc<Snapshot> {
        let Node {
            service,
            gossip,
            metrics_server,
            ..
        } = self;
        if let Some(s) = metrics_server {
            s.shutdown();
        }
        if let Some(g) = gossip {
            g.shutdown();
        }
        match Arc::try_unwrap(service) {
            Ok(svc) => svc.shutdown(),
            // A detached exchange handler can pin the Arc for up to one
            // transport deadline; the service's Drop retires the shards
            // once the last handle goes.
            Err(arc) => {
                let snap = arc.flush();
                drop(arc);
                snap
            }
        }
    }
}

/// Builder returned by [`Node::builder`]. Every method is a named
/// configuration knob; [`NodeBuilder::build`] validates the whole
/// configuration with named-key errors before anything spawns.
#[derive(Debug)]
pub struct NodeBuilder {
    cfg: ServiceConfig,
    peers: Vec<GossipMember>,
    self_index: usize,
    transport: Option<Arc<dyn Transport>>,
    /// Dynamic membership: found a new fleet as its first member.
    bootstrap: bool,
}

impl NodeBuilder {
    /// Replace the whole service configuration (gossip knobs included).
    pub fn config(mut self, cfg: ServiceConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Sketch accuracy α ∈ (0, 1).
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.cfg.alpha = alpha;
        self
    }

    /// Bucket budget m per sketch (≥ 2).
    pub fn max_buckets(mut self, m: usize) -> Self {
        self.cfg.max_buckets = m;
        self
    }

    /// Ingest shards (worker threads, ≥ 1).
    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = shards;
        self
    }

    /// Values per ingest message (writer-side batching, ≥ 1).
    pub fn batch_size(mut self, batch: usize) -> Self {
        self.cfg.batch_size = batch;
        self
    }

    /// Bounded queue depth per shard, in batches (≥ 1).
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.cfg.queue_depth = depth;
        self
    }

    /// Background epoch interval in ms (0 = manual `flush` only).
    pub fn epoch_interval_ms(mut self, ms: u64) -> Self {
        self.cfg.epoch_interval_ms = ms;
        self
    }

    /// Sliding-window ring slots (0 = cumulative all-time serving).
    pub fn window(mut self, slots: usize) -> Self {
        self.cfg.window_slots = slots;
        self
    }

    /// Serve Prometheus text exposition at `GET /metrics` on `addr`
    /// (the `metrics_bind` config key). Port 0 binds an ephemeral port
    /// — read it back via [`Node::metrics_addr`]. Without this knob the
    /// node still registers every metric ([`Node::metrics`]); it just
    /// runs no HTTP listener.
    ///
    /// ```
    /// use duddsketch::prelude::*;
    ///
    /// let node = Node::builder()
    ///     .shards(1)
    ///     .metrics_bind("127.0.0.1:0".parse().unwrap())
    ///     .build()
    ///     .unwrap();
    /// assert_ne!(node.metrics_addr().expect("listener bound").port(), 0);
    /// node.shutdown();
    /// ```
    pub fn metrics_bind(mut self, addr: SocketAddr) -> Self {
        self.cfg.metrics_bind = Some(addr);
        self
    }

    /// Export the node's structured event log to `path` (the
    /// `obs_event_log` config key): one JSON line per gossip round,
    /// per-exchange span, and membership change — the schema in
    /// `docs/OBSERVABILITY.md`. The sink is bounded and non-blocking;
    /// a lagging writer drops events (counted in
    /// `dudd_events_dropped_total`) instead of stalling rounds.
    ///
    /// ```
    /// use duddsketch::prelude::*;
    ///
    /// let dir = std::env::temp_dir();
    /// let path = dir.join(format!("dudd-doc-events-{}.jsonl", std::process::id()));
    /// let node = Node::builder().shards(1).event_log(&path).build().unwrap();
    /// node.shutdown();
    /// assert!(path.exists());
    /// std::fs::remove_file(&path).ok();
    /// ```
    pub fn event_log(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.obs_event_log = Some(path.into());
        self
    }

    /// Replace the whole gossip-loop configuration.
    pub fn gossip(mut self, gossip: GossipLoopConfig) -> Self {
        self.cfg.gossip = gossip;
        self
    }

    /// Background gossip round interval in ms (0 = manual `step` only).
    pub fn gossip_interval_ms(mut self, ms: u64) -> Self {
        self.cfg.gossip.round_interval_ms = ms;
        self
    }

    /// Neighbours contacted per gossip round (≥ 1).
    pub fn fan_out(mut self, fan_out: usize) -> Self {
        self.cfg.gossip.fan_out = fan_out;
        self
    }

    /// Convergence threshold on the probe-quantile drift.
    pub fn convergence_rel(mut self, rel: f64) -> Self {
        self.cfg.gossip.convergence_rel = rel;
        self
    }

    /// Quantiles probed for the drift metric (non-empty, in [0,1]).
    pub fn probe_quantiles(mut self, qs: &[f64]) -> Self {
        self.cfg.gossip.probe_quantiles = qs.to_vec();
        self
    }

    /// Seed for overlay and partner randomness (a remote fleet must
    /// share one seed so every node builds the same overlay).
    pub fn gossip_seed(mut self, seed: u64) -> Self {
        self.cfg.gossip.seed = seed;
        self
    }

    /// Per-exchange transport deadline in ms (≥ 1; §7.2 cancellation).
    pub fn exchange_deadline_ms(mut self, ms: u64) -> Self {
        self.cfg.gossip.exchange_deadline_ms = ms;
        self
    }

    /// Idle TCP connections kept per remote peer (0 disables pooling).
    /// Like the deadline, this knob takes effect through
    /// [`TcpTransportOptions::from_gossip`](super::TcpTransportOptions::from_gossip)
    /// when the node's transport is built from this configuration.
    ///
    /// ```
    /// use duddsketch::prelude::*;
    /// use duddsketch::service::TcpTransportOptions;
    ///
    /// let node = Node::builder().shards(1).pool_connections(4).build().unwrap();
    /// let opts = TcpTransportOptions::from_gossip(&node.service().config().gossip);
    /// assert_eq!(opts.pool_connections, 4);
    /// node.shutdown();
    /// ```
    pub fn pool_connections(mut self, connections: usize) -> Self {
        self.cfg.gossip.pool_connections = connections;
        self
    }

    /// Idle timeout in ms for pooled connections (≥ 1).
    ///
    /// ```
    /// use duddsketch::prelude::*;
    ///
    /// // A zero idle timeout is rejected with the key named.
    /// let err = Node::builder().shards(1).pool_idle_ms(0).build().unwrap_err();
    /// assert!(format!("{err:#}").contains("gossip_pool_idle_ms"));
    /// ```
    pub fn pool_idle_ms(mut self, ms: u64) -> Self {
        self.cfg.gossip.pool_idle_ms = ms;
        self
    }

    /// Enable or disable delta exchange frames (changed buckets against
    /// the pair's last completed exchange instead of full states; see
    /// `docs/PROTOCOL.md`). Default on; full-frame fallback is always
    /// automatic either way.
    ///
    /// ```
    /// use duddsketch::prelude::*;
    /// use duddsketch::service::TcpTransportOptions;
    ///
    /// let node = Node::builder().shards(1).delta_exchanges(false).build().unwrap();
    /// let opts = TcpTransportOptions::from_gossip(&node.service().config().gossip);
    /// assert!(!opts.delta_exchanges);
    /// node.shutdown();
    /// ```
    pub fn delta_exchanges(mut self, enabled: bool) -> Self {
        self.cfg.gossip.delta_exchanges = enabled;
        self
    }

    /// Membership suspicion interval in ms (≥ 1; see
    /// [`GossipLoopConfig::suspect_after_ms`]).
    pub fn suspect_after_ms(mut self, ms: u64) -> Self {
        self.cfg.gossip.suspect_after_ms = ms;
        self
    }

    /// Membership tombstone TTL in ms (≥ 1; see
    /// [`GossipLoopConfig::tombstone_ttl_ms`]).
    pub fn tombstone_ttl_ms(mut self, ms: u64) -> Self {
        self.cfg.gossip.tombstone_ttl_ms = ms;
        self
    }

    /// Found a **new fleet with dynamic membership**: this node becomes
    /// the bootstrap seed (stable member id 0). Requires a bound,
    /// remote-capable transport; joiners point
    /// [`NodeBuilder::join`] at its listen address. Mutually exclusive
    /// with the static `.peer(..)`/`.remote_peer(..)` member list.
    pub fn membership_bootstrap(mut self) -> Self {
        self.bootstrap = true;
        self
    }

    /// Join a **running fleet** via `seed` (any existing member): the
    /// `dudd-join` handshake assigns this node a stable member id and
    /// hands it the current member table; partners are drawn from the
    /// live view from then on. May be called repeatedly — seeds are
    /// tried in order until one answers. Mutually exclusive with the
    /// static member list.
    pub fn join(mut self, seed: SocketAddr) -> Self {
        self.cfg.gossip.seed_peers.push(seed);
        self
    }

    /// Add a fleet member (in global member order, this node excluded —
    /// see [`NodeBuilder::self_index`]).
    pub fn peer(mut self, member: GossipMember) -> Self {
        self.peers.push(member);
        self
    }

    /// Add a remote node at `addr` as a fleet member.
    pub fn remote_peer(mut self, addr: SocketAddr) -> Self {
        self.peers.push(GossipMember::Remote(addr));
        self
    }

    /// Where this node's own service sits in the global member order
    /// (= its peer id; default 0, Algorithm 3's distinguished peer).
    pub fn self_index(mut self, index: usize) -> Self {
        self.self_index = index;
        self
    }

    /// The transport carrying this node's exchanges (default:
    /// [`InProcessTransport`]). Pass a bound
    /// [`TcpTransport`](super::TcpTransport) to serve remote peers.
    pub fn transport(mut self, transport: impl Transport) -> Self {
        self.transport = Some(Arc::new(transport));
        self
    }

    /// [`NodeBuilder::transport`] for an already-shared transport.
    pub fn transport_shared(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Validate the full configuration (named-key errors), start the
    /// service, and — when peers are configured — the gossip loop with
    /// this node's service inserted at [`NodeBuilder::self_index`].
    pub fn build(self) -> Result<Node> {
        let NodeBuilder {
            cfg,
            peers,
            self_index,
            transport,
            bootstrap,
        } = self;
        cfg.validate()
            .map_err(anyhow::Error::msg)
            .context("node configuration")?;
        // One registry for the whole node: every layer's handles attach
        // here, so a single scrape sees ingest, gossip, transport and
        // membership together. The listener binds before any threads
        // spawn — an unusable metrics_bind fails construction cleanly.
        let registry = Arc::new(MetricsRegistry::new());
        let obs = NodeMetrics::register(&registry).context("registering node metrics")?;
        // The event sink installs before any layer spawns, so the very
        // first round (and serve) can log. The node label is the serve
        // address when the transport has one — the cross-node joinable
        // identity — and the member index otherwise.
        if let Some(path) = &cfg.obs_event_log {
            let label = transport
                .as_ref()
                .and_then(|t| t.listen_addr())
                .map(|a| a.to_string())
                .unwrap_or_else(|| format!("member:{self_index}"));
            let sink = EventSink::create(path, &label, obs.gossip.events_dropped.clone())
                .with_context(|| format!("creating event log {}", path.display()))?;
            obs.export.install(Arc::new(sink));
        }
        if bootstrap || !cfg.gossip.seed_peers.is_empty() {
            // The /metrics listener binds inside the membership path,
            // after the member table exists, so GET /members can serve
            // the gossiped view.
            return Self::build_membership(
                cfg,
                peers,
                self_index,
                transport,
                bootstrap,
                obs,
                registry,
            );
        }
        let metrics_server = match cfg.metrics_bind {
            Some(addr) => Some(MetricsServer::bind(addr, Arc::clone(&registry))?),
            None => None,
        };
        if self_index > peers.len() {
            bail!(
                "self_index {} is out of range for a fleet of {} members",
                self_index,
                peers.len() + 1
            );
        }
        let service = Arc::new(QuantileService::start_instrumented(
            cfg.clone(),
            Some(obs.service.clone()),
        )?);
        if peers.is_empty() {
            if transport.is_some() {
                bail!(
                    "a transport was configured but no gossip peers were added — \
                     add .peer(..) / .remote_peer(..) entries"
                );
            }
            return Ok(Node {
                service,
                gossip: None,
                self_member: 0,
                obs,
                metrics_server,
            });
        }
        let mut members = peers;
        members.insert(self_index, GossipMember::service(service.clone()));
        let transport: Arc<dyn Transport> =
            transport.unwrap_or_else(|| Arc::new(InProcessTransport));
        let gossip =
            GossipLoop::start_with_obs(cfg.gossip.clone(), members, transport, obs.clone())
                .context("starting node gossip loop")?;
        Ok(Node {
            service,
            gossip: Some(gossip),
            self_member: self_index,
            obs,
            metrics_server,
        })
    }

    /// The dynamic-membership construction path
    /// ([`NodeBuilder::membership_bootstrap`] / [`NodeBuilder::join`]):
    /// bootstrap or join first (so a refused handshake fails before any
    /// service threads spawn), then start the loop over the live view.
    /// The `/metrics` listener binds here — after the member table
    /// exists — so `GET /members` serves the gossiped view.
    fn build_membership(
        cfg: ServiceConfig,
        peers: Vec<GossipMember>,
        self_index: usize,
        transport: Option<Arc<dyn Transport>>,
        bootstrap: bool,
        obs: NodeMetrics,
        registry: Arc<MetricsRegistry>,
    ) -> Result<Node> {
        if !peers.is_empty() {
            bail!(
                "dynamic membership and a static member list are mutually \
                 exclusive — drop the .peer(..)/.remote_peer(..) entries \
                 (the live view replaces the global member order)"
            );
        }
        if self_index != 0 {
            bail!(
                "self_index is meaningless with dynamic membership (ids are \
                 assigned by the join handshake) — remove .self_index({self_index})"
            );
        }
        if bootstrap && !cfg.gossip.seed_peers.is_empty() {
            bail!(
                "choose one: .membership_bootstrap() founds a new fleet, \
                 .join(seed) enters an existing one"
            );
        }
        let transport = transport.context(
            "dynamic membership needs a bound remote transport — pass \
             .transport(TcpTransport::bind(..)?)",
        )?;
        let listen = transport.listen_addr().context(
            "dynamic membership needs a *serving* transport (partners must \
             reach this node) — bind it, connect-only is not enough",
        )?;
        let mcfg = MembershipConfig::from_gossip(&cfg.gossip);
        let (membership, generation) = if bootstrap {
            (Membership::bootstrap(listen, mcfg), 1)
        } else {
            let mut last_err: Option<anyhow::Error> = None;
            let mut joined = None;
            for &seed in &cfg.gossip.seed_peers {
                match transport.join_remote(seed) {
                    Ok((table, seed_gen)) => {
                        joined =
                            Some((Membership::from_join(table, listen, mcfg.clone())?, seed_gen));
                        break;
                    }
                    Err(e) => {
                        last_err = Some(anyhow::Error::new(e).context(format!("seed {seed}")))
                    }
                }
            }
            match joined {
                Some(m) => m,
                None => {
                    return Err(last_err
                        .expect("seed_peers is non-empty")
                        .context("no seed answered the dudd-join handshake"))
                }
            }
        };
        let membership = Arc::new(membership);
        let metrics_server = match cfg.metrics_bind {
            Some(addr) => {
                let table_source = Arc::clone(&membership);
                let source: MembersSource =
                    Arc::new(move || render_members_jsonl(&table_source.table()));
                Some(MetricsServer::bind_with_members(
                    addr,
                    Arc::clone(&registry),
                    Some(source),
                )?)
            }
            None => None,
        };
        let service = Arc::new(QuantileService::start_instrumented(
            cfg.clone(),
            Some(obs.service.clone()),
        )?);
        let gossip = GossipLoop::start_membership_obs(
            cfg.gossip.clone(),
            GossipMember::Service(service.clone()),
            transport,
            membership,
            generation,
            obs.clone(),
        )
        .context("starting membership gossip loop")?;
        Ok(Node {
            service,
            gossip: Some(gossip),
            self_member: 0,
            obs,
            metrics_server,
        })
    }
}

/// Render a member table as the `GET /members` NDJSON body: one flat
/// JSON object per entry (tombstones included — a dead member is fleet
/// state worth seeing). `SocketAddr` display and the status names need
/// no JSON escaping.
fn render_members_jsonl(table: &MemberTable) -> String {
    let mut out = String::new();
    for e in table.iter() {
        let status = match e.status {
            MemberStatus::Alive => "alive",
            MemberStatus::Suspect => "suspect",
            MemberStatus::Dead => "dead",
        };
        out.push_str(&format!(
            "{{\"id\":{},\"addr\":\"{}\",\"incarnation\":{},\"status\":\"{}\"}}\n",
            e.id, e.addr, e.incarnation, status
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_with_named_keys() {
        let err = Node::builder().shards(0).build().unwrap_err();
        assert!(format!("{err:#}").contains("shards"), "{err:#}");
        let err = Node::builder().alpha(f64::NAN).build().unwrap_err();
        assert!(format!("{err:#}").contains("alpha"), "{err:#}");
        let err = Node::builder().fan_out(0).build().unwrap_err();
        assert!(format!("{err:#}").contains("gossip_fan_out"), "{err:#}");
        let err = Node::builder().exchange_deadline_ms(0).build().unwrap_err();
        assert!(
            format!("{err:#}").contains("gossip_exchange_deadline_ms"),
            "{err:#}"
        );
        let err = Node::builder().pool_idle_ms(0).build().unwrap_err();
        assert!(
            format!("{err:#}").contains("gossip_pool_idle_ms"),
            "{err:#}"
        );
    }

    #[test]
    fn builder_transport_knobs_reach_the_config() {
        let node = Node::builder()
            .shards(1)
            .pool_connections(7)
            .pool_idle_ms(123)
            .delta_exchanges(false)
            .build()
            .unwrap();
        let g = &node.service().config().gossip;
        assert_eq!(g.pool_connections, 7);
        assert_eq!(g.pool_idle_ms, 123);
        assert!(!g.delta_exchanges);
        node.shutdown();
    }

    /// The builder's registry spans every layer: ingest counters tick
    /// on the node's own writers and the bound `/metrics` listener
    /// serves them.
    #[test]
    fn metrics_bind_serves_the_node_registry() {
        let node = Node::builder()
            .shards(1)
            .metrics_bind("127.0.0.1:0".parse().unwrap())
            .build()
            .unwrap();
        let addr = node.metrics_addr().expect("listener bound");
        let mut w = node.writer();
        w.insert_batch(&[1.0, 2.0, 3.0]);
        w.flush();
        node.flush();
        assert_eq!(node.metrics().service.values.get(), 3);

        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.contains("dudd_ingest_values_total 3"), "{out}");
        assert!(out.contains("dudd_epochs_total 1"), "{out}");

        drop(w);
        node.shutdown();
    }

    /// The `event_log` knob wires an [`EventSink`] through the whole
    /// stack: stepped rounds land as parseable JSONL lines labeled with
    /// this node's member identity, without dropping anything.
    #[test]
    fn event_log_knob_exports_rounds_as_jsonl() {
        let dir = std::env::temp_dir().join(format!("dudd-builder-events-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("node.jsonl");
        let data: Vec<f64> = (1..=600).map(f64::from).collect();
        let node = Node::builder()
            .shards(1)
            .peer(GossipMember::from_dataset(&data, 0.001, 1024).unwrap())
            .event_log(&path)
            .build()
            .unwrap();
        assert_eq!(
            node.service().config().obs_event_log.as_deref(),
            Some(path.as_path())
        );
        let mut exchanges = 0;
        for _ in 0..3 {
            let r = node.step().unwrap();
            exchanges += r.exchanges + r.failed;
        }
        // 3 round lines + one exchange line per attempt; the writer
        // thread flushes per burst, so poll briefly.
        let want = 3 + exchanges;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let text = loop {
            let text = std::fs::read_to_string(&path).unwrap_or_default();
            if text.lines().count() >= want || std::time::Instant::now() > deadline {
                break text;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        };
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= want, "want >= {want} lines, got {}", lines.len());
        let mut rounds = 0;
        for line in &lines {
            let obj = crate::obs::parse_flat_json(line).unwrap_or_else(|| panic!("{line}"));
            assert_eq!(obj["node"].as_str(), Some("member:0"), "{line}");
            if obj["event"].as_str() == Some("round") {
                rounds += 1;
            }
        }
        assert_eq!(rounds, 3);
        assert_eq!(node.metrics().gossip.events_dropped.get(), 0);
        node.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A dynamic-membership node serves its gossiped member table at
    /// `GET /members`, next to `/metrics`.
    #[test]
    fn members_endpoint_serves_the_gossiped_table() {
        use super::super::transport::TcpTransport;
        let transport = TcpTransport::bind(
            "127.0.0.1:0",
            std::time::Duration::from_millis(500),
        )
        .unwrap();
        let node = Node::builder()
            .shards(1)
            .transport(transport)
            .membership_bootstrap()
            .metrics_bind("127.0.0.1:0".parse().unwrap())
            .build()
            .unwrap();
        let listen = node.listen_addr().expect("serving transport");
        let addr = node.metrics_addr().expect("listener bound");

        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(s, "GET /members HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");
        let body = out.split_once("\r\n\r\n").unwrap().1;
        let members = crate::obs::observe::parse_members(body);
        assert_eq!(members.len(), 1, "bootstrap node alone: {body}");
        assert_eq!(members[0].id, 0);
        assert_eq!(members[0].addr, listen.to_string());
        assert_eq!(members[0].status, "alive");
        node.shutdown();
    }

    #[test]
    fn builder_without_peers_serves_locally() {
        let node = Node::builder().shards(2).batch_size(64).build().unwrap();
        assert!(node.gossip().is_none());
        assert!(node.step().is_none());
        assert!(node.global_view().is_none());
        assert!(node.listen_addr().is_none());
        let mut w = node.writer();
        for i in 1..=100 {
            w.insert(i as f64);
        }
        w.flush();
        let snap = node.flush();
        assert_eq!(snap.count(), 100.0);
        drop(w);
        let fin = node.shutdown();
        assert_eq!(fin.count(), 100.0);
    }

    #[test]
    fn builder_rejects_transport_without_peers() {
        let err = Node::builder()
            .shards(1)
            .transport(InProcessTransport)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("no gossip peers"), "{err:#}");
    }

    #[test]
    fn builder_rejects_out_of_range_self_index() {
        let data = [1.0, 2.0];
        let err = Node::builder()
            .shards(1)
            .peer(GossipMember::from_dataset(&data, 0.001, 1024).unwrap())
            .self_index(5)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("self_index"), "{err:#}");
    }

    #[test]
    fn builder_places_self_at_index() {
        let a: Vec<f64> = (1..=300).map(f64::from).collect();
        let b: Vec<f64> = (301..=600).map(f64::from).collect();
        let node = Node::builder()
            .shards(2)
            .peer(GossipMember::from_dataset(&a, 0.001, 1024).unwrap())
            .peer(GossipMember::from_dataset(&b, 0.001, 1024).unwrap())
            .self_index(1)
            .build()
            .unwrap();
        assert_eq!(node.self_member(), 1);
        let mut w = node.writer();
        w.insert_batch(&(601..=900).map(f64::from).collect::<Vec<_>>());
        w.flush();
        node.flush();
        // Let the loop pick up the fresh epoch and converge.
        let mut converged = 0;
        for _ in 0..300 {
            let r = node.step().unwrap();
            converged = if r.converged { converged + 1 } else { 0 };
            if converged >= 3 {
                break;
            }
        }
        let v = node.global_view().unwrap();
        assert_eq!(v.estimated_peers(), 3.0);
        assert_eq!(v.estimated_total(), 900.0);
        drop(w);
        node.shutdown();
    }
}
