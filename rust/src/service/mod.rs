//! Sharded concurrent ingest + windowed snapshot-query service.
//!
//! The production ingest path in front of a gossip peer: where the rest
//! of the crate *simulates* the paper's protocol, this module *serves* a
//! live stream at hardware speed.
//!
//! ```text
//!  writers (any #)          shards (N threads)         coordinator
//!  ┌──────────────┐  mpsc   ┌──────────────────┐  drain ┌─────────────┐
//!  │ batch buffer ├────────▶│ UddSketch<Dense> ├───────▶│ merge epoch │
//!  │ round-robin  │ bounded │  (private, no    │ deltas │ fold + ring │
//!  └──────────────┘ queues  │   locks at all)  │        └──────┬──────┘
//!                           └──────────────────┘               │ publish
//!                                              ArcSwapCell<Snapshot>
//!                                             (lock-free query reads)
//! ```
//!
//! * **Sharded ingest** — [`QuantileService::writer`] hands out batching
//!   [`ServiceWriter`]s; values ship round-robin over bounded mpsc
//!   queues to N worker threads, each folding into a private
//!   [`UddSketch`](crate::sketch::UddSketch). No shared state on the hot
//!   path, so throughput scales with shard count
//!   (`benches/service_ingest.rs`).
//! * **Exact epochs** — the coordinator periodically (or on
//!   [`QuantileService::flush`]) drains every shard's *delta* sketch and
//!   folds them with [`merge_weighted`](crate::sketch::UddSketch::merge_weighted)
//!   semantics (collapse lineages align automatically). Mergeability
//!   (Definition 7) makes the fold exact: a snapshot answers quantiles
//!   **identically** to one sequential sketch fed the same stream, with
//!   the same α guarantee (`rust/tests/integration_service.rs`).
//! * **Non-blocking queries** — snapshots publish through an
//!   [`ArcSwapCell`]; readers never take a lock and never block ingest.
//! * **Sliding windows** — with `window_slots > 0` a [`WindowRing`] keeps
//!   one sub-sketch per epoch interval and merges the most recent `k` on
//!   demand (time-bucketed-aggregate style), for "last N intervals"
//!   serving instead of all-time.
//! * **Gossip fronting** — [`ServicePeer`] /
//!   [`QuantileService::peer_state`] turn the live snapshot into the
//!   local state of Algorithm 3, connecting the service to the
//!   distributed protocol in [`crate::gossip`].
//! * **Continuous gossip loop** — [`GossipLoop`] runs the paper's
//!   refresh → exchange → serve cycle as a background task over a fleet
//!   of services, simulated peers, and remote nodes, publishing a
//!   network-converged [`GlobalView`] (union-stream quantiles,
//!   Algorithm 6) through a second [`ArcSwapCell`] next to the local
//!   snapshot.
//! * **Transport layer** — every partner interaction goes through the
//!   [`Transport`] trait ([`transport`] module): [`InProcessTransport`]
//!   reproduces the in-process fleet bit for bit, [`TcpTransport`] ships
//!   length-prefixed codec frames over `std::net` with per-exchange
//!   deadlines and §7.2 cancelled-exchange semantics, so real nodes can
//!   join across machines. The hot path reuses pooled connections, is
//!   served by a single poll-driven loop per node, and ships **delta
//!   frames** (changed buckets only) once a pair has exchanged before —
//!   see `docs/PROTOCOL.md` for the wire spec.
//! * **Membership plane** — [`membership`] makes the member set itself
//!   gossiped state: a versioned member table (id, addr, incarnation,
//!   status) rides the exchange connections by anti-entropy, nodes
//!   enter a *running* fleet through the `dudd-join` handshake
//!   ([`NodeBuilder::join`]), crashes are suspected from failed
//!   exchanges and declared dead (with exponential backoff and
//!   tombstone GC), and every change of the live view restarts the
//!   protocol so the union estimate re-anchors on the survivors — no
//!   static address book, no manual restarts (`docs/PROTOCOL.md` §9).
//! * **Fluent construction** — [`Node::builder()`] is the primary way to
//!   stand a node up: service + gossip + transport in one validated
//!   expression (named-key errors at build time).
//!
//! Configuration lives in [`crate::config::ServiceConfig`] (gossip knobs
//! in [`crate::config::GossipLoopConfig`]); the `serve-bench`,
//! `serve-gossip`, and `serve-remote` CLI subcommands drive the `data`
//! workloads through a service (or a loopback-TCP fleet) end to end.

mod builder;
pub mod clock;
mod coordinator;
mod gossip_loop;
pub mod membership;
mod peer;
mod shard;
mod snapshot;
mod swap;
pub mod transport;
mod window;

pub use builder::{Node, NodeBuilder};
pub use clock::{Clock, SystemClock, VirtualClock};
pub use coordinator::{QuantileService, ServiceWriter};
pub use gossip_loop::{
    GlobalView, GossipLoop, GossipMember, GossipRoundReport, MembershipRoundStats, NodeHandle,
    RestartCause, ServeReject,
};
pub use membership::{
    MemberEntry, MemberStatus, MemberTable, Membership, MembershipConfig,
};
pub use peer::ServicePeer;
pub use shard::ShardDelta;
pub use snapshot::Snapshot;
pub use swap::ArcSwapCell;
pub use transport::{
    ExchangeOutcome, InProcessTransport, PoolStats, RemoteChannel, TcpTransport,
    TcpTransportOptions, Transport, TransportError,
};
pub use window::WindowRing;
