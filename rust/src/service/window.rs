//! Sliding-window mode: a ring of per-epoch sub-sketches.
//!
//! Inspired by time-bucketed aggregates (timescaledb-toolkit style): each
//! coordinator epoch produces one immutable sub-sketch; the ring keeps the
//! most recent `k` of them and merges on demand, so a windowed snapshot
//! summarizes exactly the last `k` epoch intervals. Eviction is O(1)
//! (slot overwrite) and the merge cost is bounded by `k · m` buckets.

use crate::sketch::{DenseStore, SketchError, UddSketch};

/// Ring of per-epoch sub-sketches; epoch `e` (0-based) lands in slot
/// `e % k`.
#[derive(Debug, Clone)]
pub struct WindowRing {
    alpha: f64,
    max_buckets: usize,
    slots: Vec<UddSketch<DenseStore>>,
    /// Epochs absorbed so far.
    epochs: u64,
}

impl WindowRing {
    /// A ring of `slots` intervals with the service's sketch parameters.
    pub fn new(slots: usize, alpha: f64, max_buckets: usize) -> Result<Self, SketchError> {
        assert!(slots > 0, "window ring needs at least one slot");
        let mut v = Vec::with_capacity(slots);
        for _ in 0..slots {
            v.push(UddSketch::new(alpha, max_buckets)?);
        }
        Ok(Self {
            alpha,
            max_buckets,
            slots: v,
            epochs: 0,
        })
    }

    /// Ring capacity in epochs.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Epochs absorbed so far.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Slots currently holding live epochs (`min(epochs, k)`).
    pub fn live(&self) -> usize {
        self.slots.len().min(self.epochs as usize)
    }

    /// Inclusive range of (1-based) epochs the ring covers, or `None`
    /// before the first epoch.
    pub fn coverage(&self) -> Option<(u64, u64)> {
        if self.epochs == 0 {
            None
        } else {
            let hi = self.epochs;
            let lo = hi - (self.live() as u64 - 1);
            Some((lo, hi))
        }
    }

    /// Record one epoch's merged delta, evicting whatever the target slot
    /// held `k` epochs ago.
    pub fn push_epoch(&mut self, delta: UddSketch<DenseStore>) {
        let k = (self.epochs as usize) % self.slots.len();
        self.slots[k] = delta;
        self.epochs += 1;
    }

    /// Merge the live slots into one window aggregate (on demand; the
    /// slots themselves stay untouched).
    pub fn merged(&self) -> Result<UddSketch<DenseStore>, SketchError> {
        let mut out = UddSketch::new(self.alpha, self.max_buckets)?;
        for s in self.slots.iter().take(self.live()) {
            out.merge(s)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(values: &[f64]) -> UddSketch<DenseStore> {
        let mut s = UddSketch::new(0.01, 256).unwrap();
        s.extend(values);
        s
    }

    #[test]
    fn ring_covers_last_k_epochs() {
        let mut ring = WindowRing::new(3, 0.01, 256).unwrap();
        assert_eq!(ring.coverage(), None);
        assert!(ring.merged().unwrap().is_empty());

        for e in 1..=5u64 {
            ring.push_epoch(delta(&[e as f64; 10]));
        }
        assert_eq!(ring.epochs(), 5);
        assert_eq!(ring.live(), 3);
        assert_eq!(ring.coverage(), Some((3, 5)));

        // Window holds epochs 3..=5: 30 items, values {3,4,5}.
        let w = ring.merged().unwrap();
        assert_eq!(w.count(), 30.0);
        let lo = w.quantile(0.0).unwrap();
        assert!((lo - 3.0).abs() <= 0.01 * 3.0 + 1e-9, "oldest live epoch evicted wrongly: {lo}");
        let hi = w.quantile(1.0).unwrap();
        assert!((hi - 5.0).abs() <= 0.01 * 5.0 + 1e-9);
    }

    #[test]
    fn partial_ring_merges_only_live_slots() {
        let mut ring = WindowRing::new(4, 0.01, 256).unwrap();
        ring.push_epoch(delta(&[1.0, 2.0]));
        ring.push_epoch(delta(&[3.0]));
        assert_eq!(ring.live(), 2);
        assert_eq!(ring.coverage(), Some((1, 2)));
        assert_eq!(ring.merged().unwrap().count(), 3.0);
    }

    #[test]
    fn merged_equals_sequential_over_window() {
        let mut ring = WindowRing::new(2, 0.001, 512).unwrap();
        let a: Vec<f64> = (1..=500).map(|i| i as f64).collect();
        let b: Vec<f64> = (501..=900).map(|i| i as f64).collect();
        let c: Vec<f64> = (901..=1000).map(|i| i as f64).collect();
        ring.push_epoch(delta_with(&a));
        ring.push_epoch(delta_with(&b));
        ring.push_epoch(delta_with(&c));

        let mut seq: UddSketch<DenseStore> = UddSketch::new(0.001, 512).unwrap();
        seq.extend(&b);
        seq.extend(&c);

        let w = ring.merged().unwrap();
        assert_eq!(w.count(), seq.count());
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(w.quantile(q).unwrap(), seq.quantile(q).unwrap(), "q={q}");
        }
    }

    fn delta_with(values: &[f64]) -> UddSketch<DenseStore> {
        let mut s = UddSketch::new(0.001, 512).unwrap();
        s.extend(values);
        s
    }
}
