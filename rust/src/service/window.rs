//! Sliding-window mode: a ring of per-epoch sub-sketches.
//!
//! Inspired by time-bucketed aggregates (timescaledb-toolkit style): each
//! coordinator epoch produces one immutable sub-sketch; the ring keeps the
//! most recent `k` of them and merges on demand, so a windowed snapshot
//! summarizes exactly the last `k` epoch intervals. Eviction is O(1)
//! (slot overwrite) and the merge cost is bounded by `k · m` buckets.

#![forbid(unsafe_code)]

use crate::sketch::{DenseStore, SketchError, UddSketch};

/// Ring of per-epoch sub-sketches; epoch `e` (0-based) lands in slot
/// `e % k`.
///
/// ```
/// use duddsketch::service::WindowRing;
/// use duddsketch::sketch::UddSketch;
///
/// let mut ring = WindowRing::new(2, 0.01, 256).unwrap();
/// for v in [10.0, 20.0, 30.0] {
///     let mut epoch = UddSketch::new(0.01, 256).unwrap();
///     epoch.insert(v);
///     ring.push_epoch(epoch);
/// }
/// // Only the last 2 epochs are live; epoch 1 (value 10) was evicted.
/// assert_eq!(ring.coverage(), Some((2, 3)));
/// assert_eq!(ring.merged().unwrap().count(), 2.0);
/// ```
#[derive(Debug, Clone)]
pub struct WindowRing {
    alpha: f64,
    max_buckets: usize,
    slots: Vec<UddSketch<DenseStore>>,
    /// Epochs absorbed so far.
    epochs: u64,
}

impl WindowRing {
    /// A ring of `slots` intervals with the service's sketch parameters.
    pub fn new(slots: usize, alpha: f64, max_buckets: usize) -> Result<Self, SketchError> {
        assert!(slots > 0, "window ring needs at least one slot");
        let mut v = Vec::with_capacity(slots);
        for _ in 0..slots {
            v.push(UddSketch::new(alpha, max_buckets)?);
        }
        Ok(Self {
            alpha,
            max_buckets,
            slots: v,
            epochs: 0,
        })
    }

    /// Ring capacity in epochs.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Epochs absorbed so far.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Slots currently holding live epochs (`min(epochs, k)`).
    pub fn live(&self) -> usize {
        self.slots.len().min(self.epochs as usize)
    }

    /// Inclusive range of (1-based) epochs the ring covers, or `None`
    /// before the first epoch.
    pub fn coverage(&self) -> Option<(u64, u64)> {
        if self.epochs == 0 {
            None
        } else {
            let hi = self.epochs;
            let lo = hi - (self.live() as u64 - 1);
            Some((lo, hi))
        }
    }

    /// Record one epoch's merged delta, evicting whatever the target slot
    /// held `k` epochs ago.
    pub fn push_epoch(&mut self, delta: UddSketch<DenseStore>) {
        let k = (self.epochs as usize) % self.slots.len();
        self.slots[k] = delta;
        self.epochs += 1;
    }

    /// Merge the live slots into one window aggregate (on demand; the
    /// slots themselves stay untouched).
    pub fn merged(&self) -> Result<UddSketch<DenseStore>, SketchError> {
        let mut out = UddSketch::new(self.alpha, self.max_buckets)?;
        for s in self.slots.iter().take(self.live()) {
            out.merge(s)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(values: &[f64]) -> UddSketch<DenseStore> {
        let mut s = UddSketch::new(0.01, 256).unwrap();
        s.extend(values);
        s
    }

    #[test]
    fn ring_covers_last_k_epochs() {
        let mut ring = WindowRing::new(3, 0.01, 256).unwrap();
        assert_eq!(ring.coverage(), None);
        assert!(ring.merged().unwrap().is_empty());

        for e in 1..=5u64 {
            ring.push_epoch(delta(&[e as f64; 10]));
        }
        assert_eq!(ring.epochs(), 5);
        assert_eq!(ring.live(), 3);
        assert_eq!(ring.coverage(), Some((3, 5)));

        // Window holds epochs 3..=5: 30 items, values {3,4,5}.
        let w = ring.merged().unwrap();
        assert_eq!(w.count(), 30.0);
        let lo = w.quantile(0.0).unwrap();
        assert!((lo - 3.0).abs() <= 0.01 * 3.0 + 1e-9, "oldest live epoch evicted wrongly: {lo}");
        let hi = w.quantile(1.0).unwrap();
        assert!((hi - 5.0).abs() <= 0.01 * 5.0 + 1e-9);
    }

    #[test]
    fn partial_ring_merges_only_live_slots() {
        let mut ring = WindowRing::new(4, 0.01, 256).unwrap();
        ring.push_epoch(delta(&[1.0, 2.0]));
        ring.push_epoch(delta(&[3.0]));
        assert_eq!(ring.live(), 2);
        assert_eq!(ring.coverage(), Some((1, 2)));
        assert_eq!(ring.merged().unwrap().count(), 3.0);
    }

    #[test]
    fn merged_equals_sequential_over_window() {
        let mut ring = WindowRing::new(2, 0.001, 512).unwrap();
        let a: Vec<f64> = (1..=500).map(|i| i as f64).collect();
        let b: Vec<f64> = (501..=900).map(|i| i as f64).collect();
        let c: Vec<f64> = (901..=1000).map(|i| i as f64).collect();
        ring.push_epoch(delta_with(&a));
        ring.push_epoch(delta_with(&b));
        ring.push_epoch(delta_with(&c));

        let mut seq: UddSketch<DenseStore> = UddSketch::new(0.001, 512).unwrap();
        seq.extend(&b);
        seq.extend(&c);

        let w = ring.merged().unwrap();
        assert_eq!(w.count(), seq.count());
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(w.quantile(q).unwrap(), seq.quantile(q).unwrap(), "q={q}");
        }
    }

    fn delta_with(values: &[f64]) -> UddSketch<DenseStore> {
        let mut s = UddSketch::new(0.001, 512).unwrap();
        s.extend(values);
        s
    }

    #[test]
    fn many_wraps_keep_exactly_last_k() {
        // The ring wraps many times over (25 epochs through 3 slots);
        // coverage and contents must always be exactly the last k epochs,
        // with no stale slot ever leaking through a wrap boundary.
        let mut ring = WindowRing::new(3, 0.01, 256).unwrap();
        for e in 1..=25u64 {
            ring.push_epoch(delta(&[e as f64; 4]));
            assert_eq!(ring.epochs(), e);
            let live = 3.min(e as usize);
            assert_eq!(ring.live(), live);
            let lo_epoch = e - (live as u64 - 1);
            assert_eq!(ring.coverage(), Some((lo_epoch, e)));
            let w = ring.merged().unwrap();
            assert_eq!(w.count(), (4 * live) as f64);
            let lo = w.quantile(0.0).unwrap();
            let hi = w.quantile(1.0).unwrap();
            let lo_expect = lo_epoch as f64;
            let hi_expect = e as f64;
            assert!(
                (lo - lo_expect).abs() <= 0.01 * lo_expect + 1e-9,
                "epoch {e}: stale value leaked, min {lo} vs {lo_expect}"
            );
            assert!(
                (hi - hi_expect).abs() <= 0.01 * hi_expect + 1e-9,
                "epoch {e}: max {hi} vs {hi_expect}"
            );
        }
    }

    #[test]
    fn empty_epochs_age_out_data() {
        // Idle intervals are real epochs: after k empty pushes the window
        // must be empty again (the service's windowed mode publishes on
        // idle ticks for exactly this reason).
        let mut ring = WindowRing::new(2, 0.01, 256).unwrap();
        ring.push_epoch(delta(&[5.0; 6]));
        assert_eq!(ring.merged().unwrap().count(), 6.0);
        ring.push_epoch(UddSketch::new(0.01, 256).unwrap());
        assert_eq!(ring.merged().unwrap().count(), 6.0, "still in window");
        ring.push_epoch(UddSketch::new(0.01, 256).unwrap());
        let w = ring.merged().unwrap();
        assert!(w.is_empty(), "data older than k epochs survived");
        assert!(w.quantile(0.5).is_err(), "empty window must refuse queries");
        assert_eq!(ring.coverage(), Some((2, 3)));
    }
}
