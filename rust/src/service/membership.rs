//! Gossip-based membership: a versioned member table that rides the
//! `UDDX` exchange traffic, so nodes join and leave a *running* fleet.
//!
//! Before this module a fleet was a static address book: every node
//! listed every other in one global member order, and a single join or
//! crash meant restarting the whole deployment. The paper's protocol,
//! though, is defined over an unstructured P2P overlay whose defining
//! property is churn (§7.2) — P2PTFHH (Pulimeno et al., *Distributed
//! mining of time-faded heavy hitters*) shows the same gossip machinery
//! can carry the membership view itself, and Haeupler et al. (*Optimal
//! Gossip Algorithms for Quantile Computations*) ground why averaging
//! convergence survives a changing peer set.
//!
//! # The member table
//!
//! Every node maintains a [`MemberTable`]: one [`MemberEntry`] per known
//! member — `(id, addr, incarnation, status ∈ {alive, suspect, dead})`.
//! Tables spread by **anti-entropy**: after a data exchange the
//! initiator also pushes its table (`MembershipPush` frame) and merges
//! the partner's reply (`MembershipReply`), so any table change reaches
//! every node in O(log p) rounds. The merge
//! ([`MemberTable::merge`]) is deterministic and commutative in the
//! limit, so all nodes converge to byte-identical tables:
//!
//! * a **higher incarnation** wins outright (the member itself is the
//!   only writer that bumps its incarnation — that is how it refutes a
//!   false suspicion);
//! * at **equal incarnation** the worse status wins
//!   (dead > suspect > alive) — an observation of failure can only be
//!   overridden by the member's own refutation (next incarnation);
//! * ties beyond that (same id, incarnation, status, different addr —
//!   only possible after an id collision) break on the lexicographically
//!   smaller address, purely so the order of merges cannot matter.
//!
//! # Join handshake (`dudd-join`)
//!
//! A joining node contacts **any** seed with a `JoinRequest` frame
//! carrying its listen address; the seed assigns it a stable id (one
//! above the highest id it has ever seen, so a garbage-collected
//! tombstone's id is never re-minted — or the *same* id at the next
//! incarnation when the address is rejoining after a crash) and answers
//! with the full table. The joiner adopts the table, finds its own entry by address,
//! and starts gossiping; the new entry spreads by anti-entropy and every
//! node's next refresh restarts the protocol (see below).
//!
//! # Suspicion, refutation, death, tombstones
//!
//! Failed exchanges — the observations [`TcpTransport`] already
//! surfaces (`TransportError::Io`/`StaleChannel`) — drive suspicion
//! locally: a member whose failure streak outlives
//! `gossip_suspect_after_ms` turns **suspect**, and after another such
//! interval **dead**. Any reply at all (including `Busy` and
//! `StaleGeneration` rejects) is liveness evidence and clears the
//! streak. A member that learns it is suspected refutes by bumping its
//! own incarnation (alive again, one table change that spreads). Dead
//! entries are **tombstones**: they keep spreading (so a node that
//! missed the death cannot resurrect the member) until
//! `gossip_tombstone_ttl_ms` after the local node observed the death,
//! then they are garbage-collected.
//!
//! Suspect and dead members also stop burning the exchange deadline:
//! connect attempts to a **suspect** member back off exponentially
//! (restarting at the base on the suspect transition, then doubling per
//! consecutive failure, capped), and **dead** members are never
//! selected at all. The status transitions themselves are wall-clock
//! driven — a per-round [`Membership::tick`] sweep — so a suspect whose
//! probes are backoff-gated (or who is never drawn as a partner) still
//! turns dead exactly one suspicion interval after turning suspect.
//!
//! # Mass accounting under churn
//!
//! The protocol's `q̃` mass must sum to exactly 1 per restart generation
//! for the fleet-size estimate `p̃ = 1/q̃` to be unbiased. Membership
//! makes the distinguished peer (Algorithm 3's `q̃ = 1`) **dynamic**:
//! the member with the *lowest non-dead id* is distinguished.
//!
//! Under the default **restart-free** rules (`gossip_restart_free`,
//! `docs/PROTOCOL.md` §10), only a **dead ↔ non-dead flip** of some
//! member re-anchors the generation ([`MergeOutcome::reanchor`]): a
//! death removes that member's share of the averaged mass, and a
//! tombstone resurrection would double-count the rejoiner's, so both
//! reseed from the local summary and bump. A plain **join is not a
//! restart**: the joiner enters the *current* generation with `q̃ = 0`
//! (and, as the fleet's sole member, `q̃ = 1` only when it bootstraps),
//! which leaves the generation's total `q̃` mass at exactly 1 — the
//! fixed-point argument is spelled out in `docs/PROTOCOL.md` §10. An
//! incarnation advance of a live member likewise does not re-anchor:
//! the crash-rejoin it records biased `Ñ`/`p̃` at most transiently, the
//! quantile query cancels a uniform `p̃` factor, and exactness returns
//! at the next death re-anchor.
//!
//! With `gossip_restart_free = false` (the A/B arm of the churn
//! bench), the PR 5 rules apply instead: whenever a node's non-dead id
//! set changes — a join, a death, a tombstone resurrection — *or a
//! live member's incarnation advances*, its next refresh bumps the
//! restart generation and reseeds from its own summary
//! ([`Membership::take_view_changed`]); the generation sync of the
//! exchange frames drags the rest of the fleet along, and because the
//! *last* node to learn of the change also bumps, every node's final
//! reseed uses the converged table — mass is exactly 1 again among the
//! survivors. The re-anchor-on-death path of the restart-free rules is
//! this same mechanism, restricted to the flips that actually move
//! mass.
//!
//! The wire layout of the membership frames is normative in
//! `docs/PROTOCOL.md` §9; [`crate::sketch::codec`] implements it.
//!
//! The wire status codes and the BTree-only (data-ordered) state here
//! are machine-checked by the `spec-sync` and `collections` rules of
//! `dudd-analyze` (see `docs/ANALYSIS.md`).
//!
//! [`TcpTransport`]: super::TcpTransport

#![forbid(unsafe_code)]

use super::clock::{Clock, SystemClock};
use crate::config::GossipLoopConfig;
use crate::obs::{MembershipMetrics, ObsSlot};
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Liveness status of a member, as recorded in the table.
///
/// The numeric codes are wire bytes (normative in `docs/PROTOCOL.md`
/// §9) *and* the merge precedence at equal incarnation: a larger code
/// wins, so an observation of failure can only be overridden by the
/// member's own refutation at the next incarnation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MemberStatus {
    /// Exchanges complete (or no contrary evidence yet).
    Alive,
    /// A failure streak outlived `gossip_suspect_after_ms`; connect
    /// attempts back off, the member may refute.
    Suspect,
    /// The streak outlived two suspicion intervals; the entry is a
    /// tombstone that spreads until its TTL, and the member no longer
    /// participates in partner selection or the distinguished-peer rule.
    Dead,
}

impl MemberStatus {
    /// The wire code (also the equal-incarnation merge precedence).
    pub fn code(self) -> u8 {
        match self {
            MemberStatus::Alive => 0,
            MemberStatus::Suspect => 1,
            MemberStatus::Dead => 2,
        }
    }

    /// Decode a wire code.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(MemberStatus::Alive),
            1 => Some(MemberStatus::Suspect),
            2 => Some(MemberStatus::Dead),
            _ => None,
        }
    }
}

impl std::fmt::Display for MemberStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemberStatus::Alive => write!(f, "alive"),
            MemberStatus::Suspect => write!(f, "suspect"),
            MemberStatus::Dead => write!(f, "dead"),
        }
    }
}

/// One member's versioned record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemberEntry {
    /// Stable member id, assigned once by the join handshake (the
    /// bootstrap seed is id 0). Doubles as the protocol peer id.
    pub id: u64,
    /// The member's exchange listen address.
    pub addr: SocketAddr,
    /// Version counter bumped **only by the member itself** (on rejoin
    /// and on refuting a suspicion). Higher incarnation wins every
    /// merge.
    pub incarnation: u64,
    /// Liveness status at this incarnation.
    pub status: MemberStatus,
}

impl MemberEntry {
    /// A fresh alive entry at incarnation 1.
    pub fn alive(id: u64, addr: SocketAddr) -> Self {
        Self {
            id,
            addr,
            incarnation: 1,
            status: MemberStatus::Alive,
        }
    }

    /// Merge precedence: does `other` supersede `self`?
    ///
    /// Higher incarnation wins; at equal incarnation the worse status
    /// wins; remaining ties (an id collision) break on the smaller
    /// address string so merge order can never matter.
    fn superseded_by(&self, other: &MemberEntry) -> bool {
        match other.incarnation.cmp(&self.incarnation) {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Less => false,
            std::cmp::Ordering::Equal => match other.status.cmp(&self.status) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Equal => {
                    other.addr.to_string() < self.addr.to_string()
                }
            },
        }
    }
}

/// What one [`MemberTable::merge`] (or local transition) changed —
/// accumulated per round into
/// [`MembershipRoundStats`](super::MembershipRoundStats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MergeOutcome {
    /// Any entry changed (the table must keep spreading).
    pub changed: bool,
    /// New member ids learned.
    pub joined: usize,
    /// Members that turned suspect.
    pub suspected: usize,
    /// Members that turned dead.
    pub died: usize,
    /// The **non-dead id set** changed — the trigger for a protocol
    /// restart (generation bump + reseed) under the PR 5
    /// bump-on-every-view-change rules (`gossip_restart_free = false`),
    /// because the distinguished peer and the mass denominator both
    /// depend on it.
    pub view_changed: bool,
    /// Some member flipped **dead ↔ non-dead** — the only merge events
    /// that move averaged mass, and therefore the only restart trigger
    /// under the restart-free rules (`docs/PROTOCOL.md` §10). A plain
    /// join (`q̃ = 0` entry) and a live incarnation advance set
    /// [`MergeOutcome::view_changed`] but not this.
    pub reanchor: bool,
}

impl MergeOutcome {
    fn absorb(&mut self, other: MergeOutcome) {
        self.changed |= other.changed;
        self.joined += other.joined;
        self.suspected += other.suspected;
        self.died += other.died;
        self.view_changed |= other.view_changed;
        self.reanchor |= other.reanchor;
    }
}

/// The versioned member table: one entry per known member, ordered by
/// id (which makes the canonical encoding — and therefore table
/// comparison across nodes — deterministic).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MemberTable {
    entries: BTreeMap<u64, MemberEntry>,
}

impl MemberTable {
    /// An empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Entry count (tombstones included).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no members are known.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The entry for `id`, if known.
    pub fn get(&self, id: u64) -> Option<&MemberEntry> {
        self.entries.get(&id)
    }

    /// The entry whose listen address is `addr`, if any (lowest id wins
    /// when an address appears twice after an id collision).
    pub fn by_addr(&self, addr: SocketAddr) -> Option<&MemberEntry> {
        self.entries.values().find(|e| e.addr == addr)
    }

    /// Entries in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = &MemberEntry> {
        self.entries.values()
    }

    /// Highest assigned id (`None` for an empty table).
    pub fn max_id(&self) -> Option<u64> {
        self.entries.keys().next_back().copied()
    }

    /// The lowest non-dead id — the **distinguished peer** (Algorithm
    /// 3's `q̃ = 1` role) under churn.
    pub fn distinguished_id(&self) -> Option<u64> {
        self.entries
            .values()
            .find(|e| e.status != MemberStatus::Dead)
            .map(|e| e.id)
    }

    /// `(alive, suspect, dead)` counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for e in self.entries.values() {
            match e.status {
                MemberStatus::Alive => c.0 += 1,
                MemberStatus::Suspect => c.1 += 1,
                MemberStatus::Dead => c.2 += 1,
            }
        }
        c
    }

    /// Insert or supersede one entry (higher incarnation wins, then the
    /// worse status, then the smaller address — the module docs' merge
    /// precedence), reporting what changed.
    pub fn upsert(&mut self, entry: MemberEntry) -> MergeOutcome {
        let mut out = MergeOutcome::default();
        match self.entries.get_mut(&entry.id) {
            None => {
                out.changed = true;
                // A newly learned tombstone is a death, not a join (it
                // may even be a GC'd tombstone pushed back by a
                // straggler — the member never re-entered the fleet).
                match entry.status {
                    MemberStatus::Alive => out.joined = 1,
                    MemberStatus::Suspect => {
                        out.joined = 1;
                        out.suspected = 1;
                    }
                    MemberStatus::Dead => out.died = 1,
                }
                out.view_changed = entry.status != MemberStatus::Dead;
                // A fresh entry never re-anchors. A freshly learned
                // live member is a join — `q̃ = 0` entry, no mass
                // moved. A freshly learned tombstone records a flip
                // some *other* node witnessed (any member whose mass
                // entered the averages was in somebody's table as
                // alive): that witness bumps, and the bump reaches us
                // through generation adoption. Re-anchoring here would
                // turn every tombstone in a joiner's first table pull —
                // and every GC'd-tombstone push-back — into a
                // fleet-wide reseed.
                self.entries.insert(entry.id, entry);
            }
            Some(cur) if cur.superseded_by(&entry) => {
                out.changed = true;
                let was_dead = cur.status == MemberStatus::Dead;
                let is_dead = entry.status == MemberStatus::Dead;
                // `view_changed` keeps the PR 5 trigger set: the
                // non-dead set changed, OR a live member's incarnation
                // advanced (a rejoin stranded its q̃ share, or a
                // refutation recorded a suspicion round-trip).
                // `reanchor` is the restart-free subset: only the
                // dead ↔ non-dead flips actually move averaged mass —
                // a death strands the victim's share, and a tombstone
                // resurrection would re-enter mass the survivors
                // already re-anchored away (or hand a low-id rejoiner
                // a second `q̃ = 1`). An incarnation advance alone
                // biases `Ñ`/`p̃` at most transiently and cancels out
                // of quantile queries (`docs/PROTOCOL.md` §10).
                out.view_changed = was_dead != is_dead
                    || (entry.incarnation > cur.incarnation && !is_dead);
                out.reanchor = was_dead != is_dead;
                if !was_dead && is_dead {
                    out.died = 1;
                }
                if cur.status != MemberStatus::Suspect
                    && entry.status == MemberStatus::Suspect
                {
                    out.suspected = 1;
                }
                *cur = entry;
            }
            Some(_) => {}
        }
        out
    }

    /// Merge a remote table in (anti-entropy receive side). Entirely
    /// deterministic: merging the same set of entries in any order
    /// yields the same table.
    pub fn merge(&mut self, incoming: &MemberTable) -> MergeOutcome {
        let mut out = MergeOutcome::default();
        for e in incoming.entries.values() {
            out.absorb(self.upsert(e.clone()));
        }
        out
    }

    /// Remove the tombstone for `id` (tombstone GC).
    fn remove(&mut self, id: u64) {
        self.entries.remove(&id);
    }
}

/// Timing knobs of the membership runtime, normally derived from the
/// validated `gossip_*` config keys via [`MembershipConfig::from_gossip`].
#[derive(Debug, Clone)]
pub struct MembershipConfig {
    /// A failure streak older than this turns an alive member suspect;
    /// a suspect streak older than *another* such interval turns it
    /// dead (`gossip_suspect_after_ms`).
    pub suspect_after: Duration,
    /// Dead entries are garbage-collected this long after the local
    /// node observed the death (`gossip_tombstone_ttl_ms`). Keep it
    /// comfortably above the anti-entropy spread time, or a node that
    /// GC'd early keeps re-learning the tombstone from its peers.
    pub tombstone_ttl: Duration,
    /// First retry delay of the suspect-member backoff; doubles per
    /// consecutive failure up to [`MembershipConfig::backoff_cap`].
    pub backoff_base: Duration,
    /// Ceiling of the exponential backoff.
    pub backoff_cap: Duration,
    /// Restart-free churn (`gossip_restart_free`): only dead ↔ non-dead
    /// flips mark the view dirty for a generation re-anchor; joins and
    /// incarnation advances spread through the table without a restart
    /// (see the module docs' mass-accounting section).
    pub restart_free: bool,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        Self {
            suspect_after: Duration::from_millis(5_000),
            tombstone_ttl: Duration::from_millis(60_000),
            backoff_base: Duration::from_millis(250),
            backoff_cap: Duration::from_millis(30_000),
            restart_free: true,
        }
    }
}

impl MembershipConfig {
    /// Derive the timing knobs from the loop configuration
    /// (`gossip_suspect_after_ms`, `gossip_tombstone_ttl_ms`; the
    /// backoff base is a quarter of the suspicion interval, so a crashed
    /// peer draws at most a handful of full-deadline connects before the
    /// backoff dominates).
    pub fn from_gossip(cfg: &GossipLoopConfig) -> Self {
        let suspect_after = Duration::from_millis(cfg.suspect_after_ms);
        Self {
            suspect_after,
            tombstone_ttl: Duration::from_millis(cfg.tombstone_ttl_ms),
            backoff_base: (suspect_after / 4).max(Duration::from_millis(1)),
            backoff_cap: Duration::from_millis(30_000),
            restart_free: cfg.restart_free,
        }
    }
}

/// Local (never gossiped) per-member observation clocks.
#[derive(Debug, Default)]
struct Obs {
    /// Start of the current failure streak (`None` = no streak).
    streak_start: Option<Instant>,
    /// Consecutive failures in the streak (drives the backoff).
    failures: u32,
    /// Earliest next connect attempt (suspect members only).
    next_attempt: Option<Instant>,
    /// When this node observed the member turn suspect (the death clock
    /// starts here, so the refutation window is always one full
    /// suspicion interval *after* the suspect transition).
    suspect_since: Option<Instant>,
    /// The member rejected the membership plane (`NoMembership`, or a
    /// pre-plane peer answering `Malformed`): stop pushing tables to it.
    no_plane: bool,
    /// When this node observed the member dead (tombstone GC clock).
    dead_since: Option<Instant>,
}

#[derive(Debug)]
struct Inner {
    table: MemberTable,
    obs: BTreeMap<u64, Obs>,
    /// Highest member id ever seen (survives tombstone GC), so
    /// [`Membership::serve_join`] never re-mints a collected id.
    assigned_high: u64,
    /// Accumulated events since the last [`Membership::take_events`].
    pending: MergeOutcome,
    /// The view changed since the last
    /// [`Membership::take_view_changed`] in a way that requires a
    /// protocol restart — the gossip loop's re-anchor trigger. Under
    /// `restart_free` only dead ↔ non-dead flips
    /// ([`MergeOutcome::reanchor`]) set this; otherwise any non-dead id
    /// set change or live incarnation advance
    /// ([`MergeOutcome::view_changed`]) does. Kept separate from
    /// `pending` because the refresh step consumes it at a different
    /// time than the round telemetry.
    view_dirty: bool,
    /// Copy of [`MembershipConfig::restart_free`] — selects which
    /// [`MergeOutcome`] flag feeds `view_dirty`.
    restart_free: bool,
    /// This node's id now maps to a *different address* in the table:
    /// a concurrent join through another seed collided on the id and
    /// the merge tie-break kept the other node. Set sticky; the loop
    /// stops initiating (see [`Membership::identity_lost`]).
    identity_lost: bool,
}

impl Inner {
    fn absorb(&mut self, out: MergeOutcome) {
        self.pending.absorb(out);
        self.view_dirty |= if self.restart_free {
            out.reanchor
        } else {
            out.view_changed
        };
        self.assigned_high = self.assigned_high.max(self.table.max_id().unwrap_or(0));
    }

    /// Apply the time-based status transition for one member's failure
    /// streak (alive → suspect → dead). Shared by the per-failure path
    /// and the per-round [`Membership::tick`], so a backoff-gated (or
    /// never-selected) member still dies on schedule.
    fn streak_transition(
        &mut self,
        id: u64,
        now: Instant,
        cfg: &MembershipConfig,
    ) -> MergeOutcome {
        let Some(started) = self.obs.get(&id).and_then(|o| o.streak_start) else {
            return MergeOutcome::default();
        };
        let Some(cur) = self.table.get(id).cloned() else {
            return MergeOutcome::default();
        };
        let elapsed = now.duration_since(started);
        let next = match cur.status {
            MemberStatus::Alive if elapsed >= cfg.suspect_after => MemberStatus::Suspect,
            MemberStatus::Suspect => {
                // The death clock runs from when *we* saw the member turn
                // suspect (set below on our own transition; set here on
                // first sight of a merged-in suspicion), never from the
                // streak start — the member always gets one full
                // suspicion interval to refute, however late the suspect
                // promotion itself fired.
                let since = *self
                    .obs
                    .entry(id)
                    .or_default()
                    .suspect_since
                    .get_or_insert(now);
                if now.duration_since(since) >= cfg.suspect_after {
                    MemberStatus::Dead
                } else {
                    return MergeOutcome::default();
                }
            }
            _ => return MergeOutcome::default(),
        };
        let out = self.table.upsert(MemberEntry {
            status: next,
            ..cur
        });
        let o = self.obs.entry(id).or_default();
        match next {
            MemberStatus::Suspect => {
                // The backoff restarts at its base on the suspect
                // transition: the failures piled up while the member was
                // still alive (ungated) must not inflate the first
                // probe's delay to the cap.
                o.failures = 0;
                o.next_attempt = Some(now + cfg.backoff_base);
                o.suspect_since = Some(now);
            }
            MemberStatus::Dead => {
                o.dead_since.get_or_insert(now);
            }
            MemberStatus::Alive => {}
        }
        self.absorb(out);
        out
    }
}

/// The shared membership runtime of one node: the table plus the local
/// suspicion/backoff/GC clocks. Cheap to share (`Arc`); every method
/// takes one short internal lock and never blocks on sockets.
#[derive(Debug)]
pub struct Membership {
    self_id: u64,
    self_addr: SocketAddr,
    cfg: MembershipConfig,
    inner: Mutex<Inner>,
    /// The time source behind the suspicion/backoff/tombstone clocks:
    /// [`SystemClock`] in production, a shared
    /// [`VirtualClock`](super::clock::VirtualClock) under simulation.
    clock: Arc<dyn Clock>,
    /// Observability handles, installed once by the owning gossip loop
    /// at start; every mutation path mirrors its outcome here. Empty on
    /// a standalone `Membership` (unit tests, direct construction).
    metrics: ObsSlot<MembershipMetrics>,
}

impl Membership {
    /// Found a new fleet: this node is the bootstrap seed, member id 0.
    pub fn bootstrap(self_addr: SocketAddr, cfg: MembershipConfig) -> Self {
        Self::bootstrap_with_clock(self_addr, cfg, Arc::new(SystemClock))
    }

    /// [`Membership::bootstrap`] on an explicit time source — the
    /// simulator injects a shared virtual clock here.
    pub fn bootstrap_with_clock(
        self_addr: SocketAddr,
        cfg: MembershipConfig,
        clock: Arc<dyn Clock>,
    ) -> Self {
        let mut table = MemberTable::new();
        table.upsert(MemberEntry::alive(0, self_addr));
        let restart_free = cfg.restart_free;
        Self {
            self_id: 0,
            self_addr,
            cfg,
            inner: Mutex::new(Inner {
                assigned_high: table.max_id().unwrap_or(0),
                table,
                obs: BTreeMap::new(),
                pending: MergeOutcome::default(),
                view_dirty: false,
                restart_free,
                identity_lost: false,
            }),
            clock,
            metrics: ObsSlot::new(),
        }
    }

    /// Adopt the table a seed answered the join handshake with; the
    /// node's own entry is located by its listen address.
    pub fn from_join(
        table: MemberTable,
        self_addr: SocketAddr,
        cfg: MembershipConfig,
    ) -> crate::Result<Self> {
        Self::from_join_with_clock(table, self_addr, cfg, Arc::new(SystemClock))
    }

    /// [`Membership::from_join`] on an explicit time source — the
    /// simulator injects a shared virtual clock here.
    pub fn from_join_with_clock(
        table: MemberTable,
        self_addr: SocketAddr,
        cfg: MembershipConfig,
        clock: Arc<dyn Clock>,
    ) -> crate::Result<Self> {
        let me = table.by_addr(self_addr).ok_or_else(|| {
            anyhow::anyhow!(
                "join reply table carries no entry for this node's listen \
                 address {self_addr} — did the seed serve the handshake?"
            )
        })?;
        let restart_free = cfg.restart_free;
        Ok(Self {
            self_id: me.id,
            self_addr,
            cfg,
            inner: Mutex::new(Inner {
                assigned_high: table.max_id().unwrap_or(0),
                table,
                obs: BTreeMap::new(),
                pending: MergeOutcome::default(),
                view_dirty: false,
                restart_free,
                identity_lost: false,
            }),
            clock,
            metrics: ObsSlot::new(),
        })
    }

    /// The current instant of this node's time source (wall clock in
    /// production, the scenario clock under simulation). The gossip
    /// loop reads every round's `now` through this so suspicion and GC
    /// follow the injected timeline.
    pub fn now(&self) -> Instant {
        self.clock.now()
    }

    /// Install the membership-plane metric handles. The gossip loop
    /// calls this once at start; the liveness gauges sync to the
    /// current view immediately and every later mutation keeps them
    /// current. A second install is ignored (first wins).
    pub(crate) fn install_metrics(&self, metrics: Arc<MembershipMetrics>) {
        self.metrics.install(metrics);
        self.book(self.counts(), MergeOutcome::default());
    }

    /// Mirror one mutation onto the installed handles: the event
    /// counters from `out`, the liveness gauges from the post-mutation
    /// `counts`. A no-op until [`Membership::install_metrics`] runs.
    fn book(&self, counts: (usize, usize, usize), out: MergeOutcome) {
        if let Some(m) = self.metrics.get() {
            m.joins.add(out.joined as u64);
            m.suspicions.add(out.suspected as u64);
            m.deaths.add(out.died as u64);
            m.alive.set_usize(counts.0);
            m.suspect.set_usize(counts.1);
            m.dead.set_usize(counts.2);
        }
    }

    /// This node's stable member id (the protocol peer id).
    pub fn self_id(&self) -> u64 {
        self.self_id
    }

    /// This node's exchange listen address.
    pub fn self_addr(&self) -> SocketAddr {
        self.self_addr
    }

    /// The timing configuration.
    pub fn config(&self) -> &MembershipConfig {
        &self.cfg
    }

    /// A snapshot of the table.
    pub fn table(&self) -> MemberTable {
        self.lock().table.clone()
    }

    /// True when this node is the distinguished peer (lowest non-dead
    /// id) in its current view — the member that reseeds with `q̃ = 1`.
    pub fn is_distinguished(&self) -> bool {
        self.lock().table.distinguished_id() == Some(self.self_id)
    }

    /// `(alive, suspect, dead)` counts of the current view.
    pub fn counts(&self) -> (usize, usize, usize) {
        self.lock().table.counts()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("membership state poisoned")
    }

    /// Merge a table heard from a partner (anti-entropy receive). If the
    /// incoming table suspects (or kills) *this* node, the node refutes:
    /// it re-asserts itself alive at the next incarnation, a table
    /// change that spreads back out.
    pub fn merge_remote(&self, incoming: &MemberTable) -> MergeOutcome {
        let mut inner = self.lock();
        // Planeless members clear their flag when their entry's
        // incarnation advances — a rejoin (possibly after an upgrade)
        // that every node observes through the merge, not just the one
        // seed that served the handshake.
        let planeless: Vec<(u64, u64)> = inner
            .obs
            .iter()
            .filter(|(_, o)| o.no_plane)
            .filter_map(|(&id, _)| inner.table.get(id).map(|e| (id, e.incarnation)))
            .collect();
        let mut out = inner.table.merge(incoming);
        for (id, inc) in planeless {
            if inner.table.get(id).is_some_and(|e| e.incarnation > inc) {
                if let Some(o) = inner.obs.get_mut(&id) {
                    o.no_plane = false;
                }
            }
        }
        let mut refuted = false;
        let me = inner.table.get(self.self_id).cloned();
        if let Some(me) = me {
            if me.addr != self.self_addr {
                // Another address won our id (concurrent joins through
                // different seeds collided and the tie-break kept the
                // other node). Re-asserting would start an endless
                // merge war; instead the identity loss is flagged and
                // the loop stops initiating — the clean failure mode.
                // Recovery is a rejoin (which assigns a fresh id).
                inner.identity_lost = true;
            } else if me.status != MemberStatus::Alive {
                let reassert = MemberEntry {
                    id: self.self_id,
                    addr: self.self_addr,
                    incarnation: me.incarnation + 1,
                    status: MemberStatus::Alive,
                };
                out.absorb(inner.table.upsert(reassert));
                refuted = true;
            }
        }
        // Merged-in deaths start their tombstone clock now, locally.
        let now = self.clock.now();
        let dead: Vec<u64> = inner
            .table
            .iter()
            .filter(|e| e.status == MemberStatus::Dead)
            .map(|e| e.id)
            .collect();
        for id in dead {
            inner.obs.entry(id).or_default().dead_since.get_or_insert(now);
        }
        inner.absorb(out);
        self.book(inner.table.counts(), out);
        if refuted {
            if let Some(m) = self.metrics.get() {
                m.refutations.inc();
            }
        }
        out
    }

    /// Serve one `dudd-join` handshake: assign an id to `addr` (a brand
    /// new one, or the same id at the next incarnation when the address
    /// is rejoining), insert the alive entry, and return the full table
    /// for the reply.
    pub fn serve_join(&self, addr: SocketAddr) -> MemberTable {
        let mut inner = self.lock();
        let entry = match inner.table.by_addr(addr) {
            // Rejoin: the same address re-enters at the next incarnation
            // and keeps its id (supersedes any suspect/dead record).
            Some(old) => MemberEntry {
                id: old.id,
                addr,
                incarnation: old.incarnation + 1,
                status: MemberStatus::Alive,
            },
            None => {
                // High-water mark, not the table max: a GC'd tombstone's
                // id must never be re-minted for a different node.
                let id = inner.assigned_high.max(inner.table.max_id().unwrap_or(0)) + 1;
                MemberEntry::alive(id, addr)
            }
        };
        let id = entry.id;
        let out = inner.table.upsert(entry);
        inner.absorb(out);
        // A rejoin wipes the old failure streak.
        inner.obs.remove(&id);
        self.book(inner.table.counts(), out);
        inner.table.clone()
    }

    /// Record liveness evidence for `id`: any reply at all — a completed
    /// exchange, but also `Busy` and `StaleGeneration` rejects — clears
    /// the failure streak and the backoff.
    pub fn record_success(&self, id: u64) {
        let mut inner = self.lock();
        if let Some(o) = inner.obs.get_mut(&id) {
            o.streak_start = None;
            o.failures = 0;
            o.next_attempt = None;
            o.suspect_since = None;
        }
    }

    /// Record a failed exchange with `id` (connect refused, deadline,
    /// dead channel): starts/extends the failure streak, advances the
    /// exponential backoff, and applies the time-based status
    /// transitions (alive → suspect → dead).
    pub fn record_failure(&self, id: u64) -> MergeOutcome {
        let now = self.clock.now();
        let mut inner = self.lock();
        let cfg = &self.cfg;
        {
            let o = inner.obs.entry(id).or_default();
            o.streak_start.get_or_insert(now);
            o.failures = o.failures.saturating_add(1);
            let backoff = cfg
                .backoff_base
                .saturating_mul(1u32 << o.failures.min(16))
                .min(cfg.backoff_cap);
            o.next_attempt = Some(now + backoff);
        }
        let out = inner.streak_transition(id, now, cfg);
        self.book(inner.table.counts(), out);
        out
    }

    /// Advance the wall-clock status transitions for every member with
    /// an active failure streak — called once per round, so a suspect
    /// whose probes are backoff-gated (or who is simply never drawn as
    /// a partner) still turns dead exactly one suspicion interval after
    /// turning suspect, as `docs/PROTOCOL.md` §9 specifies. Returns the
    /// accumulated outcome.
    pub fn tick(&self, now: Instant) -> MergeOutcome {
        let mut inner = self.lock();
        let streaked: Vec<u64> = inner
            .obs
            .iter()
            .filter(|(_, o)| o.streak_start.is_some())
            .map(|(&id, _)| id)
            .collect();
        let mut out = MergeOutcome::default();
        for id in streaked {
            out.absorb(inner.streak_transition(id, now, &self.cfg));
        }
        self.book(inner.table.counts(), out);
        out
    }

    /// Partner candidates for one round: every non-self member that is
    /// **alive**, plus **suspect** members whose backoff has elapsed (a
    /// probe that lets them prove recovery). Dead members are never
    /// selected. Ascending id order (deterministic).
    pub fn eligible_partners(&self, now: Instant) -> Vec<(u64, SocketAddr)> {
        let inner = self.lock();
        inner
            .table
            .iter()
            .filter(|e| e.id != self.self_id)
            .filter(|e| match e.status {
                MemberStatus::Alive => true,
                MemberStatus::Suspect => match inner.obs.get(&e.id).and_then(|o| o.next_attempt)
                {
                    Some(t) => now >= t,
                    None => true,
                },
                MemberStatus::Dead => false,
            })
            .map(|e| (e.id, e.addr))
            .collect()
    }

    /// Garbage-collect tombstones whose local death observation is older
    /// than the TTL. Returns how many entries were removed. (GC is
    /// local-clock driven, so nodes may transiently disagree on a GC'd
    /// entry; a peer that still holds the tombstone simply pushes it
    /// back, which is harmless — the member stays dead — and ends once
    /// every node's TTL has passed.)
    pub fn gc(&self, now: Instant) -> usize {
        let ttl = self.cfg.tombstone_ttl;
        let mut inner = self.lock();
        let expired: Vec<u64> = inner
            .table
            .iter()
            .filter(|e| e.status == MemberStatus::Dead)
            .filter(|e| {
                inner
                    .obs
                    .get(&e.id)
                    .and_then(|o| o.dead_since)
                    .is_some_and(|t| now.duration_since(t) >= ttl)
            })
            .map(|e| e.id)
            .collect();
        for id in &expired {
            inner.table.remove(*id);
            inner.obs.remove(id);
        }
        if !expired.is_empty() {
            self.book(inner.table.counts(), MergeOutcome::default());
        }
        expired.len()
    }

    /// True once this node discovered that its member id belongs to a
    /// *different address* in the converged table — a concurrent join
    /// through another seed collided on the id and the deterministic
    /// tie-break kept the other node. A node that lost its identity
    /// must stop initiating exchanges (the gossip loop checks this
    /// every round): silently gossiping under a stolen id would break
    /// the generation's `q̃` mass with no detection anywhere. Recovery
    /// is operator-driven: restart the node with a fresh join (it will
    /// be assigned a new id). Sticky once set.
    pub fn identity_lost(&self) -> bool {
        self.lock().identity_lost
    }

    /// The partner rejected the membership plane (a static address-book
    /// node, or a pre-plane peer answering `Malformed`): per
    /// `docs/PROTOCOL.md` §8 the sender stops pushing tables there —
    /// repeating the push every round would also kill the warm pooled
    /// connection each time a `Malformed`-answering peer closes it. The
    /// flag clears when the member's incarnation advances (a rejoin,
    /// observed by every node through the merge) or when this node
    /// itself serves the member's rejoin handshake.
    pub fn mark_planeless(&self, id: u64) {
        self.lock().obs.entry(id).or_default().no_plane = true;
    }

    /// Whether membership pushes to `id` are still worthwhile (see
    /// [`Membership::mark_planeless`]).
    pub fn plane_enabled(&self, id: u64) -> bool {
        !self.lock().obs.get(&id).is_some_and(|o| o.no_plane)
    }

    /// Drain the events accumulated since the last call (merges, local
    /// transitions, joins served) — the per-round membership telemetry.
    pub fn take_events(&self) -> MergeOutcome {
        std::mem::take(&mut self.lock().pending)
    }

    /// Peek: has the non-dead member set changed since the last
    /// [`Membership::take_view_changed`]? (The gossip loop's cheap
    /// pre-lock check.)
    pub fn view_change_pending(&self) -> bool {
        self.lock().view_dirty
    }

    /// Consume the view-change flag. The gossip loop calls this under
    /// its full refresh locks: a `true` here restarts the protocol
    /// (generation bump + reseed-from-own-summary), which is what keeps
    /// the `q̃` mass at exactly 1 across joins and deaths.
    pub fn take_view_changed(&self) -> bool {
        std::mem::take(&mut self.lock().view_dirty)
    }

    /// The canonical encoding of the current table (`docs/PROTOCOL.md`
    /// §9) — byte-identical across nodes whose views have converged,
    /// which is how the churn acceptance test compares survivors.
    pub fn encoded_table(&self) -> Vec<u8> {
        crate::sketch::codec::encode_member_table(&self.lock().table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    /// Timings fast enough for unit tests but with enough margin that a
    /// scheduler stall between consecutive statements (loaded CI runner)
    /// cannot flip a "transition has NOT happened yet" assertion.
    fn fast_cfg() -> MembershipConfig {
        MembershipConfig {
            suspect_after: Duration::from_millis(150),
            tombstone_ttl: Duration::from_millis(400),
            backoff_base: Duration::from_millis(150),
            backoff_cap: Duration::from_millis(600),
            restart_free: true,
        }
    }

    #[test]
    fn merge_is_order_independent() {
        let entries = [
            MemberEntry::alive(0, addr(1)),
            MemberEntry {
                id: 1,
                addr: addr(2),
                incarnation: 3,
                status: MemberStatus::Suspect,
            },
            MemberEntry {
                id: 1,
                addr: addr(2),
                incarnation: 2,
                status: MemberStatus::Dead,
            },
            MemberEntry {
                id: 2,
                addr: addr(3),
                incarnation: 1,
                status: MemberStatus::Dead,
            },
            MemberEntry {
                id: 2,
                addr: addr(3),
                incarnation: 1,
                status: MemberStatus::Alive,
            },
        ];
        // Every permutation of upserts converges to the same table.
        let reference = {
            let mut t = MemberTable::new();
            for e in &entries {
                t.upsert(e.clone());
            }
            t
        };
        let perms: &[[usize; 5]] = &[
            [4, 3, 2, 1, 0],
            [2, 0, 4, 1, 3],
            [1, 2, 3, 4, 0],
            [3, 1, 4, 0, 2],
        ];
        for p in perms {
            let mut t = MemberTable::new();
            for &i in p {
                t.upsert(entries[i].clone());
            }
            assert_eq!(t, reference, "permutation {p:?}");
        }
        // Incarnation 3 won for member 1; dead won at equal incarnation
        // for member 2.
        assert_eq!(reference.get(1).unwrap().status, MemberStatus::Suspect);
        assert_eq!(reference.get(1).unwrap().incarnation, 3);
        assert_eq!(reference.get(2).unwrap().status, MemberStatus::Dead);
    }

    #[test]
    fn merge_reports_view_changes() {
        let mut t = MemberTable::new();
        let out = t.upsert(MemberEntry::alive(0, addr(1)));
        assert!(out.changed && out.view_changed);
        assert!(!out.reanchor, "a join must not re-anchor");
        assert_eq!(out.joined, 1);

        // Same entry again: nothing.
        let out = t.upsert(MemberEntry::alive(0, addr(1)));
        assert_eq!(out, MergeOutcome::default());

        // Suspect at same incarnation: changed, but the non-dead set is
        // intact.
        let out = t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 1,
            status: MemberStatus::Suspect,
        });
        assert!(out.changed && !out.view_changed);
        assert!(!out.reanchor);
        assert_eq!(out.suspected, 1);

        // Death changes the view — and moves mass, so it re-anchors.
        let out = t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 1,
            status: MemberStatus::Dead,
        });
        assert!(out.view_changed && out.reanchor);
        assert_eq!(out.died, 1);

        // Refutation (next incarnation, alive) changes it back: a
        // dead → non-dead flip, so it re-anchors too.
        let out = t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 2,
            status: MemberStatus::Alive,
        });
        assert!(out.changed && out.view_changed && out.reanchor);

        // A live member's incarnation advancing (alive → alive) is a
        // crash-rejoin: under the PR 5 rules (`view_changed`) the
        // protocol restarts, but under restart-free rules it does not —
        // the rejoiner re-enters with `q̃ = 0`, so no mass moved.
        let out = t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 3,
            status: MemberStatus::Alive,
        });
        assert!(out.changed && out.view_changed, "{out:?}");
        assert!(!out.reanchor, "live incarnation advance must not re-anchor");

        // A newly learned tombstone is a death, never a join — and it
        // never re-anchors (the node that witnessed the flip bumps; a
        // fresh tombstone here is a joiner's first table pull or a
        // GC'd-tombstone push-back).
        let out = t.upsert(MemberEntry {
            id: 9,
            addr: addr(9),
            incarnation: 1,
            status: MemberStatus::Dead,
        });
        assert_eq!(out.joined, 0);
        assert_eq!(out.died, 1);
        assert!(!out.view_changed);
        assert!(!out.reanchor);
    }

    /// The restart trigger (`view_dirty`, consumed by the gossip
    /// loop's refresh) fires only on dead ↔ non-dead flips under the
    /// default restart-free rules, and on every non-dead-set change
    /// under the PR 5 rules (`restart_free: false`).
    #[test]
    fn view_dirty_gating_depends_on_restart_free() {
        // Restart-free: joins and incarnation advances don't restart.
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.take_view_changed(); // drain the bootstrap self-join
        m.serve_join(addr(2));
        assert!(
            !m.take_view_changed(),
            "a served join must not restart the protocol"
        );
        let mut rejoined = MemberTable::new();
        rejoined.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 5,
            status: MemberStatus::Alive,
        });
        m.merge_remote(&rejoined);
        assert!(
            !m.take_view_changed(),
            "a live incarnation advance must not restart the protocol"
        );
        // A merged death is a dead ↔ non-dead flip: restart.
        let mut dead = MemberTable::new();
        dead.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 5,
            status: MemberStatus::Dead,
        });
        m.merge_remote(&dead);
        assert!(m.take_view_changed(), "a death must restart the protocol");
        // A tombstone resurrection flips back: restart again.
        let mut back = MemberTable::new();
        back.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 6,
            status: MemberStatus::Alive,
        });
        m.merge_remote(&back);
        assert!(
            m.take_view_changed(),
            "a tombstone resurrection must restart the protocol"
        );

        // PR 5 rules: any non-dead-set change restarts, joins included.
        let cfg = MembershipConfig {
            restart_free: false,
            ..fast_cfg()
        };
        let m = Membership::bootstrap(addr(1), cfg);
        m.take_view_changed();
        m.serve_join(addr(2));
        assert!(
            m.take_view_changed(),
            "with gossip_restart_free=false a join restarts the protocol"
        );
    }

    #[test]
    fn planeless_partners_stop_receiving_pushes_until_rejoin() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.serve_join(addr(2));
        assert!(m.plane_enabled(1));
        m.mark_planeless(1);
        assert!(!m.plane_enabled(1));
        // Liveness evidence alone does not clear the flag…
        m.record_success(1);
        assert!(!m.plane_enabled(1));
        // …but a rejoin through this seed wipes the observation record.
        m.serve_join(addr(2));
        assert!(m.plane_enabled(1));

        // Observing the member's incarnation advance in a merge clears
        // the flag too — the rejoin signal every node sees, not just
        // the seed that served the handshake.
        m.mark_planeless(1);
        assert!(!m.plane_enabled(1));
        let mut rejoined = MemberTable::new();
        rejoined.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 9,
            status: MemberStatus::Alive,
        });
        m.merge_remote(&rejoined);
        assert!(m.plane_enabled(1), "incarnation advance clears no_plane");
    }

    #[test]
    fn distinguished_is_lowest_non_dead_id() {
        let mut t = MemberTable::new();
        t.upsert(MemberEntry::alive(0, addr(1)));
        t.upsert(MemberEntry::alive(1, addr(2)));
        t.upsert(MemberEntry::alive(2, addr(3)));
        assert_eq!(t.distinguished_id(), Some(0));
        t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 1,
            status: MemberStatus::Dead,
        });
        assert_eq!(t.distinguished_id(), Some(1));
    }

    #[test]
    fn join_assigns_sequential_and_rejoin_keeps_id() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        assert_eq!(m.self_id(), 0);
        assert!(m.is_distinguished());

        let t = m.serve_join(addr(2));
        assert_eq!(t.by_addr(addr(2)).unwrap().id, 1);
        let t = m.serve_join(addr(3));
        assert_eq!(t.by_addr(addr(3)).unwrap().id, 2);

        // The same address rejoining keeps its id at the next
        // incarnation (supersedes a dead record).
        m.merge_remote(&{
            let mut t = MemberTable::new();
            t.upsert(MemberEntry {
                id: 1,
                addr: addr(2),
                incarnation: 1,
                status: MemberStatus::Dead,
            });
            t
        });
        let t = m.serve_join(addr(2));
        let e = t.by_addr(addr(2)).unwrap();
        assert_eq!(e.id, 1);
        assert_eq!(e.incarnation, 2);
        assert_eq!(e.status, MemberStatus::Alive);
        // A fresh address still gets the next id.
        let t = m.serve_join(addr(4));
        assert_eq!(t.by_addr(addr(4)).unwrap().id, 3);
    }

    #[test]
    fn failure_streak_walks_alive_suspect_dead() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.serve_join(addr(2));
        m.take_events();

        // The streak starts at the first failure; no instant transition.
        let out = m.record_failure(1);
        assert_eq!(out, MergeOutcome::default(), "too early to suspect");
        std::thread::sleep(Duration::from_millis(170));
        // Streak ≥ suspect_after → suspect.
        let out = m.record_failure(1);
        assert_eq!(out.suspected, 1);
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Suspect);
        assert!(!out.view_changed, "suspicion keeps the non-dead set");

        // Still the same streak: death waits one more full suspicion
        // interval measured from the suspect transition.
        let out = m.record_failure(1);
        assert_eq!(out, MergeOutcome::default(), "needs 2x the interval");
        std::thread::sleep(Duration::from_millis(170));
        // Streak ≥ 2 × suspect_after → dead.
        let out = m.record_failure(1);
        assert_eq!(out.died, 1);
        assert!(out.view_changed, "death changes the non-dead set");
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Dead);

        let ev = m.take_events();
        assert_eq!(ev.suspected, 1);
        assert_eq!(ev.died, 1);
        assert_eq!(m.take_events(), MergeOutcome::default(), "drained");
    }

    #[test]
    fn success_clears_streak_and_backoff() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.serve_join(addr(2));
        m.record_failure(1);
        std::thread::sleep(Duration::from_millis(170));
        m.record_failure(1);
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Suspect);
        assert!(
            m.eligible_partners(Instant::now()).is_empty(),
            "suspect member is backoff-gated right after a failure"
        );

        // Liveness evidence resets the clocks; the (still suspect)
        // member becomes immediately probeable again.
        m.record_success(1);
        assert_eq!(
            m.eligible_partners(Instant::now()),
            vec![(1, addr(2))],
            "success clears the backoff gate"
        );
        // ...and a fresh streak starts from scratch (no instant death).
        let out = m.record_failure(1);
        assert_eq!(out, MergeOutcome::default());
    }

    #[test]
    fn suspect_backoff_gates_and_doubles() {
        let cfg = fast_cfg();
        let m = Membership::bootstrap(addr(1), cfg.clone());
        m.serve_join(addr(2));
        m.record_failure(1); // streak starts
        std::thread::sleep(Duration::from_millis(170));
        m.record_failure(1); // → suspect, backoff armed
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Suspect);
        let now = Instant::now();
        assert!(m.eligible_partners(now).is_empty(), "gated");
        // After the backoff elapses the suspect is probeable again.
        assert_eq!(
            m.eligible_partners(now + Duration::from_millis(500)).len(),
            1,
            "probe allowed once the backoff elapses"
        );
        // Dead members are never eligible, backoff or not.
        std::thread::sleep(Duration::from_millis(170));
        m.record_failure(1);
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Dead);
        assert!(m
            .eligible_partners(now + Duration::from_millis(10_000))
            .is_empty());
    }

    /// The wall-clock sweep drives suspect → dead even when the member
    /// is never probed again (its backoff would otherwise gate the only
    /// event that could declare death).
    #[test]
    fn tick_advances_streaks_without_probes() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.serve_join(addr(2));
        m.take_events();
        m.record_failure(1); // one failure, then never selected again
        std::thread::sleep(Duration::from_millis(170));
        let out = m.tick(Instant::now());
        assert_eq!(out.suspected, 1, "tick promotes alive → suspect");
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Suspect);
        std::thread::sleep(Duration::from_millis(170));
        let out = m.tick(Instant::now());
        assert_eq!(out.died, 1, "tick promotes suspect → dead on schedule");
        assert!(out.view_changed);
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Dead);
        // Idle tick: nothing left to advance.
        assert_eq!(m.tick(Instant::now()), MergeOutcome::default());
    }

    /// The suspect transition restarts the backoff at its base: failures
    /// piled up while the member was still alive (ungated probes) must
    /// not push the first suspect probe out to the cap.
    #[test]
    fn suspect_transition_resets_backoff_to_base() {
        let cfg = fast_cfg();
        let m = Membership::bootstrap(addr(1), cfg.clone());
        m.serve_join(addr(2));
        // Pile up failures while alive: backoff would be base * 2^10.
        for _ in 0..10 {
            m.record_failure(1);
        }
        std::thread::sleep(Duration::from_millis(170));
        m.tick(Instant::now());
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Suspect);
        // The first probe is gated only by the base, not the piled-up cap.
        let now = Instant::now();
        assert_eq!(
            m.eligible_partners(now + cfg.backoff_base + Duration::from_millis(50))
                .len(),
            1,
            "suspect probeable one base-backoff after the transition"
        );
    }

    /// A GC'd tombstone's id is never re-minted for a different node:
    /// the seed keeps an assigned-id high-water mark.
    #[test]
    fn gc_never_recycles_ids() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        let t = m.serve_join(addr(2));
        assert_eq!(t.by_addr(addr(2)).unwrap().id, 1);
        let mut dead = MemberTable::new();
        dead.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 1,
            status: MemberStatus::Dead,
        });
        m.merge_remote(&dead);
        m.gc(Instant::now() + Duration::from_millis(450));
        assert!(m.table().get(1).is_none(), "tombstone collected");
        // A NEW address must get a fresh id, not the collected 1.
        let t = m.serve_join(addr(3));
        assert_eq!(t.by_addr(addr(3)).unwrap().id, 2);
    }

    #[test]
    fn refutation_bumps_incarnation() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        // Someone suspects us at our incarnation.
        let mut t = MemberTable::new();
        t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 1,
            status: MemberStatus::Suspect,
        });
        let out = m.merge_remote(&t);
        assert!(out.changed);
        let me = m.table().get(0).unwrap().clone();
        assert_eq!(me.status, MemberStatus::Alive, "refuted");
        assert_eq!(me.incarnation, 2, "refutation bumps the incarnation");
        // The refutation beats the suspicion in every other node's merge.
        let mut other = t.clone();
        other.merge(&m.table());
        assert_eq!(other.get(0).unwrap().status, MemberStatus::Alive);
    }

    /// A node whose id was claimed by another address (concurrent joins
    /// through different seeds colliding) detects the loss, does NOT
    /// start a refutation war, and reports it so the loop can stop
    /// initiating.
    #[test]
    fn id_collision_loser_detects_identity_loss() {
        let mut table = MemberTable::new();
        table.upsert(MemberEntry::alive(0, addr(1)));
        table.upsert(MemberEntry::alive(5, addr(2)));
        let m = Membership::from_join(table, addr(2), fast_cfg()).unwrap();
        assert_eq!(m.self_id(), 5);
        assert!(!m.identity_lost());

        // Another seed assigned the same id to a lexicographically
        // smaller address; the deterministic tie-break keeps that entry
        // ("127.0.0.1:10" < "127.0.0.1:2" as strings).
        let mut winner = MemberTable::new();
        winner.upsert(MemberEntry::alive(5, addr(10)));
        m.merge_remote(&winner);
        let me = m.table().get(5).unwrap().clone();
        assert_eq!(me.addr, addr(10), "tie-break keeps the winner");
        assert!(m.identity_lost(), "loss must be detected");
        // No refutation war: the winner's entry is left intact.
        assert_eq!(me.incarnation, 1);
        assert_eq!(me.status, MemberStatus::Alive);
        // Sticky: later merges do not clear it.
        m.merge_remote(&winner);
        assert!(m.identity_lost());
    }

    #[test]
    fn tombstones_gc_after_ttl() {
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.serve_join(addr(2));
        let mut dead = MemberTable::new();
        dead.upsert(MemberEntry {
            id: 1,
            addr: addr(2),
            incarnation: 1,
            status: MemberStatus::Dead,
        });
        m.merge_remote(&dead);
        assert_eq!(m.table().len(), 2);
        assert_eq!(m.gc(Instant::now()), 0, "TTL not elapsed");
        assert_eq!(
            m.gc(Instant::now() + Duration::from_millis(450)),
            1,
            "tombstone collected after the TTL"
        );
        assert_eq!(m.table().len(), 1);
        assert!(m.table().get(1).is_none());
        // A straggler pushing the tombstone back is harmless: the member
        // is dead again (and will GC again).
        let out = m.merge_remote(&dead);
        assert_eq!(out.died, 1);
        assert_eq!(m.table().get(1).unwrap().status, MemberStatus::Dead);
    }

    /// Installed handles mirror the table: join/suspicion/death
    /// counters from the events, liveness gauges from the view, the
    /// refutation counter from the self-suspicion path.
    #[test]
    fn installed_metrics_mirror_members_and_events() {
        let obs = crate::obs::NodeMetrics::standalone();
        let m = Membership::bootstrap(addr(1), fast_cfg());
        m.install_metrics(obs.membership.clone());
        assert_eq!(obs.membership.alive.get(), 1.0, "gauges sync on install");

        m.serve_join(addr(2));
        assert_eq!(obs.membership.joins.get(), 1);
        assert_eq!(obs.membership.alive.get(), 2.0);

        // Walk member 1 alive → suspect → dead on the wall clock.
        m.record_failure(1);
        std::thread::sleep(Duration::from_millis(170));
        m.tick(Instant::now());
        assert_eq!(obs.membership.suspicions.get(), 1);
        assert_eq!(obs.membership.suspect.get(), 1.0);
        std::thread::sleep(Duration::from_millis(170));
        m.tick(Instant::now());
        assert_eq!(obs.membership.deaths.get(), 1);
        assert_eq!(obs.membership.dead.get(), 1.0);
        assert_eq!(obs.membership.alive.get(), 1.0);

        // GC drops the tombstone gauge back to zero.
        m.gc(Instant::now() + Duration::from_millis(450));
        assert_eq!(obs.membership.dead.get(), 0.0);

        // A suspicion about *this* node is refuted in the merge — the
        // suspicion itself still counts (it happened), and so does the
        // incarnation-bump refutation.
        let mut t = MemberTable::new();
        t.upsert(MemberEntry {
            id: 0,
            addr: addr(1),
            incarnation: 1,
            status: MemberStatus::Suspect,
        });
        m.merge_remote(&t);
        assert_eq!(obs.membership.suspicions.get(), 2);
        assert_eq!(obs.membership.refutations.get(), 1);
        assert_eq!(obs.membership.alive.get(), 1.0, "refuted back to alive");
    }

    #[test]
    fn from_join_requires_own_entry() {
        let mut t = MemberTable::new();
        t.upsert(MemberEntry::alive(0, addr(1)));
        assert!(Membership::from_join(t.clone(), addr(9), fast_cfg()).is_err());
        t.upsert(MemberEntry::alive(1, addr(9)));
        let m = Membership::from_join(t, addr(9), fast_cfg()).unwrap();
        assert_eq!(m.self_id(), 1);
        assert!(!m.is_distinguished());
    }
}
