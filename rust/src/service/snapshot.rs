//! Epoch-stamped immutable query views.
//!
//! A [`Snapshot`] is what the coordinator publishes after every epoch and
//! what every query reads: a merged sketch plus provenance (epoch number,
//! lifetime operation count, window coverage). Snapshots are immutable —
//! queries on one are plain reads with no synchronization, and a handle
//! stays valid (and answers consistently) no matter how far the service
//! advances underneath it.

#![forbid(unsafe_code)]

use crate::sketch::{DenseStore, QuantileReader, SketchError, UddSketch};

/// An immutable service snapshot: the merged sketch as of one epoch.
#[derive(Debug, Clone)]
pub struct Snapshot {
    epoch: u64,
    sketch: UddSketch<DenseStore>,
    ops: u64,
    window: Option<(u64, u64)>,
}

impl Snapshot {
    /// Build a snapshot (coordinator only).
    pub(crate) fn new(
        epoch: u64,
        sketch: UddSketch<DenseStore>,
        ops: u64,
        window: Option<(u64, u64)>,
    ) -> Self {
        Self {
            epoch,
            sketch,
            ops,
            window,
        }
    }

    /// The pre-first-epoch snapshot.
    pub(crate) fn empty(alpha: f64, max_buckets: usize) -> Result<Self, SketchError> {
        Ok(Self {
            epoch: 0,
            sketch: UddSketch::new(alpha, max_buckets)?,
            ops: 0,
            window: None,
        })
    }

    /// Epoch this snapshot was published at (0 = before any epoch).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Operations (inserts + weighted updates) the service had applied
    /// when this snapshot was published — lifetime total, even in
    /// windowed mode.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Inclusive (1-based) epoch range a windowed snapshot covers;
    /// `None` in cumulative mode or before the first epoch.
    pub fn window(&self) -> Option<(u64, u64)> {
        self.window
    }

    /// The underlying merged sketch.
    pub fn sketch(&self) -> &UddSketch<DenseStore> {
        &self.sketch
    }

    /// Summarized weight (stream length for insert-only workloads).
    pub fn count(&self) -> f64 {
        self.sketch.count()
    }

    /// True when no weight is summarized.
    pub fn is_empty(&self) -> bool {
        self.sketch.is_empty()
    }

    /// Current relative-error bound α (accounts for collapses).
    pub fn alpha(&self) -> f64 {
        self.sketch.alpha()
    }

    /// Non-zero buckets in the merged sketch.
    pub fn bucket_count(&self) -> usize {
        self.sketch.bucket_count()
    }

    /// Estimate the inferior q-quantile (Definition 2).
    pub fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        self.sketch.quantile(q)
    }

    /// Batch quantile queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        self.sketch.quantiles(qs)
    }

    /// Estimated CDF at `x`.
    pub fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        self.sketch.cdf(x)
    }

    /// Estimated rank of `x` (items ≤ x).
    pub fn rank(&self, x: f64) -> f64 {
        self.sketch.rank(x)
    }
}

impl QuantileReader for Snapshot {
    fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        Snapshot::quantile(self, q)
    }

    fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        Snapshot::cdf(self, x)
    }

    fn count(&self) -> f64 {
        Snapshot::count(self)
    }

    fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        Snapshot::quantiles(self, qs)
    }

    fn is_empty(&self) -> bool {
        Snapshot::is_empty(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_reports_empty() {
        let s = Snapshot::empty(0.01, 64).unwrap();
        assert_eq!(s.epoch(), 0);
        assert_eq!(s.ops(), 0);
        assert!(s.is_empty());
        assert_eq!(s.window(), None);
        assert_eq!(s.quantile(0.5), Err(SketchError::Empty));
    }

    #[test]
    fn snapshot_delegates_queries_to_sketch() {
        let mut sk: UddSketch<DenseStore> = UddSketch::new(0.01, 256).unwrap();
        for i in 1..=100 {
            sk.insert(i as f64);
        }
        let reference = sk.clone();
        let snap = Snapshot::new(3, sk, 100, Some((1, 3)));
        assert_eq!(snap.epoch(), 3);
        assert_eq!(snap.count(), 100.0);
        assert_eq!(snap.window(), Some((1, 3)));
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(snap.quantile(q).unwrap(), reference.quantile(q).unwrap());
        }
        assert_eq!(snap.cdf(50.0).unwrap(), reference.cdf(50.0).unwrap());
        assert_eq!(snap.rank(50.0), reference.rank(50.0));
    }
}
