//! Exact quantile oracle (Definition 2) used to validate the sketches and
//! to compute the experiments' relative errors against ground truth.

use super::SketchError;

/// Holds a sorted copy of the data and answers exact inferior q-quantile
/// queries.
#[derive(Debug, Clone)]
pub struct ExactQuantiles {
    sorted: Vec<f64>,
}

impl ExactQuantiles {
    /// Sort (a copy of) the dataset. NaNs are rejected.
    pub fn new(data: &[f64]) -> Self {
        assert!(
            data.iter().all(|x| !x.is_nan()),
            "ExactQuantiles: NaN in input"
        );
        let mut sorted = data.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted }
    }

    /// Build from an already-sorted vector (asserts order in debug).
    pub fn from_sorted(sorted: Vec<f64>) -> Self {
        debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        Self { sorted }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True when the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The inferior q-quantile: element of rank `⌊1 + q(n−1)⌋`.
    pub fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        if !(0.0..=1.0).contains(&q) || q.is_nan() {
            return Err(SketchError::InvalidQuantile(q));
        }
        let n = self.sorted.len();
        if n == 0 {
            return Err(SketchError::Empty);
        }
        let rank = (1.0 + q * (n as f64 - 1.0)).floor() as usize;
        Ok(self.sorted[rank.clamp(1, n) - 1])
    }

    /// Rank of `x`: number of elements ≤ x (Definition 1).
    pub fn rank(&self, x: f64) -> usize {
        self.sorted.partition_point(|&v| v <= x)
    }

    /// Batch queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.quantile(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn definition2_on_small_set() {
        // S = {10, 20, 30, 40, 50}; n = 5.
        let e = ExactQuantiles::new(&[30.0, 10.0, 50.0, 20.0, 40.0]);
        assert_eq!(e.quantile(0.0).unwrap(), 10.0); // rank 1 = min
        assert_eq!(e.quantile(1.0).unwrap(), 50.0); // rank 5 = max
        assert_eq!(e.quantile(0.5).unwrap(), 30.0); // rank floor(3) = 3
        assert_eq!(e.quantile(0.24).unwrap(), 10.0); // rank floor(1.96)=1
        assert_eq!(e.quantile(0.25).unwrap(), 20.0); // rank floor(2)=2
    }

    #[test]
    fn rank_definition1() {
        let e = ExactQuantiles::new(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(e.rank(0.5), 0);
        assert_eq!(e.rank(2.0), 3);
        assert_eq!(e.rank(10.0), 4);
    }

    #[test]
    fn empty_and_invalid() {
        let e = ExactQuantiles::new(&[]);
        assert_eq!(e.quantile(0.5), Err(SketchError::Empty));
        let e = ExactQuantiles::new(&[1.0]);
        assert!(matches!(
            e.quantile(-0.1),
            Err(SketchError::InvalidQuantile(_))
        ));
    }
}
