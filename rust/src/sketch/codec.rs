//! Binary wire format for sketches, peer states, and exchange frames.
//!
//! A real P2P deployment ships the gossip state over the network; this
//! codec defines those frames (and gives the simulator exact per-message
//! byte accounting, reported in `RoundStats`). Hand-rolled little-endian
//! layout (serde is unavailable offline — DESIGN.md §6):
//!
//! ```text
//! magic "UDDS" | version u8 | alpha0 f64 | collapses u32 | max_buckets u64
//! zero_weight f64
//! pos_len u64 | (index i64, count f64) * pos_len
//! neg_len u64 | (index i64, count f64) * neg_len
//! ```
//!
//! Peer-state frames append `id u64 | n_tilde f64 | q_tilde f64`.
//!
//! The transport layer ([`crate::service::transport`]) wraps peer states
//! in **exchange frames** — the messages of the atomic push–pull
//! protocol:
//!
//! ```text
//! magic "UDDX" | version u8 | kind u8 | generation u64 | payload
//! ```
//!
//! where `kind` selects [`ExchangeKind`] and the payload is a peer-state
//! frame (`Push`/`Reply`) or a one-byte [`RejectReason`] (`Reject`).
//! Every decoder rejects bad magic, unknown versions/kinds, truncation at
//! any offset, and length fields larger than the remaining buffer (so a
//! hostile frame can never trigger a huge allocation).

use super::{SketchError, Store, UddSketch};
use crate::gossip::PeerState;

const MAGIC: &[u8; 4] = b"UDDS";
const EXCHANGE_MAGIC: &[u8; 4] = b"UDDX";
const VERSION: u8 = 1;

/// Encoding/decoding errors.
///
/// (`Display` is hand-written — thiserror is unavailable offline,
/// DESIGN.md §6.)
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// Frame too short or structurally invalid.
    Truncated(usize),
    /// Bad magic bytes.
    BadMagic,
    /// Unsupported version byte.
    BadVersion(u8),
    /// Unknown exchange-frame kind byte.
    BadKind(u8),
    /// Decoded parameters failed sketch validation.
    BadParams(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated(pos) => write!(f, "truncated frame at byte {pos}"),
            CodecError::BadMagic => write!(f, "bad magic (not a DUDDSketch frame)"),
            CodecError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            CodecError::BadKind(k) => write!(f, "unknown exchange frame kind {k}"),
            CodecError::BadParams(msg) => write!(f, "invalid sketch parameters: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::Truncated(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, CodecError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// A length field for `width`-byte records: rejected when the claimed
    /// count cannot fit in the remaining buffer, so hostile frames are
    /// refused *before* any allocation sized from the wire.
    fn len_field(&mut self, width: usize) -> Result<usize, CodecError> {
        let pos = self.pos;
        let n = self.u64()?;
        if n > (self.remaining() / width) as u64 {
            return Err(CodecError::Truncated(pos));
        }
        Ok(n as usize)
    }
}

fn encode_sketch_into<S: Store>(s: &UddSketch<S>, out: &mut Vec<u8>) {
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&s.mapping().alpha0().to_le_bytes());
    out.extend_from_slice(&s.mapping().collapses().to_le_bytes());
    out.extend_from_slice(&(s.max_buckets() as u64).to_le_bytes());
    out.extend_from_slice(&s.zero_weight().to_le_bytes());
    for store in [s.positive_store(), s.negative_store()] {
        let entries = store.entries();
        out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (i, c) in entries {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
}

fn decode_sketch_from<S: Store>(
    r: &mut Reader<'_>,
) -> Result<UddSketch<S>, CodecError> {
    if r.take(4)? != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let alpha0 = r.f64()?;
    let collapses = r.u32()?;
    let max_buckets = r.u64()? as usize;
    let zero_weight = r.f64()?;
    let mut sketch: UddSketch<S> = UddSketch::new(alpha0, max_buckets)
        .map_err(|e: SketchError| CodecError::BadParams(e.to_string()))?;
    sketch.align_to_collapses(collapses);
    let pos_len = r.len_field(16)?;
    let mut pos = Vec::with_capacity(pos_len);
    for _ in 0..pos_len {
        pos.push((r.i64()?, r.f64()?));
    }
    let neg_len = r.len_field(16)?;
    let mut neg = Vec::with_capacity(neg_len);
    for _ in 0..neg_len {
        neg.push((r.i64()?, r.f64()?));
    }
    sketch.load_raw(zero_weight, &pos, &neg);
    Ok(sketch)
}

/// Encode a sketch to its wire frame.
pub fn encode_sketch<S: Store>(s: &UddSketch<S>) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + 16 * s.bucket_count());
    encode_sketch_into(s, &mut out);
    out
}

/// Decode a sketch frame.
pub fn decode_sketch<S: Store>(buf: &[u8]) -> Result<UddSketch<S>, CodecError> {
    decode_sketch_from(&mut Reader::new(buf))
}

fn encode_peer_state_into(s: &PeerState, out: &mut Vec<u8>) {
    encode_sketch_into(&s.sketch, out);
    out.extend_from_slice(&(s.id as u64).to_le_bytes());
    out.extend_from_slice(&s.n_tilde.to_le_bytes());
    out.extend_from_slice(&s.q_tilde.to_le_bytes());
}

fn decode_peer_state_from(r: &mut Reader<'_>) -> Result<PeerState, CodecError> {
    let sketch = decode_sketch_from(r)?;
    let id = r.u64()? as usize;
    let n_tilde = r.f64()?;
    let q_tilde = r.f64()?;
    Ok(PeerState {
        id,
        sketch,
        n_tilde,
        q_tilde,
    })
}

/// Encode a full peer state (gossip message payload).
pub fn encode_peer_state(s: &PeerState) -> Vec<u8> {
    let mut out = Vec::with_capacity(peer_state_wire_size(s));
    encode_peer_state_into(s, &mut out);
    out
}

/// Decode a peer-state frame.
pub fn decode_peer_state(buf: &[u8]) -> Result<PeerState, CodecError> {
    decode_peer_state_from(&mut Reader::new(buf))
}

/// Message kinds of the push–pull exchange protocol (the `kind` byte of
/// the frame header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeKind {
    /// Initiator → partner: the initiator's framed pre-round state.
    Push = 1,
    /// Partner → initiator: the averaged state both sides adopt.
    Reply = 2,
    /// Partner → initiator: exchange refused; both sides keep their
    /// pre-round state (§7.2 cancelled exchange).
    Reject = 3,
}

/// Why a partner refused an inbound exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The partner is mid-exchange or mid-round; retry next round.
    Busy,
    /// The push carried an older restart generation than the partner's
    /// (the frame's `generation` field reports the partner's).
    StaleGeneration,
    /// The sketches' α₀ lineages differ; these peers can never merge.
    Lineage,
    /// The push frame failed to decode.
    Malformed,
}

impl RejectReason {
    fn code(self) -> u8 {
        match self {
            RejectReason::Busy => 1,
            RejectReason::StaleGeneration => 2,
            RejectReason::Lineage => 3,
            RejectReason::Malformed => 4,
        }
    }

    fn from_code(code: u8) -> Result<Self, CodecError> {
        Ok(match code {
            1 => RejectReason::Busy,
            2 => RejectReason::StaleGeneration,
            3 => RejectReason::Lineage,
            4 => RejectReason::Malformed,
            other => {
                return Err(CodecError::BadParams(format!(
                    "unknown reject reason {other}"
                )))
            }
        })
    }
}

/// A decoded exchange frame (see the module docs for the layout).
#[derive(Debug, Clone)]
pub enum ExchangeFrame {
    /// The initiator's framed state at its restart generation.
    Push {
        /// Initiator's restart generation.
        generation: u64,
        /// Initiator's pre-round state.
        state: PeerState,
    },
    /// The averaged state (carrying the initiator's id) both sides adopt.
    Reply {
        /// The serving node's restart generation (equals the push's after
        /// a successful exchange).
        generation: u64,
        /// The averaged state.
        state: PeerState,
    },
    /// Exchange refused; both sides keep their pre-round state.
    Reject {
        /// The serving node's generation (meaningful for
        /// [`RejectReason::StaleGeneration`]; 0 otherwise).
        generation: u64,
        /// Why the exchange was refused.
        reason: RejectReason,
    },
}

fn exchange_header(kind: ExchangeKind, generation: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(EXCHANGE_MAGIC);
    out.push(VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&generation.to_le_bytes());
}

/// Encode a push frame (initiator's pre-round state).
pub fn encode_exchange_push(generation: u64, state: &PeerState) -> Vec<u8> {
    let mut out = Vec::with_capacity(14 + peer_state_wire_size(state));
    exchange_header(ExchangeKind::Push, generation, &mut out);
    encode_peer_state_into(state, &mut out);
    out
}

/// Encode a reply frame (the averaged state both sides adopt).
pub fn encode_exchange_reply(generation: u64, state: &PeerState) -> Vec<u8> {
    let mut out = Vec::with_capacity(14 + peer_state_wire_size(state));
    exchange_header(ExchangeKind::Reply, generation, &mut out);
    encode_peer_state_into(state, &mut out);
    out
}

/// Encode a reject frame (cancelled exchange, §7.2).
pub fn encode_exchange_reject(generation: u64, reason: RejectReason) -> Vec<u8> {
    let mut out = Vec::with_capacity(15);
    exchange_header(ExchangeKind::Reject, generation, &mut out);
    out.push(reason.code());
    out
}

/// Decode any exchange frame, validating magic, version, and kind.
pub fn decode_exchange(buf: &[u8]) -> Result<ExchangeFrame, CodecError> {
    let mut r = Reader::new(buf);
    if r.take(4)? != EXCHANGE_MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let kind = r.u8()?;
    let generation = r.u64()?;
    match kind {
        1 => Ok(ExchangeFrame::Push {
            generation,
            state: decode_peer_state_from(&mut r)?,
        }),
        2 => Ok(ExchangeFrame::Reply {
            generation,
            state: decode_peer_state_from(&mut r)?,
        }),
        3 => Ok(ExchangeFrame::Reject {
            generation,
            reason: RejectReason::from_code(r.u8()?)?,
        }),
        other => Err(CodecError::BadKind(other)),
    }
}

/// Wire size of a peer state without materializing the frame (used for
/// the simulator's traffic accounting).
pub fn peer_state_wire_size(s: &PeerState) -> usize {
    // header(4+1) + alpha(8) + collapses(4) + m(8) + zero(8) = 33
    // + 2 * len(8) + 16/bucket + id(8) + n(8) + q(8)
    33 + 16 + 16 * s.sketch.bucket_count() + 24
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::{DenseStore, SparseStore};

    fn sample_sketch() -> UddSketch<SparseStore> {
        let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, 64).unwrap();
        let mut r = default_rng(1);
        for _ in 0..5_000 {
            s.insert(10f64.powf(r.next_f64() * 5.0 - 1.0));
        }
        s.insert(-3.5);
        s.insert(0.0);
        s
    }

    #[test]
    fn sketch_roundtrip_is_exact() {
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<SparseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.collapses(), s.collapses());
        assert_eq!(d.count(), s.count());
        assert_eq!(d.zero_weight(), s.zero_weight());
        assert_eq!(
            d.positive_store().entries(),
            s.positive_store().entries()
        );
        assert_eq!(
            d.negative_store().entries(),
            s.negative_store().entries()
        );
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(d.quantile(q).unwrap(), s.quantile(q).unwrap());
        }
    }

    #[test]
    fn cross_store_roundtrip() {
        // Encode sparse, decode dense: same answers.
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<DenseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.quantile(0.9).unwrap(), s.quantile(0.9).unwrap());
    }

    #[test]
    fn peer_state_roundtrip() {
        let st = PeerState::init(7, &[1.0, 2.0, 3.0], 0.01, 32).unwrap();
        let buf = encode_peer_state(&st);
        assert_eq!(buf.len(), peer_state_wire_size(&st));
        let d = decode_peer_state(&buf).unwrap();
        assert_eq!(d.id, 7);
        assert_eq!(d.n_tilde, 3.0);
        assert_eq!(d.q_tilde, 0.0);
        assert_eq!(
            d.sketch.positive_store().entries(),
            st.sketch.positive_store().entries()
        );
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(
            decode_sketch::<SparseStore>(b"np").unwrap_err(),
            CodecError::Truncated(0)
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"nope").unwrap_err(),
            CodecError::BadMagic
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"XXXX\x01aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa")
                .unwrap_err(),
            CodecError::BadMagic
        );
        let mut ok = encode_sketch(&sample_sketch());
        ok[4] = 99; // version byte
        assert_eq!(
            decode_sketch::<SparseStore>(&ok).unwrap_err(),
            CodecError::BadVersion(99)
        );
    }

    #[test]
    fn truncation_detected_everywhere() {
        let buf = encode_peer_state(&PeerState::init(0, &[5.0, 6.0], 0.01, 32).unwrap());
        for cut in 0..buf.len() {
            let r = decode_peer_state(&buf[..cut]);
            assert!(r.is_err(), "cut at {cut} should fail");
        }
        assert!(decode_peer_state(&buf).is_ok());
    }

    #[test]
    fn exchange_push_and_reply_roundtrip() {
        let st = PeerState::init(3, &[1.0, 2.5, 9.0], 0.01, 32).unwrap();
        for (buf, want_push) in [
            (encode_exchange_push(7, &st), true),
            (encode_exchange_reply(7, &st), false),
        ] {
            match decode_exchange(&buf).unwrap() {
                ExchangeFrame::Push { generation, state } if want_push => {
                    assert_eq!(generation, 7);
                    assert_eq!(state.id, 3);
                    assert_eq!(state.n_tilde, 3.0);
                }
                ExchangeFrame::Reply { generation, state } if !want_push => {
                    assert_eq!(generation, 7);
                    assert_eq!(
                        state.sketch.positive_store().entries(),
                        st.sketch.positive_store().entries()
                    );
                }
                other => panic!("wrong frame decoded: {other:?}"),
            }
        }
    }

    #[test]
    fn exchange_reject_roundtrip_all_reasons() {
        for reason in [
            RejectReason::Busy,
            RejectReason::StaleGeneration,
            RejectReason::Lineage,
            RejectReason::Malformed,
        ] {
            let buf = encode_exchange_reject(42, reason);
            match decode_exchange(&buf).unwrap() {
                ExchangeFrame::Reject { generation, reason: r } => {
                    assert_eq!(generation, 42);
                    assert_eq!(r, reason);
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
    }

    #[test]
    fn exchange_frame_rejects_bad_inputs() {
        let st = PeerState::init(0, &[5.0], 0.01, 32).unwrap();
        let good = encode_exchange_push(1, &st);

        assert_eq!(decode_exchange(b"UDD").unwrap_err(), CodecError::Truncated(0));
        assert_eq!(
            decode_exchange(b"UDDSxxxxxxxxxxxxxxxx").unwrap_err(),
            CodecError::BadMagic,
            "sketch magic is not exchange magic"
        );
        let mut bad = good.clone();
        bad[4] = 99;
        assert_eq!(decode_exchange(&bad).unwrap_err(), CodecError::BadVersion(99));
        let mut bad = good.clone();
        bad[5] = 17;
        assert_eq!(decode_exchange(&bad).unwrap_err(), CodecError::BadKind(17));
        let mut bad = encode_exchange_reject(0, RejectReason::Busy);
        *bad.last_mut().unwrap() = 200;
        assert!(matches!(
            decode_exchange(&bad).unwrap_err(),
            CodecError::BadParams(_)
        ));
        for cut in 0..good.len() {
            assert!(decode_exchange(&good[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn hostile_length_prefix_rejected_before_allocation() {
        // Patch the positive-store length field of a valid sketch frame to
        // an absurd count: the decoder must fail fast, not reserve memory.
        let s = sample_sketch();
        let mut buf = encode_sketch(&s);
        // Layout: magic(4) version(1) alpha(8) collapses(4) m(8) zero(8),
        // then pos_len at offset 33.
        buf[33..41].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_sketch::<SparseStore>(&buf).unwrap_err(),
            CodecError::Truncated(_)
        ));
    }
}
