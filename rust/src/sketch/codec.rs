//! Binary wire format for sketches and peer states.
//!
//! A real P2P deployment ships the gossip state over the network; this
//! codec defines that frame (and gives the simulator exact per-message
//! byte accounting, reported in `RoundStats`). Hand-rolled little-endian
//! layout (serde is unavailable offline — DESIGN.md §6):
//!
//! ```text
//! magic "UDDS" | version u8 | alpha0 f64 | collapses u32 | max_buckets u64
//! zero_weight f64
//! pos_len u64 | (index i64, count f64) * pos_len
//! neg_len u64 | (index i64, count f64) * neg_len
//! ```
//!
//! Peer-state frames append `id u64 | n_tilde f64 | q_tilde f64`.

use super::{SketchError, Store, UddSketch};
use crate::gossip::PeerState;

const MAGIC: &[u8; 4] = b"UDDS";
const VERSION: u8 = 1;

/// Encoding/decoding errors.
///
/// (`Display` is hand-written — thiserror is unavailable offline,
/// DESIGN.md §6.)
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// Frame too short or structurally invalid.
    Truncated(usize),
    /// Bad magic bytes.
    BadMagic,
    /// Unsupported version byte.
    BadVersion(u8),
    /// Decoded parameters failed sketch validation.
    BadParams(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated(pos) => write!(f, "truncated frame at byte {pos}"),
            CodecError::BadMagic => write!(f, "bad magic (not a DUDDSketch frame)"),
            CodecError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            CodecError::BadParams(msg) => write!(f, "invalid sketch parameters: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::Truncated(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, CodecError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn encode_sketch_into<S: Store>(s: &UddSketch<S>, out: &mut Vec<u8>) {
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&s.mapping().alpha0().to_le_bytes());
    out.extend_from_slice(&s.mapping().collapses().to_le_bytes());
    out.extend_from_slice(&(s.max_buckets() as u64).to_le_bytes());
    out.extend_from_slice(&s.zero_weight().to_le_bytes());
    for store in [s.positive_store(), s.negative_store()] {
        let entries = store.entries();
        out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (i, c) in entries {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
}

fn decode_sketch_from<S: Store>(
    r: &mut Reader<'_>,
) -> Result<UddSketch<S>, CodecError> {
    if r.take(4)? != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let alpha0 = r.f64()?;
    let collapses = r.u32()?;
    let max_buckets = r.u64()? as usize;
    let zero_weight = r.f64()?;
    let mut sketch: UddSketch<S> = UddSketch::new(alpha0, max_buckets)
        .map_err(|e: SketchError| CodecError::BadParams(e.to_string()))?;
    sketch.align_to_collapses(collapses);
    let pos_len = r.u64()? as usize;
    let mut pos = Vec::with_capacity(pos_len);
    for _ in 0..pos_len {
        pos.push((r.i64()?, r.f64()?));
    }
    let neg_len = r.u64()? as usize;
    let mut neg = Vec::with_capacity(neg_len);
    for _ in 0..neg_len {
        neg.push((r.i64()?, r.f64()?));
    }
    sketch.load_raw(zero_weight, &pos, &neg);
    Ok(sketch)
}

/// Encode a sketch to its wire frame.
pub fn encode_sketch<S: Store>(s: &UddSketch<S>) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + 16 * s.bucket_count());
    encode_sketch_into(s, &mut out);
    out
}

/// Decode a sketch frame.
pub fn decode_sketch<S: Store>(buf: &[u8]) -> Result<UddSketch<S>, CodecError> {
    decode_sketch_from(&mut Reader::new(buf))
}

/// Encode a full peer state (gossip message payload).
pub fn encode_peer_state(s: &PeerState) -> Vec<u8> {
    let mut out = encode_sketch(&s.sketch);
    out.extend_from_slice(&(s.id as u64).to_le_bytes());
    out.extend_from_slice(&s.n_tilde.to_le_bytes());
    out.extend_from_slice(&s.q_tilde.to_le_bytes());
    out
}

/// Decode a peer-state frame.
pub fn decode_peer_state(buf: &[u8]) -> Result<PeerState, CodecError> {
    let mut r = Reader::new(buf);
    let sketch = decode_sketch_from(&mut r)?;
    let id = r.u64()? as usize;
    let n_tilde = r.f64()?;
    let q_tilde = r.f64()?;
    Ok(PeerState {
        id,
        sketch,
        n_tilde,
        q_tilde,
    })
}

/// Wire size of a peer state without materializing the frame (used for
/// the simulator's traffic accounting).
pub fn peer_state_wire_size(s: &PeerState) -> usize {
    // header(4+1) + alpha(8) + collapses(4) + m(8) + zero(8) = 33
    // + 2 * len(8) + 16/bucket + id(8) + n(8) + q(8)
    33 + 16 + 16 * s.sketch.bucket_count() + 24
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::{DenseStore, SparseStore};

    fn sample_sketch() -> UddSketch<SparseStore> {
        let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, 64).unwrap();
        let mut r = default_rng(1);
        for _ in 0..5_000 {
            s.insert(10f64.powf(r.next_f64() * 5.0 - 1.0));
        }
        s.insert(-3.5);
        s.insert(0.0);
        s
    }

    #[test]
    fn sketch_roundtrip_is_exact() {
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<SparseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.collapses(), s.collapses());
        assert_eq!(d.count(), s.count());
        assert_eq!(d.zero_weight(), s.zero_weight());
        assert_eq!(
            d.positive_store().entries(),
            s.positive_store().entries()
        );
        assert_eq!(
            d.negative_store().entries(),
            s.negative_store().entries()
        );
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(d.quantile(q).unwrap(), s.quantile(q).unwrap());
        }
    }

    #[test]
    fn cross_store_roundtrip() {
        // Encode sparse, decode dense: same answers.
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<DenseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.quantile(0.9).unwrap(), s.quantile(0.9).unwrap());
    }

    #[test]
    fn peer_state_roundtrip() {
        let st = PeerState::init(7, &[1.0, 2.0, 3.0], 0.01, 32).unwrap();
        let buf = encode_peer_state(&st);
        assert_eq!(buf.len(), peer_state_wire_size(&st));
        let d = decode_peer_state(&buf).unwrap();
        assert_eq!(d.id, 7);
        assert_eq!(d.n_tilde, 3.0);
        assert_eq!(d.q_tilde, 0.0);
        assert_eq!(
            d.sketch.positive_store().entries(),
            st.sketch.positive_store().entries()
        );
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(
            decode_sketch::<SparseStore>(b"np").unwrap_err(),
            CodecError::Truncated(0)
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"nope").unwrap_err(),
            CodecError::BadMagic
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"XXXX\x01aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa")
                .unwrap_err(),
            CodecError::BadMagic
        );
        let mut ok = encode_sketch(&sample_sketch());
        ok[4] = 99; // version byte
        assert_eq!(
            decode_sketch::<SparseStore>(&ok).unwrap_err(),
            CodecError::BadVersion(99)
        );
    }

    #[test]
    fn truncation_detected_everywhere() {
        let buf = encode_peer_state(&PeerState::init(0, &[5.0, 6.0], 0.01, 32).unwrap());
        for cut in 0..buf.len() {
            let r = decode_peer_state(&buf[..cut]);
            assert!(r.is_err(), "cut at {cut} should fail");
        }
        assert!(decode_peer_state(&buf).is_ok());
    }
}
